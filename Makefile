# DYNAMIX build entrypoints.
#
# The Rust crate is self-contained with the default pure-Rust backend:
#   make build test          # no Python, no artifacts needed
#
# The XLA/PJRT backend additionally needs AOT artifacts + the `xla` crate:
#   make artifacts           # python/compile/aot.py -> artifacts/
#   (then enable the `backend-xla` feature; see rust/Cargo.toml)

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR ?= $(CURDIR)/artifacts

.PHONY: build test lint miri tsan bench bench-quick bench-compare artifacts artifacts-smoke clean-artifacts

build:
	cd rust && $(CARGO) build --release

test:
	cd rust && $(CARGO) test -q

# dynamix-lint: the repo-native invariant catalogue (SAFETY comments,
# env-read allowlist, wall-clock/collection/fold-order/feature-detect
# rules — see README "Correctness tooling"). Self-test first so a broken
# rule can never silently pass the tree.
lint:
	cd rust && $(CARGO) run --release --bin dynamix-lint -- --self-test
	cd rust && $(CARGO) run --release --bin dynamix-lint

# Miri over the unsafe concurrency core (WorkerSet queue/latch/panic
# paths, Workspace/PanelCache generation tagging, wire codec bounds, and
# the linalg SIMD lane dispatch — every new `unsafe` block's pointer
# discipline runs under the interpreter).
# Needs: rustup +nightly component add miri. Leak checking is off because
# the persistent worker threads are parked, never joined at process exit.
miri:
	cd rust && MIRIFLAGS="-Zmiri-ignore-leaks" $(CARGO) +nightly miri test --lib -- \
		runtime::native::exec runtime::native::workspace runtime::native::linalg comm::wire

# ThreadSanitizer (advisory): data-race detection on the pool + parity
# tests (linalg tiers AND the wire-codec/worker scratch reuse paths).
# Needs: rustup +nightly component add rust-src.
tsan:
	cd rust && RUSTFLAGS="-Zsanitizer=thread" $(CARGO) +nightly test -Zbuild-std \
		--target x86_64-unknown-linux-gnu --lib -- runtime::native::exec
	cd rust && RUSTFLAGS="-Zsanitizer=thread" $(CARGO) +nightly test -Zbuild-std \
		--target x86_64-unknown-linux-gnu --test linalg_parity
	cd rust && RUSTFLAGS="-Zsanitizer=thread" $(CARGO) +nightly test -Zbuild-std \
		--target x86_64-unknown-linux-gnu --test codec_parity

# Full benchmark sweep. Every bench binary appends a machine-readable run
# record (git rev, DYNAMIX_THREADS, p10/p50/p90, samples/s) to
# BENCH_native.json — the repo's perf trajectory. Tune with e.g.
#   DYNAMIX_THREADS=1 DYNAMIX_BENCH_NOTE=scalar-baseline make bench
bench:
	cd rust && $(CARGO) bench

# Smoke sweep (tiny warmup/iteration counts) for CI: exercises every bench
# path and still records BENCH_native.json, in seconds.
bench-quick:
	cd rust && DYNAMIX_BENCH_QUICK=1 $(CARGO) bench

# Print p50 deltas between the last two recorded runs of every bench suite
# in BENCH_native.json, so perf regressions are visible in PR output.
bench-compare:
	cd rust && $(CARGO) run --release --bin bench_compare

# Full artifact set: every (model, optimizer, bucket) combo (§VI grid).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir $(ARTIFACTS_DIR)

# Smoke subset: vgg11_mini/sgd at three buckets (fast CI for the xla path).
artifacts-smoke:
	cd python && $(PYTHON) -m compile.aot --out-dir $(ARTIFACTS_DIR) --subset smoke

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)
