"""L1 Pallas kernel: fused gradient-moment reduction.

DYNAMIX's RL state vector (paper §IV-B) carries sigma_norm and sigma_norm^2
— the normalized standard deviation / variance of the gradient — so every
train step must reduce the full flat gradient to its first two moments.
Doing this with two separate jnp reductions reads the gradient from HBM
twice; this kernel computes (sum, sum of squares) in a single VMEM pass.

TPU shape: the flat vector is viewed as [P/1024, 1024] (8x128 vreg-aligned
rows), the grid walks row blocks sequentially, and both partial moments
accumulate into scalar outputs — revisiting the same (1,1) output block per
grid step is the Pallas idiom for a carried accumulator. On GPU this would
be a warp-shuffle tree; on TPU it is a sublane reduction, which is why the
inner tile is 1024 = 8 sublanes x 128 lanes.

The caller zero-pads the gradient to a multiple of CHUNK; zero padding is
moment-neutral.
"""

from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 1024  # 8 sublanes x 128 lanes
ROWS_PER_BLOCK = 8


def padded_len(n: int) -> int:
    """Length the caller must zero-pad a flat vector of ``n`` entries to."""
    block = CHUNK * ROWS_PER_BLOCK
    return ((n + block - 1) // block) * block


def _moments_kernel(g_ref, s_ref, ss_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)

    blk = g_ref[...]
    s_ref[...] += jnp.sum(blk, dtype=jnp.float32)[None]
    ss_ref[...] += jnp.sum(blk * blk, dtype=jnp.float32)[None]


@partial(jax.jit, static_argnames=("interpret",))
def grad_moments(g_flat, interpret: bool = True):
    """(sum, sum_sq) of a zero-padded flat f32 vector via one fused pass.

    ``g_flat`` must have length padded_len(true_len); returns two f32
    scalars shaped [1].
    """
    n = g_flat.shape[0]
    block = CHUNK * ROWS_PER_BLOCK
    assert n % block == 0, f"grad_moments input {n} not padded to {block}"
    rows = n // CHUNK
    g2d = g_flat.reshape(rows, CHUNK)
    nblocks = rows // ROWS_PER_BLOCK
    s, ss = pl.pallas_call(
        _moments_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, CHUNK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(g2d)
    return s[0], ss[0]


def normalized_grad_stats(g_flat_padded, n_valid, interpret: bool = True):
    """sigma_norm and sigma_norm^2 (paper §IV-B) from the fused moments.

    The gradient is RMS-normalized (the scale adaptive optimizers divide
    out), then sigma_norm = std(g)/ (rms + eps). Matches
    ref.normalized_grad_stats_ref.
    """
    s, ss = grad_moments(g_flat_padded, interpret=interpret)
    n = jnp.asarray(n_valid, jnp.float32)
    mean = s / n
    var = jnp.maximum(ss / n - mean * mean, 0.0)
    rms = jnp.sqrt(ss / n)
    eps = 1e-8
    sigma_norm = jnp.sqrt(var) / (rms + eps)
    return sigma_norm, sigma_norm * sigma_norm


def vmem_footprint_bytes() -> dict:
    """Analytic VMEM footprint of one program instance (DESIGN.md §Perf)."""
    f32 = 4
    g_tile = ROWS_PER_BLOCK * CHUNK * f32
    return {"g_tile": g_tile, "accumulators": 2 * f32, "total": g_tile + 2 * f32}
