"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest/hypothesis sweeps
(see python/tests/test_kernels.py). They are also used by the L2 model code
when ``DYNAMIX_NO_PALLAS=1`` is set, which gives a kernel-free lowering used
to A/B the Pallas path during debugging.
"""

from __future__ import annotations

import jax.numpy as jnp


def fused_dense_ref(x, w, b, activation: str = "relu"):
    """Reference for the fused matmul + bias + activation kernel.

    x: [M, K] f32, w: [K, N] f32, b: [N] f32 -> [M, N] f32.
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "linear":
        pass
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return y


def matmul_ref(a, b):
    """Plain tiled-matmul reference (used by the custom-VJP backward)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def grad_stats_ref(g, n_valid=None):
    """Reference for the fused gradient-moment reduction kernel.

    Given a flat (possibly zero-padded) gradient vector ``g``, return
    ``(sum, sum_of_squares)``. Padding entries are zeros so they do not
    contribute to either moment.
    """
    del n_valid  # zero padding means full-vector sums are already correct
    s = jnp.sum(g, dtype=jnp.float32)
    ss = jnp.sum(g * g, dtype=jnp.float32)
    return s, ss


def normalized_grad_stats_ref(g, n_valid):
    """The paper's sigma_norm / sigma_norm^2 statistics (Section IV-B).

    Gradients are RMS-normalized (the scale adaptive optimizers divide out),
    then sigma_norm is the standard deviation of the normalized gradient and
    sigma_norm^2 its variance:

        rms        = sqrt(E[g^2])
        sigma_norm = std(g) / (rms + eps)
    """
    s, ss = grad_stats_ref(g)
    n = jnp.asarray(n_valid, jnp.float32)
    mean = s / n
    var = jnp.maximum(ss / n - mean * mean, 0.0)
    rms = jnp.sqrt(ss / n)
    eps = 1e-8
    sigma_norm = jnp.sqrt(var) / (rms + eps)
    return sigma_norm, sigma_norm * sigma_norm
