"""L1 Pallas kernel: fused tiled matmul + bias + activation.

This is the compute hot spot of every model in the zoo (the mini VGG /
ResNet families are dense stacks). The kernel is written TPU-shaped even
though this environment executes it under ``interpret=True`` on the CPU
PJRT plugin (real Mosaic lowering emits a TPU custom-call the CPU client
cannot run — see DESIGN.md §Hardware-Adaptation):

 * the (M,K)x(K,N) product is tiled into MXU-aligned blocks; block sizes
   adapt down for the mini models but the schedule is the one a full-size
   deployment would use (128x128x128 blocks, K innermost "arbitrary" axis);
 * the accumulator lives in the output block across the K grid axis —
   revisiting the same output block for every k step is the Pallas idiom
   for a VMEM-resident accumulator;
 * bias add + activation are fused into the K-epilogue so the activation
   never round-trips to HBM between the matmul and the nonlinearity.

Autodiff: ``pallas_call`` has no automatic transpose, so ``fused_dense``
carries a ``jax.custom_vjp`` whose backward pass reuses the same tiled
matmul kernel for dx = g_act @ W^T and dW = x^T @ g_act (g_act = upstream
grad masked by the activation derivative) — the production answer, not an
interpret-mode workaround.
"""

from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned target tile. The mini models have K,N in {10..128}; the block
# picker clamps to the actual dim so interpret-mode tracing stays cheap,
# while full-size dims tile at 128 (the MXU systolic array edge).
TILE = 128
# Contraction (K) axis tiles at 512: K never affects MXU face utilization,
# and a larger K block quarters the sequential accumulation loop that
# dominates the backward dW = x^T @ g matmul, whose K is the *batch* axis
# (up to 32768). 512x128 f32 operand tiles stay VMEM-friendly.
K_TILE = 512
# Batch (M) axis tiles at 512 — every batch bucket in the ladder is a
# multiple of 32 (the paper's minimum batch size), so the block picker
# always finds an exact divisor and no M masking is needed. 512 rows x
# 128 cols x f32 = 256 KiB per x-tile: well inside VMEM with double
# buffering, and it keeps the grid small (interpret-mode grid steps lower
# to XLA while-loop iterations, which dominated the step cost at large
# buckets before this change — see EXPERIMENTS.md §Perf).
M_TILE = 512


def _block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target, preferring target."""
    if dim % target == 0:
        return target
    for cand in (256, 128, 96, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and dim % cand == 0:
            return cand
    return dim


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """Grid = (M/bm, N/bn, K/bk). K is the innermost, sequential axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...][None, :]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


@partial(jax.jit, static_argnames=("activation", "interpret"))
def fused_dense_fwd_kernel(x, w, b, activation: str = "relu", interpret: bool = True):
    """Raw kernel invocation (no VJP). x:[M,K] w:[K,N] b:[N] -> [M,N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = _block(m, M_TILE), _block(k, K_TILE), _block(n, TILE)
    nm, nn, nk = m // bm, n // bn, k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, activation=activation),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)


@partial(jax.jit, static_argnames=("interpret",))
def matmul_kernel(a, b, interpret: bool = True):
    """Tiled matmul (linear, no bias) on the same schedule; used by bwd."""
    zero_bias = jnp.zeros((b.shape[1],), jnp.float32)
    return fused_dense_fwd_kernel(a, b, zero_bias, activation="linear", interpret=interpret)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_dense(x, w, b, activation: str = "relu"):
    """Differentiable fused dense layer y = act(x @ w + b).

    Forward and both backward matmuls run on the Pallas tiled kernel.
    """
    return fused_dense_fwd_kernel(x, w, b, activation=activation)


def _fused_dense_fwd(x, w, b, activation):
    y = fused_dense_fwd_kernel(x, w, b, activation=activation)
    return y, (x, w, y)


def _fused_dense_bwd(activation, res, g):
    x, w, y = res
    if activation == "relu":
        # d relu: pass gradient only where the fused output was positive.
        g = g * (y > 0.0).astype(g.dtype)
    dx = matmul_kernel(g, w.T)          # [M,N] @ [N,K] -> [M,K]
    dw = matmul_kernel(x.T, g)          # [K,M] @ [M,N] -> [K,N]
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)


def vmem_footprint_bytes(m: int, k: int, n: int) -> dict:
    """Analytic VMEM footprint of one program instance (DESIGN.md §Perf).

    Returns bytes for the x tile, w tile, bias tile and output accumulator
    at the block shapes the picker would choose, plus the total. Used by
    EXPERIMENTS.md §Perf to document the HBM<->VMEM schedule against the
    16 MiB/core VMEM budget of a TPUv4-class part.
    """
    bm, bk, bn = _block(m, M_TILE), _block(k, K_TILE), _block(n, TILE)
    f32 = 4
    x_t, w_t, b_t, o_t = bm * bk * f32, bk * bn * f32, bn * f32, bm * bn * f32
    return {
        "block": (bm, bk, bn),
        "x_tile": x_t,
        "w_tile": w_t,
        "bias_tile": b_t,
        "acc_tile": o_t,
        "total": x_t + w_t + b_t + o_t,
    }


def mxu_utilization_estimate(m: int, k: int, n: int) -> float:
    """Fraction of MXU lanes the chosen blocks fill (128x128 systolic array).

    A block of (bm, bk)x(bk, bn) issues bm x bn x bk MACs against a
    128x128x8-per-cycle array; utilization is the fill of the 128x128 face.
    """
    bm, bk, bn = _block(m, M_TILE), _block(k, K_TILE), _block(n, TILE)
    return min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
