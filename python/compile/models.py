"""L2 model zoo: the mini VGG / ResNet families.

The paper trains VGG11/16/19 on CIFAR-10 and ResNet34/50 on CIFAR-100. This
reproduction substitutes CPU-feasible "mini" families that preserve the
*family structure* the experiments rely on (a depth ladder within each
family, so the Fig-6 policy-transfer experiment — train on VGG16, deploy on
VGG19 — remains meaningful):

 * ``vggN_mini``  — plain dense stacks (VGG's feedforward topology),
   depth growing 11 -> 16 -> 19 exactly as the conv counts grow in VGG;
 * ``resnetN_mini`` — pre-activation residual MLP blocks (ResNet's skip
   topology), block count growing 34 -> 50.

Every layer runs on the L1 Pallas ``fused_dense`` kernel (set
``DYNAMIX_NO_PALLAS=1`` to lower against the pure-jnp oracle instead, for
A/B debugging). Parameters are exchanged with the Rust runtime as a single
flat f32 vector (``ravel_pytree``), see DESIGN.md §Flat-parameter.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import List

import jax
import jax.flatten_util
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.fused_dense import fused_dense

# Synthetic CIFAR-like feature dimension (see rust/src/data): 128 features
# standing in for 3x32x32 images after the stem.
FEATURE_DIM = 128
WIDTH = 64  # hidden width; 1-core-CPU calibrated (DESIGN.md §Substitutions)


def _dense(x, p, activation="relu"):
    if os.environ.get("DYNAMIX_NO_PALLAS"):
        return kref.fused_dense_ref(x, p["w"], p["b"], activation)
    return fused_dense(x, p["w"], p["b"], activation)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # "vgg" | "resnet"
    num_classes: int
    feature_dim: int = FEATURE_DIM
    width: int = WIDTH
    depth: int = 0               # vgg: hidden layers; resnet: residual blocks

    @property
    def dataset(self) -> str:
        return "cifar10_syn" if self.num_classes == 10 else "cifar100_syn"


# Depth ladder mirrors the paper's families. VGG11/16/19 have 8/13/16 conv
# layers; the minis keep the same ordering at CPU scale. ResNet34/50 have
# 16/24 blocks; minis use 6/10.
MODEL_ZOO = {
    "vgg11_mini": ModelConfig("vgg11_mini", "vgg", 10, depth=5),
    "vgg16_mini": ModelConfig("vgg16_mini", "vgg", 10, depth=8),
    "vgg19_mini": ModelConfig("vgg19_mini", "vgg", 10, depth=10),
    "resnet34_mini": ModelConfig("resnet34_mini", "resnet", 100, depth=6),
    "resnet50_mini": ModelConfig("resnet50_mini", "resnet", 100, depth=10),
}


def _init_dense(key, fan_in, fan_out):
    wkey, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / fan_in)
    return {
        "w": jax.random.normal(wkey, (fan_in, fan_out), jnp.float32) * scale,
        "b": jnp.zeros((fan_out,), jnp.float32),
    }


def init_params(cfg: ModelConfig, seed: int = 0):
    """He-init parameter pytree for ``cfg``."""
    key = jax.random.PRNGKey(seed)
    params = {}
    if cfg.family == "vgg":
        dims = [cfg.feature_dim] + [cfg.width] * cfg.depth
        for i in range(cfg.depth):
            key, sub = jax.random.split(key)
            params[f"layer{i}"] = _init_dense(sub, dims[i], dims[i + 1])
        key, sub = jax.random.split(key)
        params["head"] = _init_dense(sub, cfg.width, cfg.num_classes)
    elif cfg.family == "resnet":
        key, sub = jax.random.split(key)
        params["stem"] = _init_dense(sub, cfg.feature_dim, cfg.width)
        for i in range(cfg.depth):
            key, k1 = jax.random.split(key)
            key, k2 = jax.random.split(key)
            blk = {
                "fc1": _init_dense(k1, cfg.width, cfg.width),
                "fc2": _init_dense(k2, cfg.width, cfg.width),
            }
            # Identity-start residual blocks (fc2 zero-init): without this
            # the activation scale grows with depth and the deep stacks
            # diverge at the paper's learning rates.
            blk["fc2"]["w"] = jnp.zeros_like(blk["fc2"]["w"])
            params[f"block{i}"] = blk
        key, sub = jax.random.split(key)
        params["head"] = _init_dense(sub, cfg.width, cfg.num_classes)
    else:
        raise ValueError(cfg.family)
    return params


def forward(cfg: ModelConfig, params, x):
    """Logits for a batch ``x`` [B, feature_dim] -> [B, num_classes]."""
    h = x
    if cfg.family == "vgg":
        for i in range(cfg.depth):
            h = _dense(h, params[f"layer{i}"], "relu")
    else:
        h = _dense(h, params["stem"], "relu")
        for i in range(cfg.depth):
            blk = params[f"block{i}"]
            inner = _dense(h, blk["fc1"], "relu")
            h = h + _dense(inner, blk["fc2"], "linear")
            h = jnp.maximum(h, 0.0)
    return _dense(h, params["head"], "linear")


def param_count(cfg: ModelConfig) -> int:
    params = init_params(cfg)
    flat, _ = jax.flatten_util.ravel_pytree(params)
    return int(flat.shape[0])


def masked_loss_and_metrics(cfg: ModelConfig, params, x, y, mask):
    """Mean masked cross-entropy + per-sample correctness vector.

    ``mask`` is a per-sample 0/1 weight; padded rows (bucket > true batch)
    carry mask 0 and contribute exactly zero to loss, gradient, and the
    ``correct`` vector the Rust trainer slices into per-worker accuracies.
    """
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, cfg.num_classes, dtype=jnp.float32)
    ce = -jnp.sum(onehot * logp, axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(ce * mask) / denom
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == y).astype(jnp.float32) * mask
    acc = jnp.sum(correct) / denom
    return loss, (acc, correct)
