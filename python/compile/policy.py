"""L2 PPO policy network + update steps (paper §IV-A), AOT-lowered for Rust.

The centralized arbitrator runs one shared-parameter policy over per-worker
states (pi_theta(a | s_i, s_global)). Three artifacts:

 * ``policy_forward``       — states[W,S] -> (logits[W,A], values[W]);
   W = MAX_WORKERS so one PJRT call scores every worker per decision cycle.
 * ``policy_update``        — the clipped-surrogate PPO minibatch step
   (Eq. 1) with entropy bonus, value loss, and Adam, over flat theta.
 * ``policy_update_simple`` — the paper's §IV-A "simplified" variant
   (cumulative-reward policy gradient, no clipping / no advantage
   baseline); kept as a first-class artifact so the ablation bench can
   compare the two (DESIGN.md §6).

The network is a 2x64 tanh MLP with separate logit/value heads — small
enough that plain jnp is the right tool (the Pallas kernel earns its keep
on the model hot path, not on a 16-feature MLP; see DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

STATE_DIM = 16       # see rust/src/rl/state.rs — kept in the manifest
N_ACTIONS = 5        # {-100, -25, 0, +25, +100}
MAX_WORKERS = 32     # forward batch; rust masks unused rows
MINIBATCH = 256      # update minibatch; rust pads + masks
HIDDEN = 64

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def init_policy_params(seed: int = 0):
    key = jax.random.PRNGKey(seed)
    dims = [STATE_DIM, HIDDEN, HIDDEN]
    params = {}
    for i in range(2):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(1.0 / dims[i])
        params[f"fc{i}"] = {
            "w": jax.random.normal(sub, (dims[i], dims[i + 1]), jnp.float32) * scale,
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
    key, k1 = jax.random.split(key)
    key, k2 = jax.random.split(key)
    # Near-zero heads: initial policy ~uniform, initial value ~0.
    params["pi"] = {
        "w": jax.random.normal(k1, (HIDDEN, N_ACTIONS), jnp.float32) * 0.01,
        "b": jnp.zeros((N_ACTIONS,), jnp.float32),
    }
    params["vf"] = {
        "w": jax.random.normal(k2, (HIDDEN, 1), jnp.float32) * 0.01,
        "b": jnp.zeros((1,), jnp.float32),
    }
    return params


def policy_param_count() -> int:
    flat, _ = ravel_pytree(init_policy_params())
    return int(flat.shape[0])


def _trunk(params, states):
    h = states
    for i in range(2):
        p = params[f"fc{i}"]
        h = jnp.tanh(h @ p["w"] + p["b"])
    logits = h @ params["pi"]["w"] + params["pi"]["b"]
    values = (h @ params["vf"]["w"] + params["vf"]["b"])[:, 0]
    return logits, values


def make_policy_forward():
    template = init_policy_params()
    _, unravel = ravel_pytree(template)

    def fwd(theta, states):
        logits, values = _trunk(unravel(theta), states)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return logp, values

    return fwd


def _adam(theta, m, v, step, grads, lr):
    new_step = step + 1.0
    t = new_step[0]
    new_m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    new_v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
    m_hat = new_m / (1.0 - ADAM_B1**t)
    v_hat = new_v / (1.0 - ADAM_B2**t)
    return theta - lr[0] * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS), new_m, new_v, new_step


def make_policy_update():
    """Clipped-surrogate PPO minibatch step (paper Eq. 1) + Adam."""
    template = init_policy_params()
    _, unravel = ravel_pytree(template)

    def update(
        theta, m, v, step, states, actions, old_logp, adv, ret, mask, lr,
        clip_eps, ent_coef, vf_coef,
    ):
        denom = jnp.maximum(jnp.sum(mask), 1.0)

        def loss_fn(th):
            logits, values = _trunk(unravel(th), states)
            logp_all = jax.nn.log_softmax(logits, axis=-1)
            logp = jnp.take_along_axis(logp_all, actions[:, None], axis=-1)[:, 0]
            ratio = jnp.exp(logp - old_logp)
            clipped = jnp.clip(ratio, 1.0 - clip_eps[0], 1.0 + clip_eps[0])
            pg = -jnp.sum(jnp.minimum(ratio * adv, clipped * adv) * mask) / denom
            v_loss = jnp.sum(jnp.square(values - ret) * mask) / denom
            entropy = -jnp.sum(jnp.sum(jnp.exp(logp_all) * logp_all, -1) * mask) / denom
            loss = pg + vf_coef[0] * v_loss - ent_coef[0] * entropy
            approx_kl = jnp.sum((old_logp - logp) * mask) / denom
            return loss, (pg, v_loss, entropy, approx_kl)

        (loss, (pg, v_loss, entropy, kl)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(theta)
        theta2, m2, v2, step2 = _adam(theta, m, v, step, grads, lr)
        return theta2, m2, v2, step2, loss, pg, v_loss, entropy, kl

    return update


def make_policy_update_simple():
    """Paper §IV-A simplification: raw cumulative-return policy gradient.

    No clipping, no learned baseline — loss = -E[logpi(a|s) * G] with an
    entropy bonus for exploration parity with the clipped variant. Keeps
    the same I/O signature (old_logp / adv / clip_eps are accepted and
    ignored) so the Rust driver can swap variants without special cases.
    """
    template = init_policy_params()
    _, unravel = ravel_pytree(template)

    def update(
        theta, m, v, step, states, actions, old_logp, adv, ret, mask, lr,
        clip_eps, ent_coef, vf_coef,
    ):
        del old_logp, adv, clip_eps
        denom = jnp.maximum(jnp.sum(mask), 1.0)

        def loss_fn(th):
            logits, values = _trunk(unravel(th), states)
            logp_all = jax.nn.log_softmax(logits, axis=-1)
            logp = jnp.take_along_axis(logp_all, actions[:, None], axis=-1)[:, 0]
            pg = -jnp.sum(logp * ret * mask) / denom
            v_loss = jnp.sum(jnp.square(values - ret) * mask) / denom
            entropy = -jnp.sum(jnp.sum(jnp.exp(logp_all) * logp_all, -1) * mask) / denom
            loss = pg + vf_coef[0] * v_loss - ent_coef[0] * entropy
            return loss, (pg, v_loss, entropy, jnp.float32(0.0))

        (loss, (pg, v_loss, entropy, kl)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(theta)
        theta2, m2, v2, step2 = _adam(theta, m, v, step, grads, lr)
        return theta2, m2, v2, step2, loss, pg, v_loss, entropy, kl

    return update


def forward_specs():
    p = policy_param_count()
    S = jax.ShapeDtypeStruct
    return (S((p,), jnp.float32), S((MAX_WORKERS, STATE_DIM), jnp.float32))


def update_specs():
    p = policy_param_count()
    S, f32, i32 = jax.ShapeDtypeStruct, jnp.float32, jnp.int32
    B = MINIBATCH
    return (
        S((p,), f32), S((p,), f32), S((p,), f32), S((1,), f32),
        S((B, STATE_DIM), f32), S((B,), i32), S((B,), f32), S((B,), f32),
        S((B,), f32), S((B,), f32), S((1,), f32), S((1,), f32), S((1,), f32),
        S((1,), f32),
    )
