"""AOT compiler: lower every DYNAMIX computation to HLO text + manifest.

Run once at build time (``make artifacts``); the Rust binary is
self-contained afterwards. Python never runs on the decision/training path.

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Emits ``artifacts/manifest.json`` describing every artifact's I/O schema so
the Rust runtime needs no hardcoded shape knowledge.

Usage:
    python -m compile.aot --out-dir ../artifacts [--subset smoke]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models, policy, train_step

# Batch-bucket ladder: XLA shapes are static, DYNAMIX batch sizes are
# dynamic. Every per-worker batch in [32,1024] (all action deltas are
# multiples of 25... clamped) and every fused-global batch (sum over <=32
# workers) maps to the smallest bucket >= B, tail masked. All multiples of
# 32 so the Pallas M-tile never needs masking.
BUCKETS = [32, 64, 128, 192, 256, 384, 512, 768, 1024, 1536, 2048, 3072, 4096, 6144, 8192, 12288, 16384, 24576, 32768]
EVAL_BATCH = 1024

# (model, optimizer) combos the paper's experiments exercise (§VI).
TRAIN_COMBOS = [
    ("vgg11_mini", "sgd"),
    ("vgg11_mini", "adam"),
    ("vgg16_mini", "sgd"),
    ("vgg19_mini", "sgd"),
    ("resnet34_mini", "sgd"),
    ("resnet50_mini", "sgd"),
]

SMOKE_COMBOS = [("vgg11_mini", "sgd")]
SMOKE_BUCKETS = [32, 64, 128]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_schema(specs):
    return [
        {"shape": list(s.shape), "dtype": s.dtype.name}
        for s in specs
    ]


def _out_schema(fn, specs):
    outs = jax.eval_shape(fn, *specs)
    flat, _ = jax.tree.flatten(outs)
    return [{"shape": list(s.shape), "dtype": s.dtype.name} for s in flat]


def _write(out_dir, name, fn, specs, meta, manifest, t0):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    entry = dict(meta)
    entry["file"] = f"{name}.hlo.txt"
    entry["inputs"] = _spec_schema(specs)
    entry["outputs"] = _out_schema(fn, specs)
    entry["hlo_bytes"] = len(text)
    entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
    manifest["artifacts"][name] = entry
    print(f"[aot {time.time()-t0:7.1f}s] {name}: {len(text)} bytes", flush=True)


def build(out_dir: str, subset: str = "full") -> None:
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    combos = SMOKE_COMBOS if subset == "smoke" else TRAIN_COMBOS
    buckets = SMOKE_BUCKETS if subset == "smoke" else BUCKETS

    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "state_dim": policy.STATE_DIM,
        "n_actions": policy.N_ACTIONS,
        "max_workers": policy.MAX_WORKERS,
        "ppo_minibatch": policy.MINIBATCH,
        "buckets": buckets,
        "eval_batch": EVAL_BATCH,
        "feature_dim": models.FEATURE_DIM,
        "models": {
            name: {
                "family": cfg.family,
                "depth": cfg.depth,
                "width": cfg.width,
                "num_classes": cfg.num_classes,
                "feature_dim": cfg.feature_dim,
                "param_count": models.param_count(cfg),
                "dataset": cfg.dataset,
            }
            for name, cfg in models.MODEL_ZOO.items()
        },
        "policy_param_count": policy.policy_param_count(),
        "artifacts": {},
    }

    # --- train steps: one artifact per (model, optimizer, bucket) ---
    for model_name, opt in combos:
        cfg = models.MODEL_ZOO[model_name]
        fn = train_step.make_train_step(cfg, opt)
        for bucket in buckets:
            specs = train_step.train_step_specs(cfg, opt, bucket)
            _write(
                out_dir,
                f"train_{model_name}_{opt}_b{bucket}",
                fn,
                specs,
                {
                    "kind": "train_step",
                    "model": model_name,
                    "optimizer": opt,
                    "bucket": bucket,
                    "param_count": models.param_count(cfg),
                },
                manifest,
                t0,
            )

    # --- eval steps: one per model ---
    for model_name in sorted({m for m, _ in combos}):
        cfg = models.MODEL_ZOO[model_name]
        fn = train_step.make_eval_step(cfg)
        specs = train_step.eval_step_specs(cfg, EVAL_BATCH)
        _write(
            out_dir,
            f"eval_{model_name}",
            fn,
            specs,
            {
                "kind": "eval_step",
                "model": model_name,
                "bucket": EVAL_BATCH,
                "param_count": models.param_count(cfg),
            },
            manifest,
            t0,
        )

    # --- policy artifacts ---
    _write(
        out_dir, "policy_forward", policy.make_policy_forward(),
        policy.forward_specs(), {"kind": "policy_forward"}, manifest, t0,
    )
    _write(
        out_dir, "policy_update", policy.make_policy_update(),
        policy.update_specs(), {"kind": "policy_update"}, manifest, t0,
    )
    _write(
        out_dir, "policy_update_simple", policy.make_policy_update_simple(),
        policy.update_specs(), {"kind": "policy_update_simple"}, manifest, t0,
    )

    # --- initial parameter snapshots (seeded) so Rust never re-derives
    #     init logic: raw little-endian f32, one file per model + policy ---
    import numpy as np
    from jax.flatten_util import ravel_pytree

    for model_name in sorted({m for m, _ in combos}):
        cfg = models.MODEL_ZOO[model_name]
        for seed in range(4):
            flat, _ = ravel_pytree(models.init_params(cfg, seed=seed))
            np.asarray(flat, dtype="<f4").tofile(
                os.path.join(out_dir, f"init_{model_name}_seed{seed}.f32")
            )
    for seed in range(4):
        flat, _ = ravel_pytree(policy.init_policy_params(seed=seed))
        np.asarray(flat, dtype="<f4").tofile(
            os.path.join(out_dir, f"init_policy_seed{seed}.f32")
        )
    manifest["init_seeds"] = 4

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts in {time.time()-t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--subset", choices=["full", "smoke"], default="full")
    args = ap.parse_args()
    build(args.out_dir, args.subset)


if __name__ == "__main__":
    main()
