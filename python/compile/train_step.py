"""L2 train/eval steps lowered to HLO for the Rust runtime.

One artifact per (model, optimizer, batch-bucket). The signature is uniform
across optimizers so the Rust trainer is optimizer-agnostic:

  inputs : params[P] m[P|1] v[P|1] step[1] x[B,D] y[B]i32 mask[B] lr[1]
  outputs: params' m' v' step' loss acc correct[B] sigma_norm sigma_norm2
           grad_l2

 * ``correct`` is the per-sample masked correctness vector — the Rust
   trainer slices it into per-worker shard ranges to recover each worker's
   batch accuracy from the fused-global execution (DESIGN.md §Fused-global).
 * sigma_norm / sigma_norm^2 are the paper's §IV-B gradient-normalization
   statistics, produced by the L1 ``grad_stats`` Pallas kernel.
 * SGD artifacts use momentum (the paper's CIFAR baselines); ``m`` carries
   the momentum buffer and ``v`` is a [1] dummy kept for signature
   uniformity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import models
from .kernels.grad_stats import normalized_grad_stats, padded_len

SGD_MOMENTUM = 0.9
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def _grad_statistics(grads_flat):
    n = grads_flat.shape[0]
    pad = padded_len(n) - n
    gp = jnp.pad(grads_flat, (0, pad))
    return normalized_grad_stats(gp, n)


def make_train_step(cfg: models.ModelConfig, optimizer: str):
    """Build the jittable train step over flat parameters."""
    template = models.init_params(cfg)
    _, unravel = ravel_pytree(template)

    def step_fn(params_flat, m, v, step, x, y, mask, lr):
        def loss_fn(pf):
            return models.masked_loss_and_metrics(cfg, unravel(pf), x, y, mask)

        (loss, (acc, correct)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params_flat
        )
        sigma_norm, sigma_norm2 = _grad_statistics(grads)
        grad_l2 = jnp.sqrt(jnp.sum(grads * grads))
        lr_s = lr[0]
        new_step = step + 1.0
        if optimizer == "sgd":
            new_m = SGD_MOMENTUM * m + grads
            new_params = params_flat - lr_s * new_m
            new_v = v
        elif optimizer == "adam":
            t = new_step[0]
            new_m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
            new_v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
            m_hat = new_m / (1.0 - ADAM_B1**t)
            v_hat = new_v / (1.0 - ADAM_B2**t)
            new_params = params_flat - lr_s * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS)
        else:
            raise ValueError(optimizer)
        return (
            new_params,
            new_m,
            new_v,
            new_step,
            loss,
            acc,
            correct,
            sigma_norm,
            sigma_norm2,
            grad_l2,
        )

    return step_fn


def make_eval_step(cfg: models.ModelConfig):
    """Eval step: (params[P], x[E,D], y[E], mask[E]) -> (loss, acc)."""
    template = models.init_params(cfg)
    _, unravel = ravel_pytree(template)

    def eval_fn(params_flat, x, y, mask):
        loss, (acc, _) = models.masked_loss_and_metrics(
            cfg, unravel(params_flat), x, y, mask
        )
        return loss, acc

    return eval_fn


def train_step_specs(cfg: models.ModelConfig, optimizer: str, bucket: int):
    """ShapeDtypeStructs for lowering a (cfg, optimizer, bucket) artifact."""
    p = models.param_count(cfg)
    opt_dim = p  # momentum buffer for sgd, first moment for adam
    v_dim = p if optimizer == "adam" else 1
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    return (
        S((p,), f32),            # params
        S((opt_dim,), f32),      # m
        S((v_dim,), f32),        # v
        S((1,), f32),            # step
        S((bucket, cfg.feature_dim), f32),  # x
        S((bucket,), i32),       # y
        S((bucket,), f32),       # mask
        S((1,), f32),            # lr
    )


def eval_step_specs(cfg: models.ModelConfig, eval_batch: int):
    p = models.param_count(cfg)
    S = jax.ShapeDtypeStruct
    return (
        S((p,), jnp.float32),
        S((eval_batch, cfg.feature_dim), jnp.float32),
        S((eval_batch,), jnp.int32),
        S((eval_batch,), jnp.float32),
    )
