"""L2 correctness: model zoo shapes, masking semantics, training dynamics."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile import models, train_step

RNG = np.random.default_rng(1)


def _batch(cfg, b):
    x = RNG.standard_normal((b, cfg.feature_dim)).astype(np.float32)
    y = RNG.integers(0, cfg.num_classes, b).astype(np.int32)
    return x, y


@pytest.mark.parametrize("name", list(models.MODEL_ZOO))
def test_forward_shapes(name):
    cfg = models.MODEL_ZOO[name]
    params = models.init_params(cfg)
    x, _ = _batch(cfg, 32)
    logits = models.forward(cfg, params, x)
    assert logits.shape == (32, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_family_depth_ladder():
    z = models.MODEL_ZOO
    assert z["vgg11_mini"].depth < z["vgg16_mini"].depth < z["vgg19_mini"].depth
    assert z["resnet34_mini"].depth < z["resnet50_mini"].depth
    assert models.param_count(z["vgg16_mini"]) > models.param_count(z["vgg11_mini"])


def test_param_count_matches_ravel():
    cfg = models.MODEL_ZOO["resnet34_mini"]
    flat, _ = ravel_pytree(models.init_params(cfg))
    assert flat.shape[0] == models.param_count(cfg)


def test_init_deterministic_per_seed():
    cfg = models.MODEL_ZOO["vgg11_mini"]
    a, _ = ravel_pytree(models.init_params(cfg, seed=3))
    b, _ = ravel_pytree(models.init_params(cfg, seed=3))
    c, _ = ravel_pytree(models.init_params(cfg, seed=4))
    assert bool(jnp.all(a == b))
    assert not bool(jnp.all(a == c))


def test_mask_zero_rows_do_not_affect_loss_or_grad():
    cfg = models.MODEL_ZOO["vgg11_mini"]
    params = models.init_params(cfg)
    x, y = _batch(cfg, 64)
    mask_full = np.ones(64, np.float32)
    mask_half = mask_full.copy()
    mask_half[32:] = 0.0

    def loss32(p):
        return models.masked_loss_and_metrics(cfg, p, x[:32], y[:32], mask_full[:32])[0]

    def loss_masked(p):
        # 64-row batch where rows 32.. are *garbage* but masked out.
        xg = x.copy()
        xg[32:] = 1e6
        return models.masked_loss_and_metrics(cfg, p, xg, y, mask_half)[0]

    l1, l2 = loss32(params), loss_masked(params)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    g1 = ravel_pytree(jax.grad(loss32)(params))[0]
    g2 = ravel_pytree(jax.grad(loss_masked)(params))[0]
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_correct_vector_respects_mask_and_slices():
    cfg = models.MODEL_ZOO["vgg11_mini"]
    params = models.init_params(cfg)
    x, y = _batch(cfg, 64)
    mask = np.ones(64, np.float32)
    mask[48:] = 0.0
    _, (acc, correct) = models.masked_loss_and_metrics(cfg, params, x, y, mask)
    assert correct.shape == (64,)
    assert bool(jnp.all(correct[48:] == 0.0))
    np.testing.assert_allclose(jnp.sum(correct) / 48.0, acc, rtol=1e-6)


@pytest.mark.parametrize("opt", ["sgd", "adam"])
def test_train_step_decreases_loss(opt):
    cfg = models.MODEL_ZOO["vgg11_mini"]
    fn = jax.jit(train_step.make_train_step(cfg, opt))
    p = ravel_pytree(models.init_params(cfg))[0]
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p) if opt == "adam" else jnp.zeros((1,), jnp.float32)
    step = jnp.zeros((1,), jnp.float32)
    lr = jnp.asarray([0.05 if opt == "sgd" else 0.003], jnp.float32)

    # Learnable toy task: y determined by sign pattern of x projections.
    x, y = _batch(cfg, 128)
    proto = RNG.standard_normal((cfg.num_classes, cfg.feature_dim)).astype(np.float32)
    y = np.argmax(x @ proto.T, axis=1).astype(np.int32)
    mask = np.ones(128, np.float32)

    losses = []
    for _ in range(30):
        p, m, v, step, loss, acc, correct, sn, sn2, gl2 = fn(p, m, v, step, x, y, mask, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]
    assert float(step[0]) == 30.0
    assert np.isfinite(losses).all()


def test_train_step_outputs_schema():
    cfg = models.MODEL_ZOO["resnet34_mini"]
    specs = train_step.train_step_specs(cfg, "sgd", 64)
    outs = jax.eval_shape(train_step.make_train_step(cfg, "sgd"), *specs)
    pc = models.param_count(cfg)
    shapes = [tuple(o.shape) for o in outs]
    assert shapes == [
        (pc,), (pc,), (1,), (1,), (), (), (64,), (), (), (),
    ]


def test_eval_step_matches_train_metrics():
    cfg = models.MODEL_ZOO["vgg11_mini"]
    p = ravel_pytree(models.init_params(cfg))[0]
    x, y = _batch(cfg, 256)
    mask = np.ones(256, np.float32)
    loss, acc = train_step.make_eval_step(cfg)(p, x, y, mask)
    loss2, (acc2, _) = models.masked_loss_and_metrics(
        cfg, models.init_params(cfg), x, y, mask
    )
    np.testing.assert_allclose(loss, loss2, rtol=1e-6)
    np.testing.assert_allclose(acc, acc2, rtol=1e-6)


def test_adam_and_sgd_diverge():
    cfg = models.MODEL_ZOO["vgg11_mini"]
    x, y = _batch(cfg, 32)
    mask = np.ones(32, np.float32)
    outs = {}
    for opt in ["sgd", "adam"]:
        fn = train_step.make_train_step(cfg, opt)
        p = ravel_pytree(models.init_params(cfg))[0]
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p) if opt == "adam" else jnp.zeros((1,), jnp.float32)
        r = fn(p, m, v, jnp.zeros((1,), jnp.float32), x, y, mask,
               jnp.asarray([0.01], jnp.float32))
        outs[opt] = np.asarray(r[0])
    assert not np.allclose(outs["sgd"], outs["adam"])
