"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the repro contract: the kernel must
match ref.py under assert_allclose for every (M, K, N) the models can
produce, including non-tile-aligned dims the block picker must handle.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_dense import (
    fused_dense,
    fused_dense_fwd_kernel,
    matmul_kernel,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.grad_stats import (
    CHUNK,
    ROWS_PER_BLOCK,
    grad_moments,
    normalized_grad_stats,
    padded_len,
)

RNG = np.random.default_rng(0)


def _rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


# --- fused_dense forward -------------------------------------------------

dims_m = st.sampled_from([32, 64, 96, 128, 192, 256])
dims_k = st.sampled_from([10, 16, 64, 100, 128, 192])
dims_n = st.sampled_from([10, 16, 64, 100, 128])


@settings(max_examples=25, deadline=None)
@given(m=dims_m, k=dims_k, n=dims_n, act=st.sampled_from(["relu", "linear"]))
def test_fused_dense_matches_ref(m, k, n, act):
    x, w, b = _rand(m, k), _rand(k, n), _rand(n)
    got = fused_dense_fwd_kernel(x, w, b, activation=act)
    want = ref.fused_dense_ref(x, w, b, act)
    # K-blocked accumulation reorders the summation vs the monolithic
    # reference dot; tolerance reflects f32 reassociation, not a bug.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(m=dims_m, k=dims_k, n=dims_n)
def test_matmul_kernel_matches_ref(m, k, n):
    a, b = _rand(m, k), _rand(k, n)
    np.testing.assert_allclose(
        matmul_kernel(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=2e-5
    )


def test_fused_dense_zero_bias_linear_is_matmul():
    x, w = _rand(64, 128), _rand(128, 64)
    got = fused_dense_fwd_kernel(x, w, np.zeros(64, np.float32), activation="linear")
    np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-5)


def test_fused_dense_relu_clamps_negative():
    x = -np.abs(_rand(32, 64))
    w = np.eye(64, dtype=np.float32)
    b = np.zeros(64, np.float32)
    got = fused_dense_fwd_kernel(x, w, b, activation="relu")
    assert float(jnp.min(got)) == 0.0


# --- fused_dense custom VJP ----------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([32, 64]), k=dims_k, n=st.sampled_from([10, 64, 128]),
       act=st.sampled_from(["relu", "linear"]))
def test_fused_dense_grads_match_ref(m, k, n, act):
    x, w, b = _rand(m, k), _rand(k, n), _rand(n)

    def f(x, w, b):
        return jnp.sum(jnp.sin(fused_dense(x, w, b, act)))

    def fr(x, w, b):
        return jnp.sum(jnp.sin(ref.fused_dense_ref(x, w, b, act)))

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g, gr):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_fused_dense_relu_grad_zero_in_dead_region():
    # All-negative pre-activations -> relu kills every gradient.
    x = -np.abs(_rand(32, 64)) - 1.0
    w = np.eye(64, dtype=np.float32)
    b = np.zeros(64, np.float32) - 1.0

    def f(w):
        return jnp.sum(fused_dense(x, w, b, "relu"))

    g = jax.grad(f)(w)
    np.testing.assert_allclose(g, np.zeros_like(w), atol=1e-7)


# --- grad_stats ------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=60000), scale=st.sampled_from([1e-3, 1.0, 30.0]))
def test_grad_moments_matches_ref(n, scale):
    g = np.zeros(padded_len(n), np.float32)
    g[:n] = RNG.standard_normal(n).astype(np.float32) * scale
    s, ss = grad_moments(jnp.asarray(g))
    rs, rss = ref.grad_stats_ref(jnp.asarray(g))
    np.testing.assert_allclose(s, rs, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(ss, rss, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=2, max_value=40000))
def test_normalized_grad_stats_matches_ref(n):
    g = np.zeros(padded_len(n), np.float32)
    g[:n] = RNG.standard_normal(n).astype(np.float32)
    sn, sn2 = normalized_grad_stats(jnp.asarray(g), n)
    rn, rn2 = ref.normalized_grad_stats_ref(jnp.asarray(g), n)
    np.testing.assert_allclose(sn, rn, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sn2, rn2, rtol=1e-4, atol=1e-5)


def test_grad_stats_padding_is_neutral():
    n = 1000
    base = RNG.standard_normal(n).astype(np.float32)
    g1 = np.zeros(padded_len(n), np.float32)
    g1[:n] = base
    # Same values inside a much larger padded buffer.
    g2 = np.zeros(padded_len(n) + CHUNK * ROWS_PER_BLOCK * 3, np.float32)
    g2[:n] = base
    s1 = grad_moments(jnp.asarray(g1))
    s2 = grad_moments(jnp.asarray(g2))
    np.testing.assert_allclose(s1[0], s2[0], rtol=1e-5)
    np.testing.assert_allclose(s1[1], s2[1], rtol=1e-5)


def test_sigma_norm_scale_invariant():
    # RMS normalization makes sigma_norm invariant to gradient scale —
    # the property that lets the RL state compare across optimizers.
    n = 5000
    g = np.zeros(padded_len(n), np.float32)
    g[:n] = RNG.standard_normal(n).astype(np.float32)
    a, _ = normalized_grad_stats(jnp.asarray(g), n)
    b, _ = normalized_grad_stats(jnp.asarray(g * 100.0), n)
    np.testing.assert_allclose(a, b, rtol=1e-3)


def test_padded_len_properties():
    block = CHUNK * ROWS_PER_BLOCK
    for n in [1, block - 1, block, block + 1, 12345, 10 * block]:
        p = padded_len(n)
        assert p >= n and p % block == 0 and p - n < block


# --- perf-model helpers -----------------------------------------------------

def test_vmem_footprint_within_budget():
    # Full-size tiles must fit VMEM with generous room for double
    # buffering (16 MiB/core on TPUv4-class parts).
    fp = vmem_footprint_bytes(1024, 512, 512)
    assert fp["total"] <= 2 * 1024 * 1024, fp
    assert fp["block"] == (512, 512, 128)


def test_mxu_utilization_full_tiles():
    # M tile >= 128 saturates the 128x128 systolic-array face.
    assert mxu_utilization_estimate(1024, 128, 128) == pytest.approx(1.0)
    # Tiny N (the 10-way head) underfills lanes, as expected.
    assert mxu_utilization_estimate(1024, 128, 10) < 0.1
