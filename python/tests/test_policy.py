"""PPO policy network + update step correctness."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile import policy

RNG = np.random.default_rng(2)
S, A, W, B = policy.STATE_DIM, policy.N_ACTIONS, policy.MAX_WORKERS, policy.MINIBATCH


def _theta(seed=0):
    return ravel_pytree(policy.init_policy_params(seed))[0]


def test_forward_shapes_and_logprob_normalization():
    fwd = policy.make_policy_forward()
    states = RNG.standard_normal((W, S)).astype(np.float32)
    logp, values = fwd(_theta(), states)
    assert logp.shape == (W, A) and values.shape == (W,)
    np.testing.assert_allclose(jnp.sum(jnp.exp(logp), axis=-1), np.ones(W), rtol=1e-5)


def test_initial_policy_near_uniform():
    fwd = policy.make_policy_forward()
    states = RNG.standard_normal((W, S)).astype(np.float32)
    logp, values = fwd(_theta(), states)
    probs = np.asarray(jnp.exp(logp))
    assert np.abs(probs - 1.0 / A).max() < 0.05
    assert np.abs(np.asarray(values)).max() < 0.5


def _update_args(theta, update_fn=None, ret_scale=1.0):
    states = RNG.standard_normal((B, S)).astype(np.float32)
    actions = RNG.integers(0, A, B).astype(np.int32)
    fwd = policy.make_policy_forward()
    # old_logp computed in chunks of W rows through the forward artifact path
    logps = []
    for i in range(0, B, W):
        lp, _ = fwd(theta, states[i : i + W])
        logps.append(np.asarray(lp)[np.arange(W), actions[i : i + W]])
    old_logp = np.concatenate(logps).astype(np.float32)
    adv = RNG.standard_normal(B).astype(np.float32)
    ret = (RNG.standard_normal(B) * ret_scale).astype(np.float32)
    mask = np.ones(B, np.float32)
    p = theta.shape[0]
    return (
        theta, jnp.zeros((p,), jnp.float32), jnp.zeros((p,), jnp.float32),
        jnp.zeros((1,), jnp.float32), states, actions, old_logp, adv, ret, mask,
        jnp.asarray([3e-4], jnp.float32), jnp.asarray([0.2], jnp.float32),
        jnp.asarray([0.01], jnp.float32), jnp.asarray([0.5], jnp.float32),
    )


@pytest.mark.parametrize("maker", [policy.make_policy_update, policy.make_policy_update_simple])
def test_update_changes_params_finite(maker):
    upd = jax.jit(maker())
    args = _update_args(_theta())
    theta2, m2, v2, step2, loss, pg, vl, ent, kl = upd(*args)
    assert theta2.shape == args[0].shape
    assert not np.allclose(theta2, args[0])
    for s in [loss, pg, vl, ent, kl]:
        assert np.isfinite(float(s))
    assert float(step2[0]) == 1.0


def test_clipped_update_kl_zero_on_first_step():
    # Immediately after computing old_logp from the same theta, KL ~ 0.
    upd = policy.make_policy_update()
    args = _update_args(_theta())
    *_, kl = upd(*args)
    assert abs(float(kl)) < 1e-4


def test_update_improves_surrogate_on_repeated_steps():
    # Repeatedly reinforcing action 2 with positive advantage must raise
    # its probability.
    theta = _theta()
    upd = jax.jit(policy.make_policy_update())
    fwd = policy.make_policy_forward()
    states = np.tile(RNG.standard_normal((1, S)).astype(np.float32), (B, 1))
    actions = np.full(B, 2, np.int32)
    adv = np.ones(B, np.float32)
    ret = np.ones(B, np.float32)
    mask = np.ones(B, np.float32)
    p = theta.shape[0]
    m = jnp.zeros((p,), jnp.float32)
    v = jnp.zeros((p,), jnp.float32)
    step = jnp.zeros((1,), jnp.float32)
    prob0 = float(jnp.exp(fwd(theta, states[:W])[0][0, 2]))
    for _ in range(10):
        lp, _ = fwd(theta, states[:W])
        old_logp = np.tile(np.asarray(lp)[0, 2], B).astype(np.float32)
        theta, m, v, step, *_ = upd(
            theta, m, v, step, states, actions, old_logp, adv, ret, mask,
            jnp.asarray([1e-3], jnp.float32), jnp.asarray([0.2], jnp.float32),
            jnp.asarray([0.0], jnp.float32), jnp.asarray([0.0], jnp.float32),
        )
    prob1 = float(jnp.exp(fwd(theta, states[:W])[0][0, 2]))
    assert prob1 > prob0 + 0.05, (prob0, prob1)


def test_mask_rows_do_not_contribute():
    upd = policy.make_policy_update()
    args = list(_update_args(_theta()))
    # Zero-mask the second half and fill it with garbage.
    mask = np.ones(B, np.float32)
    mask[B // 2:] = 0.0
    states_g = np.array(args[4])
    states_g[B // 2:] = 1e5
    args_g = list(args)
    args_g[4], args_g[9] = states_g, mask
    args_h = list(args)
    args_h[9] = mask
    out_g = upd(*args_g)
    out_h = upd(*args_h)
    np.testing.assert_allclose(out_g[0], out_h[0], rtol=1e-5, atol=1e-6)


def test_simple_variant_ignores_clip_and_adv():
    upd = policy.make_policy_update_simple()
    args = list(_update_args(_theta()))
    a1 = upd(*args)
    args2 = list(args)
    args2[7] = np.zeros(B, np.float32)              # adv
    args2[11] = jnp.asarray([9.9], jnp.float32)     # clip_eps
    a2 = upd(*args2)
    np.testing.assert_allclose(a1[0], a2[0], rtol=1e-6)


def test_policy_param_count_stable():
    # The manifest ships this; rust sizes buffers from it.
    expected = (S * 64 + 64) + (64 * 64 + 64) + (64 * A + A) + (64 + 1)
    assert policy.policy_param_count() == expected
