"""AOT pipeline: artifacts lower, manifest schema is complete and honest."""

import json
import os

import numpy as np
import pytest
import jax

from compile import aot, models, policy, train_step


@pytest.fixture(scope="module")
def smoke_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.build(str(d), subset="smoke")
    return str(d)


def test_manifest_schema(smoke_dir):
    man = json.load(open(os.path.join(smoke_dir, "manifest.json")))
    assert man["version"] == 1
    assert man["state_dim"] == policy.STATE_DIM
    assert man["n_actions"] == 5
    assert set(man["models"]) == set(models.MODEL_ZOO)
    for name, art in man["artifacts"].items():
        assert os.path.exists(os.path.join(smoke_dir, art["file"])), name
        assert art["kind"] in {
            "train_step", "eval_step", "policy_forward",
            "policy_update", "policy_update_simple",
        }
        for io in art["inputs"] + art["outputs"]:
            assert io["dtype"] in {"float32", "int32"}
            assert all(isinstance(d, int) for d in io["shape"])


def test_manifest_io_matches_eval_shape(smoke_dir):
    man = json.load(open(os.path.join(smoke_dir, "manifest.json")))
    art = man["artifacts"]["train_vgg11_mini_sgd_b32"]
    cfg = models.MODEL_ZOO["vgg11_mini"]
    specs = train_step.train_step_specs(cfg, "sgd", 32)
    assert [list(s.shape) for s in specs] == [i["shape"] for i in art["inputs"]]
    outs = jax.eval_shape(train_step.make_train_step(cfg, "sgd"), *specs)
    assert [list(o.shape) for o in outs] == [o["shape"] for o in art["outputs"]]


def test_hlo_text_is_parseable_entry_computation(smoke_dir):
    txt = open(os.path.join(smoke_dir, "train_vgg11_mini_sgd_b32.hlo.txt")).read()
    assert "ENTRY" in txt and "HloModule" in txt
    # Tuple-rooted (return_tuple=True) so rust can decompose_tuple.
    assert "tuple(" in txt.replace(" ", "")[-4000:] or "tuple" in txt


def test_init_snapshots_deterministic(smoke_dir):
    man = json.load(open(os.path.join(smoke_dir, "manifest.json")))
    pc = man["models"]["vgg11_mini"]["param_count"]
    raw = np.fromfile(os.path.join(smoke_dir, "init_vgg11_mini_seed0.f32"), "<f4")
    assert raw.shape[0] == pc
    from jax.flatten_util import ravel_pytree
    flat, _ = ravel_pytree(models.init_params(models.MODEL_ZOO["vgg11_mini"], 0))
    np.testing.assert_allclose(raw, np.asarray(flat), rtol=0, atol=0)


def test_policy_init_snapshot(smoke_dir):
    raw = np.fromfile(os.path.join(smoke_dir, "init_policy_seed1.f32"), "<f4")
    assert raw.shape[0] == policy.policy_param_count()
    assert np.isfinite(raw).all()


def test_bucket_ladder_invariants():
    assert aot.BUCKETS == sorted(aot.BUCKETS)
    assert all(b % 32 == 0 for b in aot.BUCKETS)
    assert aot.BUCKETS[0] == 32
    # Ladder never over-pads by more than 2x (cost bound for fused-global).
    for lo, hi in zip(aot.BUCKETS, aot.BUCKETS[1:]):
        assert hi <= 2 * lo, (lo, hi)
    # Covers a full 32-worker cluster at the paper's max batch 1024... or
    # documents the cap the trainer splits at.
    assert aot.BUCKETS[-1] >= 32 * 1024
