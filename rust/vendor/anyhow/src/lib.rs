//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment for this repo has no network access and no crates.io
//! mirror, so the crate graph must be fully self-contained. This shim covers
//! exactly the surface the dynamix crate uses — `Result`, `Error`,
//! `anyhow!`, `bail!`, `ensure!`, and `?`-conversion from any
//! `std::error::Error` — with the same observable behaviour (message
//! formatting, source-chain rendering under `{:#}` and in converted errors).
//! If a registry ever becomes available, deleting `rust/vendor` and pointing
//! Cargo.toml at the real `anyhow = "1"` is a drop-in swap.

use std::fmt;

/// String-backed error value. Deliberately does NOT implement
/// `std::error::Error`, exactly like the real `anyhow::Error` — that is what
/// makes the blanket `From<E: std::error::Error>` impl below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }

    /// Attach context, mirroring `anyhow::Error::context` semantics
    /// (context first, original message behind it).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The real anyhow renders the cause chain under `{:#}`; the shim
        // flattens chains at conversion time, so both forms are the msg.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("fmt {args}")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt {args}")` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt {args}")` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn macros_and_conversion() {
        let e: Error = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        assert_eq!(format!("{e:#}"), "x = 7");
        assert_eq!(format!("{e:?}"), "x = 7");

        fn bails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 1)
        }
        assert_eq!(bails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(bails(true).unwrap_err().to_string(), "unreachable 1");

        let io = io_fail().unwrap_err().to_string();
        assert!(!io.is_empty());
    }
}
