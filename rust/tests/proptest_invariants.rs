//! Property-based tests over coordinator/substrate invariants.
//!
//! The offline build has no `proptest` crate, so this file carries a small
//! seeded property harness (`check`): each property runs hundreds of
//! randomized cases from a deterministic PRNG and reports the failing
//! case's seed+inputs on violation. Same discipline, zero deps.

use dynamix::cluster::{batch_fits, SimCluster};
use dynamix::comm::Msg;
use dynamix::config::{ClusterPreset, Topology};
use dynamix::data::ShardSampler;
use dynamix::metrics::ConvergenceDetector;
use dynamix::netsim::NetworkSim;
use dynamix::rl::action::{BatchRule, DELTAS, N_ACTIONS};
use dynamix::sim::elastic;
use dynamix::sim::engine::EventQueue;
use dynamix::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
use dynamix::rl::reward::{discounted_returns, RewardParams};
use dynamix::rl::state::{GlobalState, StateBuilder, StateVector};
use dynamix::rl::trajectory::{Trajectory, Transition, UpdateBatch};
use dynamix::sysmetrics::WindowSummary;
use dynamix::util::json::Json;
use dynamix::util::rng::Rng;

/// Run `cases` randomized checks; panic with the case index on failure.
fn check<F: FnMut(&mut Rng, usize)>(name: &str, cases: usize, mut f: F) {
    for case in 0..cases {
        let mut rng = Rng::new(0xBEEF ^ case as u64);
        f(&mut rng, case);
    }
    println!("property {name}: {cases} cases ok");
}

#[test]
fn prop_batch_rule_closed_under_any_action_sequence() {
    check("batch_rule_closed", 500, |rng, case| {
        let rule = BatchRule { min: 32, max: 1024 };
        let mut b = 32 + rng.below(993);
        for step in 0..100 {
            let a = rng.below(N_ACTIONS);
            let cap = if rng.uniform() < 0.3 {
                Some(32 + rng.below(1024))
            } else {
                None
            };
            b = rule.apply(b, a, cap);
            assert!(
                (rule.min..=rule.max).contains(&b),
                "case {case} step {step}: batch {b} escaped [{},{}]",
                rule.min,
                rule.max
            );
        }
    });
}

#[test]
fn prop_realized_delta_consistent_with_apply() {
    check("realized_delta", 300, |rng, case| {
        let rule = BatchRule { min: 32, max: 1024 };
        let b = 32 + rng.below(993);
        let a = rng.below(N_ACTIONS);
        let applied = rule.apply(b, a, None);
        let delta = rule.realized_delta(b, a, None);
        assert_eq!(applied as i64, b as i64 + delta as i64, "case {case}");
        // Realized delta never exceeds the commanded delta in magnitude.
        assert!(delta.abs() <= DELTAS[a].abs(), "case {case}");
    });
}

#[test]
fn prop_shards_always_disjoint_and_exact() {
    check("shards_disjoint", 60, |rng, case| {
        let n_workers = 1 + rng.below(8);
        let size = 64 + rng.below(1000);
        let draw = size / n_workers;
        if draw == 0 {
            return;
        }
        let mut seen = vec![0u8; size];
        for w in 0..n_workers {
            let mut s = ShardSampler::new(w, n_workers, size, case as u64);
            let mut idx = Vec::new();
            s.next_indices(draw, &mut idx);
            for &i in &idx {
                seen[i as usize] += 1;
            }
        }
        assert!(
            seen.iter().all(|&c| c <= 1),
            "case {case}: overlap with n={n_workers} size={size}"
        );
    });
}

#[test]
fn prop_sampler_epoch_is_permutation() {
    check("sampler_permutation", 40, |rng, case| {
        let size = 32 + rng.below(300);
        let mut s = ShardSampler::new(0, 1, size, case as u64);
        let mut idx = Vec::new();
        s.next_indices(size, &mut idx);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        let expect: Vec<u64> = (0..size as u64).collect();
        assert_eq!(sorted, expect, "case {case}");
    });
}

#[test]
fn prop_state_vector_always_bounded() {
    check("state_bounded", 400, |rng, _case| {
        let builder = StateBuilder {
            use_network_features: rng.uniform() < 0.8,
            use_grad_stats_features: rng.uniform() < 0.8,
            iter_time_ref: rng.uniform_range(1e-4, 10.0),
        };
        let w = WindowSummary {
            acc_mean: rng.uniform(),
            acc_std: rng.uniform(),
            acc_gain: rng.normal() * 100.0,
            iter_time_mean: rng.exponential(0.5),
            throughput_mean: rng.uniform_range(0.0, 100.0),
            retransmissions: rng.exponential(1e-4),
            cpu_time_ratio: rng.uniform_range(0.0, 64.0),
            mem_util: rng.uniform_range(0.0, 2.0),
            sigma_norm: rng.exponential(0.5),
            sigma_norm2: rng.exponential(0.5),
            loss_mean: rng.exponential(0.2),
            iters: 5,
        };
        let g = GlobalState {
            loss: rng.exponential(0.2),
            eval_acc: rng.uniform(),
            eval_trend: rng.normal(),
            progress: rng.uniform(),
            n_workers: 1 + rng.below(32),
        };
        let s = builder.build(&w, 32 + rng.below(993), &g);
        assert_eq!(s.0.len(), dynamix::rl::state::STATE_DIM);
        assert!(s.0.iter().all(|v| v.is_finite() && (-3.0..=3.0).contains(v)));
    });
}

#[test]
fn prop_reward_monotone_in_accuracy_and_time() {
    check("reward_monotone", 200, |rng, case| {
        let p = RewardParams {
            adaptive: rng.uniform() < 0.5,
            ..Default::default()
        };
        let base = WindowSummary {
            acc_mean: rng.uniform_range(0.1, 0.8),
            iter_time_mean: rng.uniform_range(0.01, 1.0),
            sigma_norm: rng.uniform(),
            sigma_norm2: rng.uniform(),
            ..Default::default()
        };
        let batch = 32 + rng.below(993);
        let r0 = p.compute(&base, batch);
        let mut better_acc = base;
        better_acc.acc_mean += 0.1;
        assert!(p.compute(&better_acc, batch) > r0, "case {case}: acc up, reward down");
        let mut slower = base;
        slower.iter_time_mean *= 2.0;
        assert!(p.compute(&slower, batch) < r0, "case {case}: slower, reward up");
    });
}

#[test]
fn prop_discounted_returns_bounds() {
    check("returns_bounds", 200, |rng, case| {
        let n = 1 + rng.below(50);
        let rewards: Vec<f64> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let gamma = rng.uniform();
        let g = discounted_returns(&rewards, gamma);
        assert_eq!(g.len(), n);
        // |G_t| <= max|r| / (1-gamma) (geometric bound).
        let rmax = rewards.iter().fold(0.0f64, |m, r| m.max(r.abs()));
        let bound = rmax / (1.0 - gamma).max(1e-9) + 1e-9;
        assert!(
            g.iter().all(|x| x.abs() <= bound),
            "case {case}: returns exceed geometric bound"
        );
        // Recurrence: G_t = r_t + gamma*G_{t+1}.
        for i in 0..n - 1 {
            assert!((g[i] - (rewards[i] + gamma * g[i + 1])).abs() < 1e-9, "case {case}");
        }
    });
}

#[test]
fn prop_gae_zero_when_value_equals_return() {
    // A perfect critic (values == discounted rewards-to-go) yields ~zero
    // advantages for any gamma with lambda=1.
    check("gae_perfect_critic", 100, |rng, case| {
        let n = 2 + rng.below(30);
        let rewards: Vec<f64> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let gamma = rng.uniform_range(0.5, 1.0);
        let returns = discounted_returns(&rewards, gamma);
        let mut t = Trajectory::default();
        for i in 0..n {
            t.push(Transition {
                state: StateVector(vec![0.0; 16]),
                action: 0,
                logp: -1.0,
                value: returns[i] as f32,
                reward: rewards[i],
            });
        }
        let (adv, _) = t.gae(gamma, 1.0);
        assert!(
            adv.iter().all(|a| a.abs() < 1e-3),
            "case {case}: nonzero advantage under perfect critic: {adv:?}"
        );
    });
}

#[test]
fn prop_update_batch_advantages_normalized() {
    check("adv_normalized", 100, |rng, case| {
        let n_trajs = 1 + rng.below(4);
        let mut trajs = Vec::new();
        for _ in 0..n_trajs {
            let mut t = Trajectory::default();
            for _ in 0..(2 + rng.below(20)) {
                t.push(Transition {
                    state: StateVector(vec![rng.normal() as f32; 16]),
                    action: rng.below(5),
                    logp: -1.6,
                    value: rng.normal() as f32,
                    reward: rng.normal(),
                });
            }
            trajs.push(t);
        }
        let b = UpdateBatch::from_trajectories(&trajs, 0.99, 0.95);
        if b.len() < 2 {
            return;
        }
        let mean: f32 = b.advantages.iter().sum::<f32>() / b.len() as f32;
        assert!(mean.abs() < 1e-3, "case {case}: adv mean {mean}");
    });
}

/// Random finite f32 vector (finite so equality survives the roundtrip).
fn rand_f32s(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    (0..rng.below(max_len + 1)).map(|_| rng.normal() as f32).collect()
}

/// A random message spanning every wire variant — control plane and the
/// shard-gradient data plane, including both Option branches of ShardStep
/// and the PROTO_VERSION 5 zero-plane slice frames. The compressed slice
/// variants go through the real codecs so the decoder's structural
/// validation (strict topk index monotonicity, count checks) accepts
/// them; hostile frames are covered by the truncation property and the
/// dedicated tests in `comm`.
fn random_wire_msg(rng: &mut Rng) -> Msg {
    match rng.below(18) {
        0 => Msg::Register { worker: rng.next_u64() as u32, max_batch: rng.next_u64() as u32 },
        1 => Msg::Welcome {
            worker: rng.next_u64() as u32,
            k: rng.next_u64() as u32,
            initial_batch: rng.next_u64() as u32,
            n_workers: 1 + rng.below(32) as u32,
            cycles: rng.next_u64() as u32,
        },
        2 => Msg::StateReport {
            worker: rng.next_u64() as u32,
            cycle: rng.next_u64() as u32,
            state: StateVector((0..16).map(|_| rng.normal() as f32).collect()),
            reward: rng.normal(),
            sim_clock: rng.exponential(0.01),
        },
        3 => Msg::Action {
            worker: rng.next_u64() as u32,
            cycle: rng.next_u64() as u32,
            delta: DELTAS[rng.below(5)],
            new_batch: 32 + rng.below(993) as u32,
        },
        4 => Msg::Barrier { cycle: rng.next_u64() as u32 },
        5 => Msg::Shutdown,
        6 => {
            let rows = if rng.uniform() < 0.5 {
                let m = rng.below(5);
                Some(dynamix::comm::ShardRows {
                    model: format!("model-{}", rng.below(100)),
                    x: (0..m * 4).map(|_| rng.normal() as f32).collect(),
                    y: (0..m).map(|_| rng.below(100) as i32).collect(),
                    mask: (0..m).map(|_| if rng.uniform() < 0.8 { 1.0 } else { 0.0 }).collect(),
                })
            } else {
                None
            };
            let params = if rng.uniform() < 0.5 { Some(rand_f32s(rng, 24)) } else { None };
            Msg::ShardStep {
                seq: rng.next_u64(),
                denom: 1.0 + rng.below(4096) as f32,
                train: rng.uniform() < 0.5,
                rows,
                params,
            }
        }
        7 => Msg::ShardFwd {
            seq: rng.next_u64(),
            loss_terms: rand_f32s(rng, 16),
            correct: rand_f32s(rng, 16),
        },
        8 => Msg::ShardGradSeed { seq: rng.next_u64(), grad: rand_f32s(rng, 48) },
        9 => Msg::ShardGradOut { seq: rng.next_u64(), grad: rand_f32s(rng, 48) },
        10 => Msg::ShardGradFin {
            seq: rng.next_u64(),
            loss: rng.normal() as f32,
            acc: rng.uniform() as f32,
            sigma_norm: rng.uniform() as f32,
            sigma_norm2: rng.uniform() as f32,
            grad_l2: rng.uniform() as f32,
            // Half the draws take the zero plane's barrier shape (empty
            // gradient, stats only in the v5 triple).
            grad: if rng.uniform() < 0.5 { Vec::new() } else { rand_f32s(rng, 48) },
        },
        11 => Msg::ShardErr {
            seq: rng.next_u64(),
            msg: format!("err-{}-\"quoted\"", rng.below(1000)),
        },
        12 => Msg::ShardGradBucket {
            seq: rng.next_u64(),
            bucket: rng.below(16) as u32,
            offset: rng.next_u64() % 100_000,
            grad: rand_f32s(rng, 48),
        },
        13 => Msg::ShardBucketFin {
            seq: rng.next_u64(),
            buckets: rng.below(64) as u32,
        },
        14 => Msg::ShardGradSlice {
            seq: rng.next_u64(),
            slice: rng.below(16) as u32,
            offset: rng.next_u64() % 100_000,
            grad: rand_f32s(rng, 48),
        },
        15 => {
            let x = rand_f32s(rng, 48);
            let (idx, val) = dynamix::comm::wire::topk_encode(&x);
            Msg::ShardGradTopK {
                seq: rng.next_u64(),
                slice: rng.below(16) as u32,
                offset: rng.next_u64() % 100_000,
                len: x.len() as u64,
                idx,
                val,
            }
        }
        16 => {
            let x = rand_f32s(rng, 48);
            let (scale, q) = dynamix::comm::wire::q8_encode(&x);
            Msg::ShardGradQ8 {
                seq: rng.next_u64(),
                slice: rng.below(16) as u32,
                offset: rng.next_u64() % 100_000,
                scale,
                q,
            }
        }
        _ => Msg::ShardParamSlice {
            seq: rng.next_u64(),
            slice: rng.below(16) as u32,
            offset: rng.next_u64() % 100_000,
            params: rand_f32s(rng, 48),
        },
    }
}

#[test]
fn prop_q8_codec_is_byte_stable_and_exact_on_decoded_values() {
    // The q8 scale is a power of two chosen so the quantized maximum
    // lands in [64, 127]: decode is exact (no rounding in q * 2^e), so a
    // second encode of the decoded vector reproduces the identical
    // (scale, bytes) — the leader can forward compressed frames verbatim
    // without decode/re-encode drift.
    check("q8_byte_stable", 400, |rng, case| {
        let x = rand_f32s(rng, 64);
        let (scale, q) = dynamix::comm::wire::q8_encode(&x);
        let decoded = dynamix::comm::wire::q8_decode(scale, &q).unwrap();
        let (scale2, q2) = dynamix::comm::wire::q8_encode(&decoded);
        assert_eq!(scale.to_bits(), scale2.to_bits(), "case {case}: scale moved");
        assert_eq!(q, q2, "case {case}: bytes moved");
        // And re-decode is a fixed point.
        let decoded2 = dynamix::comm::wire::q8_decode(scale2, &q2).unwrap();
        assert_eq!(
            decoded.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            decoded2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "case {case}: decode not a fixed point"
        );
    });
}

#[test]
fn prop_topk_indices_strictly_increasing_and_roundtrip_sparse() {
    check("topk_monotone", 400, |rng, case| {
        let x = rand_f32s(rng, 64);
        let (idx, val) = dynamix::comm::wire::topk_encode(&x);
        assert_eq!(idx.len(), dynamix::comm::wire::topk_k(x.len()), "case {case}");
        assert_eq!(idx.len(), val.len(), "case {case}");
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "case {case}: indices not strictly increasing: {idx:?}");
        }
        let decoded = dynamix::comm::wire::topk_decode(x.len(), &idx, &val).unwrap();
        assert_eq!(decoded.len(), x.len(), "case {case}");
        // Every kept coordinate survives bitwise; every dropped one is 0.
        let kept: std::collections::BTreeMap<u32, f32> =
            idx.iter().copied().zip(val.iter().copied()).collect();
        for (i, v) in decoded.iter().enumerate() {
            match kept.get(&(i as u32)) {
                Some(orig) => assert_eq!(v.to_bits(), orig.to_bits(), "case {case}: idx {i}"),
                None => assert_eq!(*v, 0.0, "case {case}: dropped idx {i} nonzero"),
            }
        }
    });
}

#[test]
fn prop_topk_partial_select_matches_full_sort_reference() {
    // The O(n) quickselect encoder must keep EXACTLY the set the
    // historical full sort kept, ties and all. Values are drawn from a
    // tiny magnitude alphabet so nearly every draw is riddled with
    // magnitude ties straddling the k cut — the case where an unstable
    // partition could legally differ from an unstable sort if the key
    // were not duplicate-free.
    check("topk_select_vs_sort", 400, |rng, case| {
        let len = 1 + rng.below(97);
        let x: Vec<f32> = (0..len)
            .map(|_| {
                let mag = [0.0f32, 1.0, 1.0, 2.0, 4.0][rng.below(5)];
                if rng.uniform() < 0.5 {
                    mag
                } else {
                    -mag
                }
            })
            .collect();
        let (idx, val) = dynamix::comm::wire::topk_encode(&x);
        let k = dynamix::comm::wire::topk_k(len);
        let mut order: Vec<u32> = (0..len as u32).collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(x[i as usize].abs().to_bits()), i));
        let mut ridx = order[..k].to_vec();
        ridx.sort_unstable();
        assert_eq!(idx, ridx, "case {case}: partial select kept a different set");
        for (&i, v) in ridx.iter().zip(&val) {
            assert_eq!(v.to_bits(), x[i as usize].to_bits(), "case {case}: idx {i}");
        }
    });
}

#[test]
fn prop_q8_dispatched_codec_matches_scalar_transliteration() {
    // Whatever tier `DYNAMIX_KERNEL` resolved for this process, the wire
    // bytes must equal the plain scalar loops (the CI kernel sweep runs
    // this under every tier, which is what pins the AVX2 lanes).
    check("q8_vs_scalar", 400, |rng, case| {
        let x: Vec<f32> = rand_f32s(rng, 70);
        let (scale, q) = dynamix::comm::wire::q8_encode(&x);
        let max_bits = x.iter().map(|v| v.abs().to_bits()).max().unwrap_or(0);
        let e = ((max_bits >> 23) & 0xFF) as i32 - 127;
        let (rs, rq): (f32, Vec<i8>) = if max_bits == 0 || !(-120..=127).contains(&e) {
            (0.0, vec![0; x.len()])
        } else {
            let s = f32::from_bits(((e - 6 + 127) as u32) << 23);
            (s, x.iter().map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8).collect())
        };
        assert_eq!(scale.to_bits(), rs.to_bits(), "case {case}: scale");
        assert_eq!(q, rq, "case {case}: bytes");
        let dec = dynamix::comm::wire::q8_decode(scale, &q).unwrap();
        for (i, (d, &b)) in dec.iter().zip(&q).enumerate() {
            assert_eq!(d.to_bits(), (b as f32 * scale).to_bits(), "case {case}: decode {i}");
        }
    });
}

#[test]
fn prop_wire_roundtrip_random_messages() {
    check("wire_roundtrip", 600, |rng, case| {
        let msg = random_wire_msg(rng);
        let frame = msg.encode();
        let len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
        assert_eq!(len + 4, frame.len(), "case {case}: bad length prefix");
        let decoded = Msg::decode(&frame[4..]).unwrap();
        assert_eq!(decoded, msg, "case {case}");
    });
}

#[test]
fn prop_wire_rejects_truncated_and_padded_frames() {
    // Fuzz-ish decoder hardening: EVERY strict prefix of a valid body must
    // error (never panic, never mis-decode a shorter message), and any
    // trailing garbage must be rejected. A successful decode consumes the
    // whole body, so a prefix that parsed fully would have failed the
    // original finish() — prefixes are guaranteed invalid; verify it.
    check("wire_truncation", 300, |rng, case| {
        let msg = random_wire_msg(rng);
        let frame = msg.encode();
        let body = &frame[4..];
        let cuts: Vec<usize> = if body.len() <= 32 {
            (0..body.len()).collect()
        } else {
            // Sample interior cuts + always test the boundary-ish ones.
            let mut c: Vec<usize> = (0..16).map(|_| rng.below(body.len())).collect();
            c.extend([0, 1, 2, 3, body.len() / 2, body.len() - 1]);
            c
        };
        for cut in cuts {
            assert!(
                Msg::decode(&body[..cut]).is_err(),
                "case {case}: truncation at {cut}/{} decoded",
                body.len()
            );
        }
        let mut padded = body.to_vec();
        padded.push(rng.below(256) as u8);
        assert!(
            Msg::decode(&padded).is_err(),
            "case {case}: trailing byte accepted"
        );
    });
}

#[test]
fn prop_netsim_time_positive_and_monotone_in_bytes() {
    check("netsim_monotone", 100, |rng, case| {
        let n = 2 + rng.below(31);
        let profs = dynamix::cluster::profiles(dynamix::config::ClusterPreset::OscA100, n, 0);
        let mut net = NetworkSim::new(case as u64);
        net.set_congestion_vol(0.0);
        net.retx_per_gib = 0.0; // isolate the deterministic cost model
        let small = rng.below(10 << 20) + 1;
        let big = small * 4;
        let topo = if rng.uniform() < 0.5 {
            Topology::RingAllReduce
        } else {
            Topology::ParameterServer { servers: 1 + rng.below(4) }
        };
        let t_small = net.sync(topo, &profs, small).time_s;
        let t_big = net.sync(topo, &profs, big).time_s;
        assert!(t_small > 0.0 && t_big > t_small, "case {case}: {t_small} !< {t_big}");
    });
}

#[test]
fn prop_convergence_detector_latch_is_stable() {
    check("detector_latch", 200, |rng, case| {
        let target = rng.uniform_range(0.3, 0.9);
        let mut d = ConvergenceDetector::new(target, 1 + rng.below(3));
        let mut latched_time = None;
        for i in 0..50 {
            let acc = rng.uniform();
            let t = i as f64;
            if let Some(ct) = d.observe(acc, t) {
                if let Some(prev) = latched_time {
                    assert_eq!(prev, ct, "case {case}: latch moved");
                }
                latched_time = Some(ct);
            }
        }
        if let Some(ct) = latched_time {
            assert!(d.converged());
            assert_eq!(d.time(), Some(ct));
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => Json::Str(format!("s{}-\"x\"\n{}", rng.below(100), rng.below(100))),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(5) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    check("json_roundtrip", 300, |rng, case| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "case {case}: {text}");
    });
}

#[test]
fn prop_event_queue_pops_in_nondecreasing_time_order() {
    check("event_queue_order", 300, |rng, case| {
        let mut q = EventQueue::new();
        let n = 1 + rng.below(60);
        for i in 0..n {
            // Coarse grid so duplicate timestamps are common (tie order).
            q.push((rng.below(20) as f64) * 0.5, i);
        }
        let mut popped: Vec<(f64, usize)> = Vec::new();
        let mut now = 0.0;
        while !q.is_empty() {
            now += rng.exponential(0.5);
            popped.extend(q.drain_due(now));
        }
        assert_eq!(popped.len(), n, "case {case}: events lost");
        for w in popped.windows(2) {
            assert!(
                w[0].0 <= w[1].0,
                "case {case}: pop times regressed: {} then {}",
                w[0].0,
                w[1].0
            );
            // FIFO among equal timestamps: insertion order == payload order.
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "case {case}: tie order broken");
            }
        }
    });
}

#[test]
fn prop_churn_preserves_batch_bounds_and_oom_rule() {
    // The trainer's elastic-membership path is exactly (SimCluster
    // membership + elastic::redistribute_freed/rejoin_batch + BatchRule);
    // drive that composition through arbitrary event sequences.
    check("churn_invariants", 40, |rng, case| {
        let n = 2 + rng.below(7);
        let mut cluster = SimCluster::new(ClusterPreset::FabricHetero, n, case as u64);
        // Param count chosen so the T4 profiles' memory ceiling actually
        // binds below 1024 — the OOM clamp is exercised, not vacuous.
        let pc = 200_000_000;
        let rule = BatchRule { min: 32, max: 1024 };
        let mut batches: Vec<usize> = (0..n)
            .map(|w| {
                let cap = cluster.max_batch(w, pc, 1024);
                rule.apply(32 + rng.below(993), 2, Some(cap))
            })
            .collect();
        for step in 0..80 {
            match rng.below(5) {
                0 => {
                    // Preempt (trainer refuses to empty the cluster).
                    let w = rng.below(n);
                    if cluster.is_active(w) && cluster.n_active() > 1 {
                        cluster.set_active(w, false);
                        let caps: Vec<usize> =
                            (0..n).map(|i| cluster.max_batch(i, pc, 1024)).collect();
                        let active = cluster.active_mask();
                        elastic::redistribute_freed(
                            batches[w],
                            &mut batches,
                            &active,
                            &caps,
                            1024,
                        );
                    }
                }
                1 => {
                    // Rejoin with a valid batch.
                    let w = rng.below(n);
                    if !cluster.is_active(w) {
                        cluster.set_active(w, true);
                        let cap = cluster.max_batch(w, pc, 1024);
                        batches[w] = elastic::rejoin_batch(batches[w], cap, 32, 1024);
                        assert!(
                            batches[w] == 32 || batch_fits(cluster.profile(w), pc, batches[w]),
                            "case {case} step {step}: rejoined w{w} violates OOM rule"
                        );
                    }
                }
                2 => {
                    // An RL action on a random active worker.
                    let w = rng.below(n);
                    if cluster.is_active(w) {
                        let cap = cluster.max_batch(w, pc, 1024);
                        batches[w] = rule.apply(batches[w], rng.below(N_ACTIONS), Some(cap));
                    }
                }
                3 => {
                    // Dynamics events never touch batch validity.
                    cluster.scale_speed(rng.below(n), rng.uniform_range(0.05, 2.0));
                    cluster.set_load_mean(rng.below(n), rng.uniform_range(0.0, 0.9));
                }
                _ => {
                    cluster.scale_bandwidth_all(rng.uniform_range(0.05, 2.0));
                    let out = cluster.compute_phase(&batches);
                    cluster.advance_iteration(&out, 0.001);
                }
            }
            assert!(cluster.n_active() >= 1, "case {case}: cluster emptied");
            for w in 0..n {
                if cluster.is_active(w) {
                    assert!(
                        (32..=1024).contains(&batches[w]),
                        "case {case} step {step}: w{w} batch {} escaped [32,1024]",
                        batches[w]
                    );
                    let cap = cluster.max_batch(w, pc, 1024);
                    assert!(
                        batches[w] <= cap.max(32),
                        "case {case} step {step}: w{w} batch {} above mem cap {cap}",
                        batches[w]
                    );
                }
            }
        }
    });
}

#[test]
fn prop_scenario_scripts_roundtrip_json() {
    fn random_event(rng: &mut Rng, n_workers: usize) -> ScenarioEvent {
        let grid = |rng: &mut Rng, lo: f64, hi: f64| {
            // Values on a coarse grid: exact f64 JSON round-trips without
            // depending on shortest-representation printing subtleties.
            let steps = 64.0;
            lo + (hi - lo) * (rng.below(steps as usize) as f64) / steps
        };
        match rng.below(7) {
            0 => ScenarioEvent::SlowdownWorker {
                worker: rng.below(n_workers),
                factor: grid(rng, 0.1, 2.0),
            },
            1 => ScenarioEvent::BandwidthDrop {
                factor: grid(rng, 0.1, 2.0),
            },
            2 => ScenarioEvent::CongestionStorm {
                level: grid(rng, 0.0, 0.9),
                duration_s: grid(rng, 0.1, 5.0),
            },
            3 => ScenarioEvent::CongestionRelax,
            4 => ScenarioEvent::PreemptWorker {
                worker: rng.below(n_workers),
            },
            5 => ScenarioEvent::RejoinWorker {
                worker: rng.below(n_workers),
            },
            _ => ScenarioEvent::LoadShift {
                worker: rng.below(n_workers),
                load_mean: grid(rng, 0.0, 0.95),
            },
        }
    }
    check("scenario_roundtrip", 200, |rng, case| {
        let n_workers = 1 + rng.below(16);
        let script = ScenarioScript {
            name: format!("prop-{case}"),
            events: (0..rng.below(12))
                .map(|_| TimedEvent {
                    at_s: (rng.below(400) as f64) * 0.25,
                    event: random_event(rng, n_workers),
                })
                .collect(),
        };
        script.validate(n_workers).unwrap();
        let text = script.to_json().to_string();
        let back = ScenarioScript::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, script, "case {case}: {text}");
    });
}

#[test]
fn prop_kernel_tiers_agree_on_random_shapes() {
    // Every executable tier (scalar / blocked / simd-where-supported) at
    // several thread counts, on randomized shapes and data: the
    // forward/input-grad kernels agree with the scalar reference within
    // float tolerance, and the reduce-sensitive weight-gradient kernel is
    // BITWISE identical (the sharded data plane's parity contract).
    use dynamix::runtime::native::exec::{KernelTier, Pool};
    use dynamix::runtime::native::linalg::{self, scalar};
    use dynamix::runtime::native::workspace::PanelCache;
    check("kernel_tiers_agree", 60, |rng, case| {
        let m = 1 + rng.below(40);
        let k = 1 + rng.below(48);
        let n = 1 + rng.below(40);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();

        let mut acc_ref = vec![0.0f32; m * n];
        scalar::matmul_acc(&x, &w, m, k, n, &mut acc_ref);
        let mut bt_ref = vec![0.0f32; m * k];
        scalar::matmul_bt(&dy, &w, m, k, n, &mut bt_ref);
        let mut at_ref = vec![0.0f32; k * n];
        scalar::matmul_at(&x, &dy, m, k, n, &mut at_ref);

        for tier in KernelTier::available() {
            for threads in [1usize, 3] {
                let pool = Pool::with_config(threads, tier);
                let tag = format!("case {case} {} t{threads} m{m}k{k}n{n}", tier.as_str());

                let mut acc = vec![0.0f32; m * n];
                linalg::matmul_acc(&pool, &x, &w, m, k, n, &mut acc);
                for (a, b) in acc.iter().zip(&acc_ref) {
                    assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{tag}: acc {a} vs {b}");
                }

                let mut panels = PanelCache::default();
                let mut bt = vec![0.0f32; m * k];
                linalg::matmul_bt_ws(&pool, &mut panels, 1, 0, &dy, &w, m, k, n, &mut bt);
                for (a, b) in bt.iter().zip(&bt_ref) {
                    assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{tag}: bt {a} vs {b}");
                }

                let mut at = vec![0.0f32; k * n];
                linalg::matmul_at(&pool, &x, &dy, m, k, n, &mut at);
                for (i, (a, b)) in at.iter().zip(&at_ref).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{tag}: at[{i}] must be bitwise ({a} vs {b})"
                    );
                }
            }
        }
    });
}
