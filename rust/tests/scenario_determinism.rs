//! Scenario determinism: identical seed + scenario script ⇒ identical
//! RunRecord traces (sim clock, batch trace, rewards) regardless of the
//! native backend's kernel thread count, and identical scripted timelines
//! between the RL policy and static baselines (the apples-to-apples
//! guarantee the dynamics experiment depends on).
//!
//! Thread counts are pinned via `NativeBackend::with_threads`, not the
//! environment, so these tests cannot race other tests over env vars.

use dynamix::baselines::{run_baseline, StaticPolicy};
use dynamix::config::ExperimentConfig;
use dynamix::coordinator::Coordinator;
use dynamix::metrics::RunRecord;
use dynamix::runtime::{Backend, NativeBackend};
use dynamix::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
use std::sync::Arc;

fn backend(threads: usize) -> Backend {
    Arc::new(NativeBackend::with_threads(threads))
}

/// Early-firing churn script: every event lands well inside the short sim
/// horizon of these tests, so every run applies the full timeline.
fn churn_script() -> ScenarioScript {
    use ScenarioEvent::*;
    let at = |at_s: f64, event: ScenarioEvent| TimedEvent { at_s, event };
    ScenarioScript {
        name: "det-churn".into(),
        events: vec![
            at(0.01, PreemptWorker { worker: 3 }),
            at(
                0.02,
                LoadShift {
                    worker: 0,
                    load_mean: 0.5,
                },
            ),
            at(0.03, BandwidthDrop { factor: 0.3 }),
            at(
                0.05,
                CongestionStorm {
                    level: 0.7,
                    duration_s: 0.05,
                },
            ),
            at(0.12, RejoinWorker { worker: 3 }),
        ],
    }
}

fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.cluster.n_workers = 4;
    c.batch.initial = 64;
    c.rl.k = 2;
    c.steps_per_episode = 4;
    c.train.max_steps = 100;
    c.scenario = Some(churn_script());
    c
}

/// Serialize everything the ISSUE's determinism contract covers: the
/// trace points (sim clock, accuracy, loss, batch stats), the scenario
/// annotations, and the applied-event log.
fn inference_fingerprint(threads: usize) -> (String, Vec<(f64, String)>) {
    let mut coord = Coordinator::new(cfg(), backend(threads)).unwrap();
    let mut record = RunRecord::new("det");
    coord.run_inference(4, &mut record).unwrap();
    (
        record.to_json().to_string(),
        coord.trainer.events_applied.clone(),
    )
}

#[test]
fn inference_trace_bitwise_identical_across_thread_counts() {
    let (r1, e1) = inference_fingerprint(1);
    let (r4, e4) = inference_fingerprint(4);
    assert_eq!(e1, e4, "applied-event logs diverged across thread counts");
    assert_eq!(r1, r4, "run records diverged across thread counts");
    assert!(!e1.is_empty(), "script never fired — test horizon too short");
    // The preemption actually happened (membership path exercised).
    assert!(e1.iter().any(|(_, d)| d.contains("preempt_worker")));
    assert!(e1.iter().any(|(_, d)| d.contains("rejoin_worker")));
}

#[test]
fn rl_training_rewards_identical_across_thread_counts() {
    let run = |threads: usize| {
        let mut coord = Coordinator::new(cfg(), backend(threads)).unwrap();
        let eps = coord.train_rl(1).unwrap();
        (
            eps[0].worker_returns.clone(),
            eps[0].sim_time,
            coord.trainer.events_applied.clone(),
        )
    };
    let (ret1, t1, ev1) = run(1);
    let (ret4, t4, ev4) = run(4);
    assert_eq!(ret1, ret4, "per-worker returns diverged");
    assert_eq!(t1, t4, "sim time diverged");
    assert_eq!(ev1, ev4, "event application diverged");
}

#[test]
fn policy_and_baseline_replay_the_identical_timeline() {
    // Same cfg + seed: the frozen-policy run and the static baseline must
    // carry bitwise-identical scenario timelines and applied-event logs —
    // the batch policies differ, the environment script must not.
    let mut coord = Coordinator::new(cfg(), backend(2)).unwrap();
    let mut rl_rec = RunRecord::new("rl");
    coord.run_inference(4, &mut rl_rec).unwrap();

    let mut base_rec = RunRecord::new("static");
    let mut pol = StaticPolicy(64);
    let trainer_events = {
        run_baseline(&cfg(), backend(2), &mut pol, 4, &mut base_rec).unwrap();
        // run_baseline annotates the record; compare through it.
        base_rec.extra.get("events_applied").unwrap().to_string()
    };

    let rl_timeline = rl_rec.extra.get("scenario_timeline").unwrap().to_string();
    let base_timeline = base_rec.extra.get("scenario_timeline").unwrap().to_string();
    assert_eq!(rl_timeline, base_timeline, "scripted timelines diverged");

    let rl_events = rl_rec.extra.get("events_applied").unwrap().to_string();
    assert_eq!(rl_events, trainer_events, "applied events diverged");
    assert!(rl_events.contains("preempt_worker"), "churn never fired");
}

#[test]
fn episode_resets_replay_the_script_identically() {
    // Two consecutive episodes under the same seed and script must apply
    // the same events at the same script times.
    let mut coord = Coordinator::new(cfg(), backend(1)).unwrap();
    let mut rec1 = RunRecord::new("ep1");
    coord.run_inference(3, &mut rec1).unwrap();
    let ev1 = coord.trainer.events_applied.clone();
    let mut rec2 = RunRecord::new("ep2");
    coord.run_inference(3, &mut rec2).unwrap();
    let ev2 = coord.trainer.events_applied.clone();
    assert_eq!(ev1, ev2, "rearm did not replay the script");
    assert_eq!(
        rec1.to_json().to_string().replace("ep1", "ep"),
        rec2.to_json().to_string().replace("ep2", "ep"),
        "episode traces diverged"
    );
}
