//! Bitwise-parity oracle for the sharded data plane.
//!
//! `ShardedBackend` (loopback, n ∈ {1,2,4,7}) must produce **bit-identical**
//! params, optimizer moments, loss, accuracy, per-row correctness and
//! gradient statistics to `NativeBackend` on the same fused batches —
//! across awkward fused-batch sizes (not divisible by n, batch < n,
//! single-example shards), both optimizers, both kernel thread counts
//! (1 and 4), mid-run shard preemption, and the TCP shard transport.

use dynamix::config::Optimizer;
use dynamix::runtime::sharded::transport::{ShardTransport, TcpShardTransport};
use dynamix::runtime::sharded::worker as shard_worker;
use dynamix::runtime::{
    ComputeBackend, KernelTier, NativeBackend, OptState, ShardedBackend, TrainOut,
};
use dynamix::util::rng::Rng;
use std::sync::Arc;

const MODEL: &str = "vgg11_mini";

/// Deterministic fused batch: `n_valid` random rows padded to `bucket`.
fn batch(bucket: usize, fd: usize, n_valid: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; bucket * fd];
    let mut y = vec![0i32; bucket];
    let mut mask = vec![0.0f32; bucket];
    for r in 0..n_valid {
        for v in &mut x[r * fd..(r + 1) * fd] {
            *v = rng.normal() as f32;
        }
        y[r] = rng.below(10) as i32;
        mask[r] = 1.0;
    }
    (x, y, mask)
}

/// Everything one train step produces, as comparable bits.
#[derive(Debug, PartialEq)]
struct StepBits {
    loss: u32,
    acc: u32,
    sigma_norm: u32,
    sigma_norm2: u32,
    grad_l2: u32,
    correct: Vec<u32>,
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run a sequence of train steps at the given valid-batch sizes plus one
/// eval, returning per-step outputs and the final optimizer state bits.
fn run_sequence(
    b: &dyn ComputeBackend,
    optimizer: Optimizer,
    valid_batches: &[usize],
) -> (Vec<StepBits>, Vec<u32>, Vec<u32>, Vec<u32>, (u32, u32)) {
    let fd = b.schema().feature_dim;
    let mut state = OptState::new(b.init_params(MODEL, 0).unwrap(), optimizer);
    let lr = match optimizer {
        Optimizer::Sgd => 0.05,
        Optimizer::Adam => 0.002,
    };
    let mut steps = Vec::new();
    let mut out = TrainOut::default();
    for (i, &nv) in valid_batches.iter().enumerate() {
        let bucket = b.schema().bucket_for(nv).unwrap();
        let (x, y, mask) = batch(bucket, fd, nv, 1000 + i as u64);
        b.train_step_into(MODEL, optimizer, bucket, &mut state, &x, &y, &mask, lr, &mut out)
            .unwrap();
        steps.push(StepBits {
            loss: out.loss.to_bits(),
            acc: out.acc.to_bits(),
            sigma_norm: out.sigma_norm.to_bits(),
            sigma_norm2: out.sigma_norm2.to_bits(),
            grad_l2: out.grad_l2.to_bits(),
            correct: bits(&out.correct),
        });
    }
    let (ex, ey, emask) = batch(96, fd, 96, 7777);
    let (el, ea) = b.eval_step(MODEL, &state.params, &ex, &ey, &emask).unwrap();
    (
        steps,
        bits(&state.params),
        bits(&state.m),
        bits(&state.v),
        (el.to_bits(), ea.to_bits()),
    )
}

/// Awkward valid-batch ladder: < 7 (some shards empty at n=7), exactly a
/// bucket, off-bucket (padding rows live), prime-ish, and one that leaves
/// single-example shards at n=7.
const BATCHES: &[usize] = &[5, 32, 103, 61, 7];

#[test]
fn loopback_matches_native_bitwise_for_all_shard_and_thread_counts() {
    for &threads in &[1usize, 4] {
        let native = NativeBackend::with_threads(threads);
        for optimizer in [Optimizer::Sgd, Optimizer::Adam] {
            let want = run_sequence(&native, optimizer, BATCHES);
            for &n in &[1usize, 2, 4, 7] {
                let sharded = ShardedBackend::loopback_with_threads(n, threads);
                let got = run_sequence(&sharded, optimizer, BATCHES);
                assert_eq!(
                    got, want,
                    "sharded(n={n}, threads={threads}, {optimizer:?}) diverged from native"
                );
            }
        }
    }
}

#[test]
fn single_example_shards_hold_parity() {
    // 31 shards on a 32-row bucket: almost every shard owns exactly one
    // sample — the degenerate end of the row-split spectrum.
    let native = NativeBackend::with_threads(1);
    let sharded = ShardedBackend::loopback_with_threads(31, 1);
    let want = run_sequence(&native, Optimizer::Sgd, &[32, 17]);
    let got = run_sequence(&sharded, Optimizer::Sgd, &[32, 17]);
    assert_eq!(got, want, "single-example shards diverged from native");
}

#[test]
fn every_kernel_tier_holds_sharded_parity_bitwise() {
    // The tier axis of the oracle, pinned in-process (the CI test leg
    // additionally sweeps DYNAMIX_KERNEL over the whole suite): for each
    // executable tier, the sharded data plane reproduces the native
    // backend bit for bit across shard counts and thread counts. Holds
    // because every tier preserves the sequential per-output-element row
    // fold on matmul_at / col_sums.
    for tier in KernelTier::available() {
        let native = NativeBackend::with_kernel(1, tier);
        let want = run_sequence(&native, Optimizer::Sgd, &[5, 32, 103]);
        // Native itself must be thread-stable per tier for the oracle to
        // compose across thread counts.
        let native_t4 = NativeBackend::with_kernel(4, tier);
        assert_eq!(
            run_sequence(&native_t4, Optimizer::Sgd, &[5, 32, 103]),
            want,
            "{tier:?}: native not thread-stable"
        );
        for (n, threads) in [(1usize, 4usize), (4, 1), (4, 4), (7, 2)] {
            let sharded = ShardedBackend::loopback_with_kernel(n, threads, tier);
            let got = run_sequence(&sharded, Optimizer::Sgd, &[5, 32, 103]);
            assert_eq!(
                got, want,
                "sharded(n={n}, threads={threads}, {tier:?}) diverged from native"
            );
        }
    }
}

#[test]
fn parity_holds_across_kernel_thread_counts() {
    // Transitivity check made explicit: the t=1 and t=4 oracles are
    // themselves bit-identical (PR 2's guarantee), so the sharded planes
    // above all agree with each other too.
    let a = run_sequence(&NativeBackend::with_threads(1), Optimizer::Sgd, BATCHES);
    let b = run_sequence(&NativeBackend::with_threads(4), Optimizer::Sgd, BATCHES);
    assert_eq!(a, b, "native must be thread-count stable for the oracle to compose");
}

#[test]
fn preemption_mid_run_does_not_perturb_the_math() {
    // Drop a shard (its rows redistribute across survivors), step, revive
    // it, step again: every output stays bit-identical to the native
    // backend, which never had shards to lose.
    let native = NativeBackend::with_threads(1);
    let sharded = ShardedBackend::loopback_with_threads(4, 1);
    let fd = native.schema().feature_dim;
    let mut ns = OptState::new(native.init_params(MODEL, 0).unwrap(), Optimizer::Sgd);
    let mut ss = OptState::new(sharded.init_params(MODEL, 0).unwrap(), Optimizer::Sgd);
    let mut no = TrainOut::default();
    let mut so = TrainOut::default();
    let plan: &[(usize, Option<(usize, bool)>)] = &[
        (96, None),
        (96, Some((2, false))), // preempt shard 2 before this step
        (103, None),
        (103, Some((2, true))), // rejoin
        (64, Some((0, false))),
        (64, None),
    ];
    for (i, &(nv, membership)) in plan.iter().enumerate() {
        if let Some((shard, active)) = membership {
            assert!(sharded.set_shard_active(shard, active));
        }
        let bucket = native.schema().bucket_for(nv).unwrap();
        let (x, y, mask) = batch(bucket, fd, nv, 5000 + i as u64);
        native
            .train_step_into(MODEL, Optimizer::Sgd, bucket, &mut ns, &x, &y, &mask, 0.05, &mut no)
            .unwrap();
        sharded
            .train_step_into(MODEL, Optimizer::Sgd, bucket, &mut ss, &x, &y, &mask, 0.05, &mut so)
            .unwrap();
        assert_eq!(no.loss.to_bits(), so.loss.to_bits(), "step {i}: loss diverged");
        assert_eq!(no.grad_l2.to_bits(), so.grad_l2.to_bits(), "step {i}: grad_l2 diverged");
        assert_eq!(bits(&no.correct), bits(&so.correct), "step {i}: correct diverged");
        assert_eq!(bits(&ns.params), bits(&ss.params), "step {i}: params diverged");
        assert_eq!(bits(&ns.m), bits(&ss.m), "step {i}: momentum diverged");
    }
}

#[test]
fn all_zoo_models_hold_parity_on_one_step() {
    let native = NativeBackend::with_threads(1);
    let sharded = ShardedBackend::loopback_with_threads(3, 1);
    let mut rng = Rng::new(11);
    for (name, info) in native.schema().models.clone() {
        let fd = info.feature_dim;
        let nv = 50usize;
        let bucket = native.schema().bucket_for(nv).unwrap();
        let mut x = vec![0.0f32; bucket * fd];
        let mut y = vec![0i32; bucket];
        let mut mask = vec![0.0f32; bucket];
        for r in 0..nv {
            for v in &mut x[r * fd..(r + 1) * fd] {
                *v = rng.normal() as f32;
            }
            y[r] = rng.below(info.num_classes) as i32;
            mask[r] = 1.0;
        }
        let mut ns = OptState::new(native.init_params(&name, 3).unwrap(), Optimizer::Adam);
        let mut ss = OptState::new(sharded.init_params(&name, 3).unwrap(), Optimizer::Adam);
        let a = native
            .train_step(&name, Optimizer::Adam, bucket, &mut ns, &x, &y, &mask, 0.002)
            .unwrap();
        let b = sharded
            .train_step(&name, Optimizer::Adam, bucket, &mut ss, &x, &y, &mask, 0.002)
            .unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{name}: loss diverged");
        assert_eq!(bits(&ns.params), bits(&ss.params), "{name}: params diverged");
        assert_eq!(bits(&ns.v), bits(&ss.v), "{name}: adam v diverged");
    }
}

#[test]
fn tcp_transport_matches_native_bitwise() {
    // The same protocol over real sockets + the comm::wire codec: two
    // shard-server processes' worth of state behind TCP transports.
    use std::net::TcpListener;
    let mut handles = Vec::new();
    let mut links: Vec<Box<dyn ShardTransport>> = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        handles.push(std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpShardTransport::new(
                dynamix::comm::TcpTransport::new(stream).unwrap(),
            );
            shard_worker::serve(t, Arc::new(NativeBackend::with_threads(1))).unwrap();
        }));
        let stream = std::net::TcpStream::connect(addr).unwrap();
        links.push(Box::new(TcpShardTransport::new(
            dynamix::comm::TcpTransport::new(stream).unwrap(),
        )));
    }
    let sharded =
        ShardedBackend::over_transports(Arc::new(NativeBackend::with_threads(1)), links).unwrap();
    let native = NativeBackend::with_threads(1);
    let want = run_sequence(&native, Optimizer::Sgd, &[33, 64]);
    let got = run_sequence(&sharded, Optimizer::Sgd, &[33, 64]);
    assert_eq!(got, want, "TCP shard transport diverged from native");
    drop(sharded); // sends Shutdown over the sockets
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn end_to_end_inference_runs_are_identical() {
    // Full-stack determinism: a frozen-policy inference run (trainer +
    // coordinator + RL agent + simulators) records the exact same JSON on
    // the sharded data plane as on the native backend.
    use dynamix::config::ExperimentConfig;
    use dynamix::coordinator::Coordinator;
    use dynamix::metrics::RunRecord;
    use dynamix::runtime::Backend;
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.n_workers = 4;
    cfg.batch.initial = 64;
    cfg.rl.k = 2;
    cfg.steps_per_episode = 3;
    cfg.train.max_steps = 60;
    let run = |backend: Backend| {
        let mut c = Coordinator::new(cfg.clone(), backend).unwrap();
        let mut record = RunRecord::new("parity-e2e");
        c.run_inference(3, &mut record).unwrap();
        record.to_json().to_string()
    };
    let native = run(dynamix::runtime::native_backend());
    let sharded = run(Arc::new(ShardedBackend::loopback_with_threads(4, 1)));
    // The sharded record additionally carries the data_plane annotation;
    // strip it before comparing the trajectories byte for byte.
    let strip = |s: &str| {
        let j = dynamix::util::json::Json::parse(s).unwrap();
        match j {
            dynamix::util::json::Json::Obj(mut m) => {
                m.remove("data_plane");
                dynamix::util::json::Json::Obj(m).to_string()
            }
            other => other.to_string(),
        }
    };
    assert_eq!(
        strip(&native),
        strip(&sharded),
        "end-to-end inference diverged between native and sharded"
    );
}
