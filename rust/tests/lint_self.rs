//! End-to-end coverage for `dynamix-lint`: the committed tree must scan
//! clean with the full rule catalogue, every rule must prove it still
//! fires via its embedded known-bad fixture, and the suppression
//! semantics (justification required; invalid allows never suppress)
//! must hold.

use dynamix::util::lint;

/// The real tree, as committed, carries zero violations — this is the
/// same check the blocking CI leg runs via `make lint`.
#[test]
fn committed_tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let (violations, files) = lint::scan_tree(root).expect("tree scan");
    assert!(
        files >= 40,
        "suspiciously few files scanned ({files}) — did the walk break?"
    );
    let rendered: Vec<String> = violations.iter().map(|v| v.render()).collect();
    assert!(
        violations.is_empty(),
        "committed tree has lint violations:\n{}",
        rendered.join("\n")
    );
}

/// Every rule fires exactly once on its known-bad fixture and stays
/// silent on the known-good variant.
#[test]
fn every_rule_fires_on_its_fixture() {
    let fails = lint::self_test();
    assert!(fails.is_empty(), "self-test failures:\n{}", fails.join("\n"));
}

/// An allow without a justification is itself flagged AND does not
/// suppress the underlying finding; adding the justification clears both.
#[test]
fn suppression_requires_justification() {
    let bare = "fn f() { let v = std::env::var(\"X\").ok(); } // lint:allow(env-read)\n";
    let vs = lint::scan_source("src/trainer/x.rs", bare);
    let rules: Vec<&str> = vs.iter().map(|v| v.rule).collect();
    assert!(rules.contains(&"suppression"), "{rules:?}");
    assert!(rules.contains(&"env-read"), "unjustified allow must not suppress: {rules:?}");

    let justified =
        "fn f() { let v = std::env::var(\"X\").ok(); } // lint:allow(env-read): test fixture needs the raw value.\n";
    assert!(lint::scan_source("src/trainer/x.rs", justified).is_empty());
}

/// An allow naming a rule that does not exist is flagged and ignored.
#[test]
fn unknown_rule_in_allow_is_flagged() {
    let src =
        "fn f() { let v = std::env::var(\"X\").ok(); } // lint:allow(no-such-rule): reasons.\n";
    let vs = lint::scan_source("src/trainer/x.rs", src);
    let rules: Vec<&str> = vs.iter().map(|v| v.rule).collect();
    assert!(rules.contains(&"suppression"), "{rules:?}");
    assert!(rules.contains(&"env-read"), "{rules:?}");
}

/// `--format json` output is valid JSON with the expected shape.
#[test]
fn json_report_shape() {
    use dynamix::util::json::Json;
    let vs = lint::scan_source(
        "src/sim/x.rs",
        "fn now() -> std::time::Instant { std::time::Instant::now() }\n",
    );
    assert_eq!(vs.len(), 1);
    let report = lint::report_json(&vs, 1);
    let parsed = Json::parse(&report).expect("report is valid JSON");
    assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(parsed.get("files_scanned").and_then(Json::as_usize), Some(1));
    let items = parsed.get("violations").and_then(Json::as_arr).expect("violations array");
    assert_eq!(items.len(), 1);
    assert_eq!(items[0].get("rule").and_then(Json::as_str), Some("wall-clock"));
    assert_eq!(items[0].get("file").and_then(Json::as_str), Some("src/sim/x.rs"));
    assert_eq!(items[0].get("line").and_then(Json::as_usize), Some(1));

    let clean = lint::report_json(&[], 42);
    let parsed = Json::parse(&clean).unwrap();
    assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
}
