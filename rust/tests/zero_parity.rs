//! Parity + determinism oracle for the **ZeRO plane** (reduce-scatter →
//! per-owner optimizer slice → all-gather) introduced in PR 8.
//!
//! Contract under test, exactly as documented in `runtime/sharded`:
//!
//! * **dense** wire: the zero plane is *bitwise identical* to the
//!   full-replica ring and to the fused native backend — same travel
//!   plan, same fold order, only the optimizer-application grouping and
//!   the accounting differ. Checked across shard counts (including the
//!   n = 1 and eval bypasses), bucket plans, kernel tiers, overlap
//!   on/off, and mid-run preemption.
//! * **topk/q8** wire: bit parity with the fused step is deliberately
//!   traded for wire bytes, but the codecs are deterministic — two fresh
//!   backends replay the identical bit sequence — and training still
//!   converges on a repeated batch.
//!
//! Every backend here pins plane and wire through the builders, never the
//! environment: CI sweeps `DYNAMIX_PLANE`/`DYNAMIX_WIRE` across whole
//! test binaries and these oracles must hold under any ambient setting.

use dynamix::comm::wire::WireMode;
use dynamix::config::Optimizer;
use dynamix::runtime::{
    ComputeBackend, KernelTier, NativeBackend, OptState, Plane, ShardedBackend, TrainOut,
};
use dynamix::util::rng::Rng;

/// Bucket-plan targets: finest (one bucket per completion stage), ~two
/// dense layers per bucket, and the whole-model single bucket.
const PLANS: &[usize] = &[0, 40 << 10, 1 << 30];

/// Awkward valid-batch ladder (see `overlap_parity`): empty shards at
/// n = 7, exact bucket, live padding rows, single-example shards.
const BATCHES: &[usize] = &[5, 32, 103, 61, 7];

fn batch(bucket: usize, fd: usize, n_valid: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; bucket * fd];
    let mut y = vec![0i32; bucket];
    let mut mask = vec![0.0f32; bucket];
    for r in 0..n_valid {
        for v in &mut x[r * fd..(r + 1) * fd] {
            *v = rng.normal() as f32;
        }
        y[r] = rng.below(10) as i32;
        mask[r] = 1.0;
    }
    (x, y, mask)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Multi-step Adam train sequence reduced to comparable bits (losses,
/// accuracies, per-example corrects, final params + second moments).
fn run_sequence(
    b: &dyn ComputeBackend,
    model: &str,
    valid_batches: &[usize],
) -> (Vec<(u32, u32, u32, Vec<u32>)>, Vec<u32>, Vec<u32>) {
    let fd = b.schema().feature_dim;
    let mut state = OptState::new(b.init_params(model, 0).unwrap(), Optimizer::Adam);
    let mut steps = Vec::new();
    let mut out = TrainOut::default();
    for (i, &nv) in valid_batches.iter().enumerate() {
        let bucket = b.schema().bucket_for(nv).unwrap();
        let (x, y, mask) = batch(bucket, fd, nv, 4_400 + i as u64);
        b.train_step_into(model, Optimizer::Adam, bucket, &mut state, &x, &y, &mask, 0.002, &mut out)
            .unwrap();
        steps.push((
            out.loss.to_bits(),
            out.acc.to_bits(),
            out.grad_l2.to_bits(),
            bits(&out.correct),
        ));
    }
    (steps, bits(&state.params), bits(&state.v))
}

fn zero(n: usize, wire: WireMode, overlap: bool, target: usize) -> ShardedBackend {
    ShardedBackend::loopback_with_threads(n, 1)
        .with_overlap(overlap, target)
        .with_plane(Plane::Zero)
        .with_wire(wire)
}

#[test]
fn zero_dense_equals_replica_equals_native_across_plans_and_shards() {
    for model in ["vgg11_mini", "resnet34_mini"] {
        let native = NativeBackend::with_threads(1);
        let want = run_sequence(&native, model, BATCHES);
        for &target in PLANS {
            for n in [1usize, 2, 4, 7] {
                for overlap in [false, true] {
                    let zb = zero(n, WireMode::Dense, overlap, target);
                    assert_eq!(
                        run_sequence(&zb, model, BATCHES),
                        want,
                        "{model}: zero/dense (n={n}, overlap={overlap}, \
                         bucket_bytes={target}) diverged from native"
                    );
                }
                let replica = ShardedBackend::loopback_with_threads(n, 1)
                    .with_overlap(true, target)
                    .with_plane(Plane::Replica);
                assert_eq!(
                    run_sequence(&replica, model, BATCHES),
                    want,
                    "{model}: replica ring (n={n}, bucket_bytes={target}) diverged"
                );
            }
        }
    }
}

#[test]
fn zero_dense_parity_holds_per_kernel_tier() {
    for tier in KernelTier::available() {
        let native = NativeBackend::with_kernel(1, tier);
        let want = run_sequence(&native, "vgg11_mini", &[5, 32, 103]);
        let zb = ShardedBackend::loopback_with_kernel(4, 1, tier)
            .with_overlap(true, 40 << 10)
            .with_plane(Plane::Zero)
            .with_wire(WireMode::Dense);
        assert_eq!(
            run_sequence(&zb, "vgg11_mini", &[5, 32, 103]),
            want,
            "zero/dense ({tier:?}) diverged from native"
        );
    }
}

#[test]
fn zero_dense_survives_preemption_mid_run() {
    // Membership churn re-partitions parameter ownership (the freed
    // slice redistributes to survivors), but dense-wire outputs must
    // stay bit-identical to native throughout: ownership only groups
    // optimizer application, it never reorders a fold.
    let native = NativeBackend::with_threads(1);
    let sharded = zero(4, WireMode::Dense, true, 0);
    let fd = native.schema().feature_dim;
    let mut ns = OptState::new(native.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
    let mut ss = OptState::new(sharded.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
    let mut no = TrainOut::default();
    let mut so = TrainOut::default();
    let plan: &[(usize, Option<(usize, bool)>)] = &[
        (96, None),
        (96, Some((1, false))),
        (103, None),
        (103, Some((1, true))),
        (64, None),
    ];
    for (i, &(nv, membership)) in plan.iter().enumerate() {
        if let Some((shard, active)) = membership {
            assert!(sharded.set_shard_active(shard, active));
        }
        let bucket = native.schema().bucket_for(nv).unwrap();
        let (x, y, mask) = batch(bucket, fd, nv, 8_800 + i as u64);
        native
            .train_step_into("vgg11_mini", Optimizer::Sgd, bucket, &mut ns, &x, &y, &mask, 0.05, &mut no)
            .unwrap();
        sharded
            .train_step_into("vgg11_mini", Optimizer::Sgd, bucket, &mut ss, &x, &y, &mask, 0.05, &mut so)
            .unwrap();
        assert_eq!(no.loss.to_bits(), so.loss.to_bits(), "step {i}: loss diverged");
        assert_eq!(bits(&ns.params), bits(&ss.params), "step {i}: params diverged");
    }
}

#[test]
fn compressed_wire_is_run_to_run_deterministic() {
    // topk/q8 drop bit parity with the fused step by design; what they
    // must never drop is determinism. Two fresh backends with identical
    // inputs replay the identical bit sequence — the codecs have no
    // hidden iteration-order or floating-environment dependence.
    for wire in [WireMode::TopK, WireMode::Q8] {
        for n in [2usize, 4, 7] {
            let a = run_sequence(&zero(n, wire, true, 40 << 10), "vgg11_mini", BATCHES);
            let b = run_sequence(&zero(n, wire, true, 40 << 10), "vgg11_mini", BATCHES);
            assert_eq!(a, b, "zero/{wire:?} (n={n}) is not run-to-run deterministic");
        }
    }
}

#[test]
fn compressed_wire_still_converges_on_a_repeated_batch() {
    // Lossy codecs must remain usable: six Adam steps on one repeated
    // batch strictly reduce the loss below the first step's.
    for wire in [WireMode::TopK, WireMode::Q8] {
        let b = zero(4, wire, true, 40 << 10);
        let fd = b.schema().feature_dim;
        let mut state = OptState::new(b.init_params("vgg11_mini", 0).unwrap(), Optimizer::Adam);
        let mut out = TrainOut::default();
        let bucket = b.schema().bucket_for(64).unwrap();
        let (x, y, mask) = batch(bucket, fd, 64, 777);
        let mut losses = Vec::new();
        for _ in 0..6 {
            b.train_step_into(
                "vgg11_mini", Optimizer::Adam, bucket, &mut state, &x, &y, &mask, 0.002, &mut out,
            )
            .unwrap();
            losses.push(out.loss);
        }
        let first = losses[0];
        let min = losses.iter().copied().fold(f32::INFINITY, f32::min);
        assert!(
            min < first,
            "zero/{wire:?}: loss never improved over 6 repeated steps ({losses:?})"
        );
    }
}

#[test]
fn single_shard_and_eval_steps_bypass_the_zero_exchange() {
    // n = 1 has nothing to scatter (the bulk path runs, compressed or
    // not); eval steps never touch a gradient. Both must match native
    // bitwise even under a compressed wire setting.
    let native = NativeBackend::with_threads(1);
    for wire in [WireMode::Dense, WireMode::TopK, WireMode::Q8] {
        let single = zero(1, wire, true, 0);
        assert_eq!(
            run_sequence(&single, "vgg11_mini", &[32, 7]),
            run_sequence(&native, "vgg11_mini", &[32, 7]),
            "n=1 zero/{wire:?} diverged"
        );
    }
    let fd = native.schema().feature_dim;
    let params = native.init_params("vgg11_mini", 0).unwrap();
    let (x, y, mask) = batch(96, fd, 96, 31);
    let multi = zero(3, WireMode::Q8, true, 0);
    let (nl, na) = native.eval_step("vgg11_mini", &params, &x, &y, &mask).unwrap();
    let (sl, sa) = multi.eval_step("vgg11_mini", &params, &x, &y, &mask).unwrap();
    assert_eq!((nl.to_bits(), na.to_bits()), (sl.to_bits(), sa.to_bits()));
}
