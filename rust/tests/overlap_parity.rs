//! Bitwise-parity oracle for the **overlapped** (pipelined bucket ring)
//! backward: `DYNAMIX_OVERLAP=on` ≡ `off` (bulk ring) ≡ native fused, to
//! the last bit, across bucket plans (one bucket per completion stage,
//! ~two-layer buckets, whole-model), kernel tiers × thread counts,
//! awkward fused batches (including empty shards at n = 7), and both
//! model families (the ResNet plan merges residual blocks across the
//! stem/head adjacency; the VGG head bucket is never mergeable).
//!
//! The overlap changes the *schedule* — bucket `k` hops the ring while
//! stage `k+1` is still folding — but not one arithmetic operation: seeds
//! arrive before folds, stages fold in completion order, and every
//! per-element row fold replays the fused sequence. These tests are the
//! machine check of that claim.

use dynamix::config::Optimizer;
use dynamix::runtime::{
    ComputeBackend, KernelTier, NativeBackend, OptState, ShardedBackend, TrainOut,
};
use dynamix::util::rng::Rng;

/// Bucket-plan targets swept by the oracle: 0 = one bucket per completion
/// stage (finest), 40 KiB ≈ two dense layers per bucket, 1 GiB = the
/// whole-model single bucket (the degenerate plan that reduces the
/// pipeline to a bulk ring with bucket framing).
const PLANS: &[usize] = &[0, 40 << 10, 1 << 30];

/// Awkward valid-batch ladder (as in `sharded_parity`): < 7 rows leaves
/// empty shards at n = 7, 32 is exactly a bucket, 103/61 exercise live
/// padding rows, 7 gives single-example shards.
const BATCHES: &[usize] = &[5, 32, 103, 61, 7];

fn batch(bucket: usize, fd: usize, n_valid: usize, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; bucket * fd];
    let mut y = vec![0i32; bucket];
    let mut mask = vec![0.0f32; bucket];
    for r in 0..n_valid {
        for v in &mut x[r * fd..(r + 1) * fd] {
            *v = rng.normal() as f32;
        }
        y[r] = rng.below(10) as i32;
        mask[r] = 1.0;
    }
    (x, y, mask)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Multi-step train sequence + one eval, reduced to comparable bits.
fn run_sequence(
    b: &dyn ComputeBackend,
    model: &str,
    valid_batches: &[usize],
) -> (Vec<(u32, u32, u32, Vec<u32>)>, Vec<u32>, Vec<u32>) {
    let fd = b.schema().feature_dim;
    let mut state = OptState::new(b.init_params(model, 0).unwrap(), Optimizer::Adam);
    let mut steps = Vec::new();
    let mut out = TrainOut::default();
    for (i, &nv) in valid_batches.iter().enumerate() {
        let bucket = b.schema().bucket_for(nv).unwrap();
        let (x, y, mask) = batch(bucket, fd, nv, 4_400 + i as u64);
        b.train_step_into(model, Optimizer::Adam, bucket, &mut state, &x, &y, &mask, 0.002, &mut out)
            .unwrap();
        steps.push((
            out.loss.to_bits(),
            out.acc.to_bits(),
            out.grad_l2.to_bits(),
            bits(&out.correct),
        ));
    }
    (steps, bits(&state.params), bits(&state.v))
}

#[test]
fn overlapped_equals_bulk_equals_native_across_bucket_plans() {
    for model in ["vgg11_mini", "resnet34_mini"] {
        let native = NativeBackend::with_threads(1);
        let want = run_sequence(&native, model, BATCHES);
        let bulk = ShardedBackend::loopback_with_threads(4, 1).with_overlap(false, 0);
        assert_eq!(
            run_sequence(&bulk, model, BATCHES),
            want,
            "{model}: bulk ring diverged from native"
        );
        for &target in PLANS {
            for n in [2usize, 4, 7] {
                let overlapped =
                    ShardedBackend::loopback_with_threads(n, 1).with_overlap(true, target);
                assert_eq!(
                    run_sequence(&overlapped, model, BATCHES),
                    want,
                    "{model}: overlapped ring (n={n}, bucket_bytes={target}) diverged"
                );
            }
        }
    }
}

#[test]
fn overlapped_parity_holds_per_kernel_tier_and_thread_count() {
    for tier in KernelTier::available() {
        for threads in [1usize, 4] {
            let native = NativeBackend::with_kernel(threads, tier);
            let want = run_sequence(&native, "vgg11_mini", &[5, 32, 103]);
            let overlapped = ShardedBackend::loopback_with_kernel(4, threads, tier)
                .with_overlap(true, 40 << 10);
            assert_eq!(
                run_sequence(&overlapped, "vgg11_mini", &[5, 32, 103]),
                want,
                "overlapped ring ({tier:?}, threads={threads}) diverged from native"
            );
        }
    }
}

#[test]
fn overlap_survives_preemption_mid_run() {
    // Membership churn under the pipelined ring: drop a shard, step,
    // revive, step — every output stays bit-identical to native. The
    // surviving ring is shorter but folds the identical row sequence.
    let native = NativeBackend::with_threads(1);
    let sharded = ShardedBackend::loopback_with_threads(4, 1).with_overlap(true, 0);
    let fd = native.schema().feature_dim;
    let mut ns = OptState::new(native.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
    let mut ss = OptState::new(sharded.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
    let mut no = TrainOut::default();
    let mut so = TrainOut::default();
    let plan: &[(usize, Option<(usize, bool)>)] = &[
        (96, None),
        (96, Some((1, false))),
        (103, None),
        (103, Some((1, true))),
        (64, None),
    ];
    for (i, &(nv, membership)) in plan.iter().enumerate() {
        if let Some((shard, active)) = membership {
            assert!(sharded.set_shard_active(shard, active));
        }
        let bucket = native.schema().bucket_for(nv).unwrap();
        let (x, y, mask) = batch(bucket, fd, nv, 8_800 + i as u64);
        native
            .train_step_into("vgg11_mini", Optimizer::Sgd, bucket, &mut ns, &x, &y, &mask, 0.05, &mut no)
            .unwrap();
        sharded
            .train_step_into("vgg11_mini", Optimizer::Sgd, bucket, &mut ss, &x, &y, &mask, 0.05, &mut so)
            .unwrap();
        assert_eq!(no.loss.to_bits(), so.loss.to_bits(), "step {i}: loss diverged");
        assert_eq!(bits(&ns.params), bits(&ss.params), "step {i}: params diverged");
    }
}

#[test]
fn single_shard_and_eval_steps_bypass_the_pipeline() {
    // n = 1 has no ring to pipeline; eval steps never reduce a gradient.
    // Both must work unchanged with overlap enabled.
    let native = NativeBackend::with_threads(1);
    let sharded = ShardedBackend::loopback_with_threads(1, 1).with_overlap(true, 0);
    let want = run_sequence(&native, "vgg11_mini", &[32, 7]);
    assert_eq!(
        run_sequence(&sharded, "vgg11_mini", &[32, 7]),
        want,
        "n=1 with overlap enabled diverged"
    );
    let fd = native.schema().feature_dim;
    let params = native.init_params("vgg11_mini", 0).unwrap();
    let (x, y, mask) = batch(96, fd, 96, 31);
    let multi = ShardedBackend::loopback_with_threads(3, 1).with_overlap(true, 0);
    let (nl, na) = native.eval_step("vgg11_mini", &params, &x, &y, &mask).unwrap();
    let (sl, sa) = multi.eval_step("vgg11_mini", &params, &x, &y, &mask).unwrap();
    assert_eq!((nl.to_bits(), na.to_bits()), (sl.to_bits(), sa.to_bits()));
}
