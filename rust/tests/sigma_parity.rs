//! Regression oracle for the **zero-plane sigma-stat blackout** (fixed by
//! wire protocol v5).
//!
//! The bug: on the deployed TCP zero plane the step barrier is an
//! empty-gradient `ShardGradFin` (the reduced gradient travels slice-wise
//! and never reassembles worker-side), so workers had nothing to derive
//! their sigma-stat RL features from and silently pushed 0.0 into the
//! window aggregator — the policy saw a different (blacked-out) state
//! distribution on the zero plane than on the replica plane for the SAME
//! training run. Since v5 the fin carries the leader-computed normalized
//! gradient-moment triple on BOTH planes and the worker consumes the
//! carried values instead of deriving its own.
//!
//! The oracle drives the REAL [`dynamix::comm::leader::worker`] loop over
//! a REAL TCP socket, with the test acting as the leader (n = 1 makes the
//! ring protocol exact and small), once per plane with the identical
//! preset/seed. The worker's `StateReport` vector must have nonzero
//! sigma features, bitwise identical across planes.
//!
//! Single `#[test]`: the worker reads `DYNAMIX_PLANE`/`DYNAMIX_WIRE` from
//! the environment at startup, so this binary pins both and must not run
//! concurrent env-sensitive tests.

use dynamix::comm::{leader, Msg, TcpTransport, Transport};
use dynamix::config::{presets, Scale};
use dynamix::rl::state::{idx, StateVector};
use dynamix::runtime::native::model::{fold_masked_ce_partial, normalized_grad_stats};
use dynamix::runtime::{ComputeBackend, NativeBackend};
use std::net::TcpListener;

/// Bucket target of the deployed reduce-scatter (`leader::ZERO_BUCKET_BYTES`
/// is a compile-time protocol constant derived on both sides, never
/// transmitted — the test-leader must agree with the worker's arithmetic).
const ZERO_BUCKET_BYTES: usize = 32 << 10;

/// Act as the leader for ONE real TCP worker through one full control
/// cycle (`k` data-plane iterations + the state report), returning the
/// worker's state vector. `zero` selects which plane's frames we speak;
/// the worker's side of the plane comes from `DYNAMIX_PLANE`, which the
/// caller pins to match.
fn drive_one_worker(zero: bool) -> StateVector {
    let cfg = presets::scaled(presets::by_name("vgg11-sgd").unwrap(), Scale::Quick);
    let model = cfg.train.model.clone();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let wh = std::thread::spawn(move || leader::worker(&addr, "vgg11-sgd", Scale::Quick, 0));
    let (stream, _) = listener.accept().unwrap();
    let mut t = TcpTransport::new(stream).unwrap();

    let batch = match t.recv().unwrap() {
        Msg::Register { worker, max_batch } => {
            assert_eq!(worker, 0);
            cfg.batch.initial.min(max_batch as usize)
        }
        other => panic!("expected Register, got {other:?}"),
    };
    t.send(&Msg::Welcome {
        worker: 0,
        k: cfg.rl.k as u32,
        initial_batch: batch as u32,
        n_workers: 1,
        cycles: 1,
    })
    .unwrap();

    let native = NativeBackend::with_threads(1);
    let pc = native.schema().model(&model).unwrap().param_count;
    let plan = native.bucket_plan(&model, ZERO_BUCKET_BYTES).unwrap();
    let part = native.param_partition(&model, &[true], ZERO_BUCKET_BYTES).unwrap();

    let mut seq = 0u64;
    for _ in 0..cfg.rl.k {
        seq += 1;
        let denom = batch as f32;
        t.send(&Msg::ShardStep { seq, denom, train: true, rows: None, params: None })
            .unwrap();
        let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
        match t.recv().unwrap() {
            Msg::ShardFwd { seq: rs, loss_terms, correct } => {
                assert_eq!(rs, seq);
                fold_masked_ce_partial(&loss_terms, &correct, &mut loss_sum, &mut acc_sum);
            }
            other => panic!("expected ShardFwd, got {other:?}"),
        }
        let loss = (loss_sum / denom as f64) as f32;
        let acc = (acc_sum / denom as f64) as f32;

        let grad = if zero {
            // Reduce-scatter: ring every travel-plan window through the
            // sole worker, then scatter it its (whole-model) owned slice
            // and take the updated params back; the all-gather leg is
            // empty with one owner.
            let mut grad = vec![0.0f32; pc];
            for (b, win) in plan.iter().enumerate() {
                t.send(&Msg::ShardGradSlice {
                    seq,
                    slice: b as u32,
                    offset: win.offset as u64,
                    grad: vec![0.0f32; win.len],
                })
                .unwrap();
                match t.recv().unwrap() {
                    Msg::ShardGradSlice { offset, grad: dense, .. } => {
                        let off = offset as usize;
                        grad[off..off + dense.len()].copy_from_slice(&dense);
                    }
                    other => panic!("expected folded slice, got {other:?}"),
                }
            }
            let r = part[0].clone();
            t.send(&Msg::ShardGradSlice {
                seq,
                slice: 0,
                offset: r.start as u64,
                grad: grad[r].to_vec(),
            })
            .unwrap();
            match t.recv().unwrap() {
                Msg::ShardParamSlice { seq: rs, .. } => assert_eq!(rs, seq),
                other => panic!("expected ShardParamSlice, got {other:?}"),
            }
            grad
        } else {
            // Replica ring: the whole accumulator makes its single hop.
            t.send(&Msg::ShardGradSeed { seq, grad: vec![0.0f32; pc] }).unwrap();
            match t.recv().unwrap() {
                Msg::ShardGradOut { seq: rs, grad } => {
                    assert_eq!(rs, seq);
                    grad
                }
                other => panic!("expected ShardGradOut, got {other:?}"),
            }
        };

        let (sigma_norm, sigma_norm2, grad_l2) = normalized_grad_stats(&grad);
        assert!(grad_l2 > 0.0, "reduced gradient unexpectedly zero at seq {seq}");
        t.send(&Msg::ShardGradFin {
            seq,
            loss,
            acc,
            sigma_norm,
            sigma_norm2,
            grad_l2,
            grad: if zero { Vec::new() } else { grad },
        })
        .unwrap();
    }

    let state = match t.recv().unwrap() {
        Msg::StateReport { state, .. } => state,
        other => panic!("expected StateReport, got {other:?}"),
    };
    t.send(&Msg::Shutdown).unwrap();
    wh.join().unwrap().unwrap();
    state
}

#[test]
fn tcp_worker_sigma_features_nonzero_and_identical_across_planes() {
    let prev_plane = std::env::var("DYNAMIX_PLANE").ok();
    let prev_wire = std::env::var("DYNAMIX_WIRE").ok();
    // Dense wire: the compressed codecs trade bit parity by design, and
    // the blackout was never about compression.
    std::env::set_var("DYNAMIX_WIRE", "dense");

    std::env::set_var("DYNAMIX_PLANE", "zero");
    let zs = drive_one_worker(true);
    std::env::set_var("DYNAMIX_PLANE", "replica");
    let rs = drive_one_worker(false);

    match prev_plane {
        Some(v) => std::env::set_var("DYNAMIX_PLANE", v),
        None => std::env::remove_var("DYNAMIX_PLANE"),
    }
    match prev_wire {
        Some(v) => std::env::set_var("DYNAMIX_WIRE", v),
        None => std::env::remove_var("DYNAMIX_WIRE"),
    }

    // The blackout symptom: these read 0.0 on the zero plane pre-v5.
    assert!(
        zs.0[idx::SIGMA_NORM] != 0.0 && zs.0[idx::SIGMA_NORM2] != 0.0,
        "zero-plane sigma features blacked out: {zs:?}"
    );
    assert!(
        rs.0[idx::SIGMA_NORM] != 0.0 && rs.0[idx::SIGMA_NORM2] != 0.0,
        "replica-plane sigma features zero: {rs:?}"
    );
    // Plane parity: same preset, same seed, dense wire — the reduced
    // gradient is bitwise identical across planes (the zero_parity
    // contract), so the carried sigma stats must be too.
    assert_eq!(
        zs.0[idx::SIGMA_NORM].to_bits(),
        rs.0[idx::SIGMA_NORM].to_bits(),
        "sigma_norm differs across planes: zero={} replica={}",
        zs.0[idx::SIGMA_NORM],
        rs.0[idx::SIGMA_NORM]
    );
    assert_eq!(
        zs.0[idx::SIGMA_NORM2].to_bits(),
        rs.0[idx::SIGMA_NORM2].to_bits(),
        "sigma_norm2 differs across planes: zero={} replica={}",
        zs.0[idx::SIGMA_NORM2],
        rs.0[idx::SIGMA_NORM2]
    );
    // Accuracy is deterministic too (wall-clock features are not, which
    // is why the assertion set stops here).
    assert_eq!(zs.0[idx::ACC_MEAN].to_bits(), rs.0[idx::ACC_MEAN].to_bits());
}
