//! Payoff oracle for durable elastic runs: kill a checkpointed inference
//! run mid-flight, restore from the latest image, and the resumed
//! [`RunRecord`] must be **bitwise identical** to the uninterrupted run.
//!
//! Two crash shapes:
//! * in-process "crash" — the coordinator is dropped and every image
//!   after the chosen resume point is deleted, swept across gradient
//!   plane {zero, replica} × backward/comm overlap on/off, under a
//!   scenario script whose preemption + rejoin straddle the resume
//!   point (the restored `ScenarioRuntime` must re-arm mid-timeline);
//! * a real `kill -9` — the test re-execs itself, SIGKILLs the child
//!   between checkpoints, and resumes in the parent.
//!
//! Plane and overlap are pinned through the [`ShardedBackend`] builders,
//! never the environment (CI sweeps `DYNAMIX_PLANE`/`DYNAMIX_WIRE` across
//! whole test binaries); every run also pins the checkpoint policy via
//! `set_ckpt_policy`/`set_resume` so ambient `DYNAMIX_CKPT_*` settings
//! cannot leak in. The SIGKILL child is the one deliberate exception: it
//! inherits the parent's environment, which carries the checkpoint dir.

use dynamix::comm::wire::WireMode;
use dynamix::config::ExperimentConfig;
use dynamix::coordinator::Coordinator;
use dynamix::metrics::RunRecord;
use dynamix::runtime::{native_backend, Backend, Plane, ShardedBackend};
use dynamix::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
use std::path::PathBuf;
use std::sync::Arc;

/// Decision-cycle horizon shared by every run in this file: `progress =
/// step / max_cycles` feeds the policy state, so a resume is only exact
/// over the original horizon.
const HORIZON: usize = 6;

fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.cluster.n_workers = 2;
    c.batch.initial = 64;
    c.rl.k = 2;
    c.steps_per_episode = HORIZON;
    c.train.max_steps = 100;
    c.train.eval_every = 2;
    // Mid-run churn: worker 1 drops early and rejoins late, so a resume
    // from the step-1 image re-arms the timeline with the preemption
    // either already applied (in the image) or still queued — both paths
    // must replay to the identical record.
    c.scenario = Some(ScenarioScript {
        name: "ckpt-churn".into(),
        events: vec![
            TimedEvent { at_s: 0.05, event: ScenarioEvent::PreemptWorker { worker: 1 } },
            TimedEvent { at_s: 0.30, event: ScenarioEvent::RejoinWorker { worker: 1 } },
        ],
    });
    c
}

fn sharded(plane: Plane, overlap: bool) -> Backend {
    Arc::new(
        ShardedBackend::loopback_with_threads(2, 1)
            .with_overlap(overlap, 40 << 10)
            .with_plane(plane)
            .with_wire(WireMode::Dense),
    )
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dynamix_ckres_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// One inference run over a FRESH coordinator + backend with an explicit
/// checkpoint policy (hermetic against ambient `DYNAMIX_CKPT_*`).
fn run(backend: Backend, dir: Option<PathBuf>, resume: bool) -> RunRecord {
    let mut coord = Coordinator::new(cfg(), backend).unwrap();
    coord.set_ckpt_policy(dir, 1);
    coord.set_resume(resume);
    let mut record = RunRecord::new("durable");
    coord.run_inference(HORIZON, &mut record).unwrap();
    record
}

fn assert_records_bitwise_eq(tag: &str, a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.name, b.name, "{tag}: name");
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point counts differ");
    for (i, (p, q)) in a.points.iter().zip(&b.points).enumerate() {
        assert_eq!(p.iter, q.iter, "{tag}: point {i} iter");
        assert_eq!(p.sim_time.to_bits(), q.sim_time.to_bits(), "{tag}: point {i} sim_time");
        assert_eq!(p.train_acc.to_bits(), q.train_acc.to_bits(), "{tag}: point {i} train_acc");
        assert_eq!(p.eval_acc.to_bits(), q.eval_acc.to_bits(), "{tag}: point {i} eval_acc");
        assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "{tag}: point {i} loss");
        assert_eq!(p.batch_mean.to_bits(), q.batch_mean.to_bits(), "{tag}: point {i} batch_mean");
        assert_eq!(p.batch_std.to_bits(), q.batch_std.to_bits(), "{tag}: point {i} batch_std");
        assert_eq!(p.global_batch, q.global_batch, "{tag}: point {i} global_batch");
    }
    assert_eq!(a.final_eval_acc.to_bits(), b.final_eval_acc.to_bits(), "{tag}: final_eval_acc");
    assert_eq!(
        a.convergence_time.map(f64::to_bits),
        b.convergence_time.map(f64::to_bits),
        "{tag}: convergence_time"
    );
    assert_eq!(a.total_sim_time.to_bits(), b.total_sim_time.to_bits(), "{tag}: total_sim_time");
    assert_eq!(a.total_iters, b.total_iters, "{tag}: total_iters");
    assert_eq!(a.extra, b.extra, "{tag}: record extras differ");
}

/// Delete every image after `keep` — the in-process stand-in for a crash
/// right after the step-`keep` checkpoint landed.
fn truncate_to(dir: &PathBuf, keep: usize) {
    while let Some((step, path)) = dynamix::ckpt::latest(dir) {
        if step <= keep {
            break;
        }
        std::fs::remove_file(&path).unwrap();
    }
    assert!(
        dynamix::ckpt::latest(dir).map_or(false, |(s, _)| s <= keep),
        "no image at or before step {keep} under {dir:?}"
    );
}

#[test]
fn drop_and_resume_is_bitwise_across_planes_and_overlap() {
    for (plane, label) in [(Plane::Zero, "zero"), (Plane::Replica, "replica")] {
        for overlap in [false, true] {
            let tag = format!("{label}_overlap_{overlap}");
            let dir = temp_dir(&tag);
            // Uninterrupted reference.
            let reference = run(sharded(plane, overlap), None, false);
            // Checkpointed run; the coordinator drops at the end of the
            // closure — the in-process crash — and the image trail is
            // truncated to the step-1 checkpoint.
            let killed = run(sharded(plane, overlap), Some(dir.clone()), false);
            assert_records_bitwise_eq(&tag, &reference, &killed);
            truncate_to(&dir, 1);
            // Resume in a fresh coordinator + fresh backend.
            let resumed = run(sharded(plane, overlap), Some(dir.clone()), true);
            assert_records_bitwise_eq(&tag, &reference, &resumed);
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

#[test]
fn journal_replays_the_churn_timeline_across_a_resume() {
    use dynamix::util::json::Json;
    let dir = temp_dir("journal");
    let reference = run(sharded(Plane::Zero, true), None, false);
    run(sharded(Plane::Zero, true), Some(dir.clone()), false);
    truncate_to(&dir, 1);
    let resumed = run(sharded(Plane::Zero, true), Some(dir.clone()), true);
    assert_records_bitwise_eq("journal", &reference, &resumed);
    // The journal saw the scenario's membership events (sim-time stamped)
    // plus cycles and checkpoints from both lives of the run.
    let lines = dynamix::ckpt::Journal::read(&dir).unwrap();
    let kinds: Vec<&str> = lines
        .iter()
        .filter_map(|l| l.get("kind").and_then(Json::as_str))
        .collect();
    assert!(kinds.contains(&"cycle"), "no cycle lines in {kinds:?}");
    assert!(kinds.contains(&"ckpt"), "no ckpt lines in {kinds:?}");
    let events: Vec<&str> = lines
        .iter()
        .filter(|l| l.get("kind").and_then(Json::as_str) == Some("event"))
        .filter_map(|l| l.get("event").and_then(Json::as_str))
        .collect();
    assert!(
        events.iter().any(|e| e.contains("preempt_worker")),
        "preemption never journaled: {events:?}"
    );
    assert!(
        events.iter().any(|e| e.contains("rejoin_worker")),
        "rejoin never journaled: {events:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Not a standalone test: the SIGKILL oracle below re-execs this binary
/// with `DYNAMIX_CKPT_CHILD` set (plus the `DYNAMIX_CKPT_*` policy in the
/// environment — the one env-seeded coordinator in this file) and kills
/// the child between checkpoints. Without the gate it is a no-op.
#[test]
fn child_runs_durable_inference_to_completion() {
    if std::env::var("DYNAMIX_CKPT_CHILD").is_err() {
        return;
    }
    let mut coord = Coordinator::new(cfg(), native_backend()).unwrap();
    let mut record = RunRecord::new("durable");
    coord.run_inference(HORIZON, &mut record).unwrap();
}

#[test]
fn sigkill_mid_run_then_restore_is_bitwise() {
    use std::process::{Command, Stdio};
    use std::time::{Duration, Instant};
    let dir = temp_dir("sigkill");
    let exe = std::env::current_exe().unwrap();
    let mut child = Command::new(exe)
        .args(["child_runs_durable_inference_to_completion", "--exact", "--nocapture"])
        .env("DYNAMIX_CKPT_CHILD", "1")
        .env("DYNAMIX_CKPT_DIR", &dir)
        .env("DYNAMIX_CKPT_EVERY", "1")
        .env_remove("DYNAMIX_RESUME")
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    // Kill -9 as soon as the step-2 image lands. If the child outruns the
    // poll and exits first, the trail is complete — the resume below is
    // then a pure tail-replay, which must ALSO be bitwise.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if dynamix::ckpt::latest(&dir).map_or(false, |(s, _)| s >= 2) {
            child.kill().ok();
            break;
        }
        if child.try_wait().unwrap().is_some() {
            break;
        }
        assert!(Instant::now() < deadline, "child never reached the step-2 checkpoint");
        std::thread::sleep(Duration::from_millis(1));
    }
    child.wait().unwrap();

    let reference = run(native_backend(), None, false);
    let resumed = run(native_backend(), Some(dir.clone()), true);
    assert_records_bitwise_eq("sigkill", &reference, &resumed);
    std::fs::remove_dir_all(&dir).ok();
}
