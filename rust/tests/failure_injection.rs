//! Failure-injection tests: corrupted inputs, missing artifacts, protocol
//! abuse, resource-pressure edge cases. The system must fail loudly and
//! informatively, never hang or silently mis-train.

use dynamix::comm::{channel_pair, Msg, Transport};
use dynamix::config::{ClusterPreset, ExperimentConfig};
use dynamix::rl::state::StateVector;
use dynamix::runtime::{default_backend, Backend, ComputeBackend, Manifest, ShardedBackend};
use dynamix::sim::scenario::ScenarioScript;
use dynamix::trainer::BspTrainer;
use std::path::PathBuf;
use std::sync::Arc;

fn store() -> Backend {
    default_backend().expect("backend selection failed")
}

fn temp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dynamix_fi_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_informative() {
    let d = temp_dir("nomanifest");
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupted_manifest_rejected() {
    let d = temp_dir("badmanifest");
    std::fs::write(d.join("manifest.json"), "{ not json !").unwrap();
    assert!(Manifest::load(&d).is_err());
    // Valid JSON, wrong schema:
    std::fs::write(d.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

// Artifact-file failure modes only exist on the XLA path; these skip
// cleanly on artifact-less (native) builds.
#[cfg(feature = "backend-xla")]
#[test]
fn missing_hlo_file_fails_at_compile_not_load() {
    use dynamix::runtime::ArtifactStore;
    // Store opens fine (lazy compile), then fails with the artifact name
    // when the file is gone.
    let s = ArtifactStore::open_default().expect("run `make artifacts` first");
    let real_dir = s.manifest.dir.clone();
    let d = temp_dir("missinghlo");
    std::fs::copy(real_dir.join("manifest.json"), d.join("manifest.json")).unwrap();
    // Copy init files but NO hlo files.
    for entry in std::fs::read_dir(&real_dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "f32").unwrap_or(false) {
            std::fs::copy(&p, d.join(p.file_name().unwrap())).unwrap();
        }
    }
    let broken = ArtifactStore::open(&d).unwrap();
    let err = match broken.get("policy_forward") {
        Ok(_) => panic!("compile should fail without the hlo file"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("policy_forward") || err.contains(".hlo.txt"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[cfg(feature = "backend-xla")]
#[test]
fn truncated_init_snapshot_rejected() {
    use dynamix::runtime::ArtifactStore;
    let s = ArtifactStore::open_default().expect("run `make artifacts` first");
    let d = temp_dir("shortinit");
    std::fs::copy(s.manifest.dir.join("manifest.json"), d.join("manifest.json")).unwrap();
    std::fs::write(d.join("init_vgg11_mini_seed0.f32"), [0u8; 10]).unwrap();
    let broken = ArtifactStore::open(&d).unwrap();
    assert!(broken.manifest.load_init_params("vgg11_mini", 0).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn native_backend_rejects_unknown_model_everywhere() {
    // Failure mode parity with the old missing-artifact errors: every
    // model-keyed entry point must name the offending model.
    let b = dynamix::runtime::native_backend();
    let err = b.init_params("vgg99_mini", 0).unwrap_err().to_string();
    assert!(err.contains("vgg99_mini"), "{err}");
    assert!(b.schema().model("nope").is_err());
    let mut cfg = ExperimentConfig::default();
    cfg.train.model = "nope".into();
    assert!(BspTrainer::new(&cfg, b).is_err());
}

#[test]
fn wire_rejects_corrupted_frames() {
    let good = Msg::StateReport {
        worker: 1,
        cycle: 2,
        state: StateVector(vec![0.5; 16]),
        reward: 1.0,
        sim_clock: 3.0,
    }
    .encode();
    // Truncations at every prefix length must error, not panic.
    for cut in 4..good.len() - 1 {
        assert!(Msg::decode(&good[4..cut]).is_err(), "cut={cut}");
    }
    // Bit flips in the header region must error (version/tag corruption).
    for i in 4..7 {
        let mut bad = good.clone();
        bad[i] ^= 0xFF;
        assert!(Msg::decode(&bad[4..]).is_err(), "flip at {i}");
    }
}

#[test]
fn transport_peer_disconnect_is_an_error_not_a_hang() {
    let (mut a, b) = channel_pair();
    drop(b);
    assert!(a.send(&Msg::Shutdown).is_err());
    assert!(a.recv().is_err());
}

#[test]
fn oversized_tcp_frame_rejected() {
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        // Claim a 100 MiB frame.
        s.write_all(&(100u32 << 20).to_le_bytes()).unwrap();
        s.write_all(&[0u8; 64]).unwrap();
    });
    let mut t = dynamix::comm::TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
    let err = t.recv().unwrap_err().to_string();
    assert!(err.contains("frame too large"), "{err}");
    h.join().unwrap();
}

#[test]
fn trainer_rejects_oversized_global_batch() {
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.n_workers = 4;
    let mut t = BspTrainer::new(&cfg, store()).unwrap();
    // Force a global batch beyond the bucket ladder.
    let &max_bucket = t.runtime.schema().buckets.last().unwrap();
    t.batches = vec![max_bucket; 4];
    let err = t.iterate().unwrap_err().to_string();
    assert!(err.contains("exceeds largest bucket"), "{err}");
}

#[test]
fn trainer_rejects_malformed_step_inputs() {
    let s = store();
    let mut rt = dynamix::trainer::ModelRuntime::new(
        s,
        "vgg11_mini",
        dynamix::config::Optimizer::Sgd,
        0.05,
        0,
    )
    .unwrap();
    let fd = rt.feature_dim;
    // xs too short for the bucket.
    assert!(rt.train_step(&vec![0.0; 31 * fd], &vec![0; 32], 32, 32).is_err());
    // n_valid > bucket.
    assert!(rt
        .train_step(&vec![0.0; 32 * fd], &vec![0; 32], 64, 32)
        .is_err());
}

#[test]
fn spot_market_burst_load_never_stalls_clock() {
    // Under the most hostile preset the BSP clock must strictly advance.
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.preset = ClusterPreset::SpotMarket;
    cfg.cluster.n_workers = 6;
    cfg.batch.initial = 64;
    let mut t = BspTrainer::new(&cfg, store()).unwrap();
    let mut prev = 0.0;
    for _ in 0..10 {
        let out = t.iterate().unwrap();
        assert!(out.sim_clock > prev, "clock stalled");
        assert!(out.sim_dt.is_finite() && out.sim_dt > 0.0);
        prev = out.sim_clock;
    }
}

#[test]
fn agent_rejects_wrong_state_dim() {
    let mut agent = dynamix::rl::agent::PpoAgent::new(
        store(),
        dynamix::config::RlConfig::default(),
        0,
    )
    .unwrap();
    let bad = vec![StateVector(vec![0.0; 7])];
    assert!(agent.act(&bad, true).is_err());
}

#[test]
fn agent_rejects_wrong_theta_len() {
    let mut agent = dynamix::rl::agent::PpoAgent::new(
        store(),
        dynamix::config::RlConfig::default(),
        0,
    )
    .unwrap();
    assert!(agent.load_theta(&[0.0; 3]).is_err());
}

/// One scripted run on the sharded loopback data plane: iterate until the
/// preempt_rejoin script's w3/w1 churn arc (4 events) has fully applied
/// plus two settling steps, enforcing the churn invariants (batch bounds,
/// OOM rule, trainer/backend membership mirroring, conserved global
/// batch) after every iteration. Returns a determinism fingerprint.
fn run_shard_churn(threads: usize) -> (Vec<(u64, String)>, Vec<u64>, Vec<Vec<bool>>) {
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.n_workers = 4;
    cfg.batch.initial = 64;
    cfg.scenario = Some(ScenarioScript::by_name("preempt_rejoin").unwrap());
    let backend: Backend = Arc::new(ShardedBackend::loopback_with_threads(4, threads));
    let mut t = BspTrainer::new(&cfg, backend.clone()).unwrap();
    let mut losses = Vec::new();
    let mut memberships = Vec::new();
    let mut iters = 0usize;
    let mut settle = 0usize;
    while settle < 2 && iters < 2000 {
        if t.events_applied.len() >= 4 {
            settle += 1; // both preempts + both rejoins landed
        }
        // The step completes under any membership — a dropped shard's
        // samples are absorbed by the survivors inside the fused step.
        let out = t.iterate().unwrap();
        iters += 1;
        losses.push(out.loss.to_bits());
        memberships.push(backend.shard_membership());
        // Trainer membership and data-plane membership mirror exactly
        // (shard_count == n_workers here).
        assert_eq!(backend.shard_membership(), t.active_mask(), "iter {iters}: mirror broke");
        // The fused step spans exactly the live membership's batches.
        let expect: usize = t.active_batches().iter().sum();
        assert_eq!(out.global_batch, expect, "iter {iters}: fused batch != live budget");
        // While only preemptions have fired, survivors absorb the freed
        // budget exactly: the global batch is conserved (mem caps don't
        // bind at these sizes). Rejoins legitimately grow it again —
        // the returning worker resumes its frozen batch.
        let rejoined = t.events_applied.iter().any(|(_, d)| d.contains("rejoin_worker"));
        let preempted = t.events_applied.iter().any(|(_, d)| d.contains("preempt_worker"));
        if preempted && !rejoined {
            assert_eq!(out.global_batch, 4 * 64, "iter {iters}: samples lost in churn");
        }
        // Churn invariants (as in proptest_invariants::prop_churn_*):
        // active batches stay inside [32,1024] and under the OOM ceiling.
        for w in 0..4 {
            if t.is_active(w) {
                assert!(
                    (32..=1024).contains(&t.batches[w]),
                    "iter {iters}: w{w} batch {} escaped bounds",
                    t.batches[w]
                );
                let cap = t.mem_cap(w, 1024);
                assert!(
                    t.batches[w] <= cap.max(32),
                    "iter {iters}: w{w} batch {} above mem cap {cap}",
                    t.batches[w]
                );
            }
        }
    }
    let events = t
        .events_applied
        .iter()
        .map(|(at, d)| (at.to_bits(), d.clone()))
        .collect();
    (events, losses, memberships)
}

#[test]
fn preempt_rejoin_scenario_kills_and_revives_loopback_shards() {
    // preempt_rejoin: w3 down at 0.6s, w1 down at 1.2s, w3 back at 2.4s,
    // w1 back at 3.6s. Run past both rejoins and check the data plane
    // followed the whole arc, deterministically across kernel threads.
    let (events, losses, memberships) = run_shard_churn(1);
    assert!(
        events.iter().any(|(_, d)| d.contains("preempt_worker w3")),
        "preemption never fired: {events:?}"
    );
    assert!(
        events.iter().any(|(_, d)| d.contains("rejoin_worker w3")),
        "rejoin never fired: {events:?}"
    );
    // Mid-run some iteration saw shard 3 (and later shard 1) absent.
    assert!(memberships.iter().any(|m| !m[3]), "shard 3 never dropped");
    assert!(memberships.iter().any(|m| !m[1]), "shard 1 never dropped");
    // After the horizon both rejoins have fired: full membership again.
    assert_eq!(memberships.last().unwrap(), &vec![true; 4], "rejoin did not restore shards");

    // Bitwise-deterministic across kernel thread counts: same event log,
    // same losses, same membership trajectory.
    let again = run_shard_churn(4);
    assert_eq!(again, (events, losses, memberships), "shard churn not thread-stable");
}

#[test]
fn shard_protocol_rejects_malformed_shard_steps() {
    // The data plane fails loudly on bad inputs, like every other seam.
    let b = ShardedBackend::loopback_with_threads(2, 1);
    let mut state = dynamix::runtime::OptState::new(
        b.init_params("vgg11_mini", 0).unwrap(),
        dynamix::config::Optimizer::Sgd,
    );
    let fd = b.schema().feature_dim;
    // Off-ladder bucket.
    let err = b
        .train_step("vgg11_mini", dynamix::config::Optimizer::Sgd, 33, &mut state,
                    &vec![0.0; 33 * fd], &vec![0; 33], &vec![1.0; 33], 0.05)
        .unwrap_err()
        .to_string();
    assert!(err.contains("ladder"), "{err}");
    // Wrong x size.
    assert!(b
        .train_step("vgg11_mini", dynamix::config::Optimizer::Sgd, 32, &mut state,
                    &vec![0.0; 31 * fd], &vec![0; 32], &vec![1.0; 32], 0.05)
        .is_err());
    // Out-of-range label surfaces from the shard with the offending value.
    let err = b
        .train_step("vgg11_mini", dynamix::config::Optimizer::Sgd, 32, &mut state,
                    &vec![0.0; 32 * fd], &vec![37; 32], &vec![1.0; 32], 0.05)
        .unwrap_err()
        .to_string();
    assert!(err.contains("37"), "{err}");
    // Unknown model.
    assert!(b.init_params("nope", 0).is_err());
    // The data plane still works after the errors (stale held state on
    // the shards is recycled by the next Step).
    let (x, y, mask) = (vec![0.1; 32 * fd], vec![1i32; 32], vec![1.0; 32]);
    b.train_step("vgg11_mini", dynamix::config::Optimizer::Sgd, 32, &mut state, &x, &y, &mask, 0.05)
        .unwrap();
}

/// A TCP shard transport that severs its connection the moment the
/// traveling gradient reaches it after the kill flag is raised — on the
/// bulk ring that is the `GradSeed`, on the overlapped replica ring the
/// first `GradBucket` frame, and on the zero plane the first slice frame
/// of any wire mode, i.e. the socket dies **mid-hop** with the leader's
/// accumulator in flight whichever plane is configured.
struct KillableTransport<T: dynamix::runtime::sharded::transport::ShardTransport> {
    inner: T,
    kill: Arc<std::sync::atomic::AtomicBool>,
}

impl<T: dynamix::runtime::sharded::transport::ShardTransport>
    dynamix::runtime::sharded::transport::ShardTransport for KillableTransport<T>
{
    fn send(&mut self, msg: dynamix::runtime::sharded::transport::ShardMsg) -> anyhow::Result<()> {
        self.inner.send(msg)
    }

    fn recv(&mut self) -> anyhow::Result<dynamix::runtime::sharded::transport::ShardMsg> {
        let msg = self.inner.recv()?;
        if self.kill.load(std::sync::atomic::Ordering::SeqCst)
            && matches!(
                msg,
                dynamix::runtime::sharded::transport::ShardMsg::GradSeed { .. }
                    | dynamix::runtime::sharded::transport::ShardMsg::GradBucket { .. }
                    | dynamix::runtime::sharded::transport::ShardMsg::GradSlice { .. }
                    | dynamix::runtime::sharded::transport::ShardMsg::GradTopK { .. }
                    | dynamix::runtime::sharded::transport::ShardMsg::GradQ8 { .. }
            )
        {
            // Returning an error makes `serve` exit, dropping the TCP
            // stream: from the leader's side the shard was just killed.
            anyhow::bail!("injected shard kill (scenario preempt)");
        }
        Ok(msg)
    }
}

#[test]
fn tcp_shard_killed_mid_ring_surfaces_clean_error_and_recovers() {
    // Socket-level fault injection, timed by the scenario engine: a
    // preempt_worker event on the scripted timeline decides WHEN the TCP
    // shard dies; the kill itself severs the real socket mid-ring (after
    // Fwd, while the leader's traveling gradient accumulator is at that
    // shard). The leader must surface a clean shard-tagged error — never
    // wedge — and after dropping the dead shard from the membership the
    // data plane must finish the run bit-identically to the native
    // backend (a failed step applies no optimizer update, so the retry is
    // exact).
    use dynamix::config::Optimizer;
    use dynamix::runtime::sharded::transport::TcpShardTransport;
    use dynamix::runtime::sharded::worker as shard_worker;
    use dynamix::runtime::{NativeBackend, OptState};
    use dynamix::sim::scenario::{ScenarioEvent, ScenarioRuntime, ScenarioScript, TimedEvent};
    use dynamix::util::rng::Rng;
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicBool, Ordering};

    // The timeline: kill shard 1 at t = 2.5, re-admit it at t = 4.5 (the
    // BSP clock below ticks 1.0s per step, so the kill lands before step
    // index 2's ring and the rejoin before step index 4's).
    let script = ScenarioScript {
        name: "kill-tcp-shard".into(),
        events: vec![
            TimedEvent {
                at_s: 2.5,
                event: ScenarioEvent::PreemptWorker { worker: 1 },
            },
            TimedEvent {
                at_s: 4.5,
                event: ScenarioEvent::RejoinWorker { worker: 1 },
            },
        ],
    };
    let mut timeline = ScenarioRuntime::new(script);
    let kill = Arc::new(AtomicBool::new(false));

    // Two real TCP shard servers; server 1 is killable.
    let mut handles = Vec::new();
    let mut links: Vec<Box<dyn dynamix::runtime::sharded::transport::ShardTransport>> = Vec::new();
    for id in 0..2usize {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let kill = kill.clone();
        handles.push(std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpShardTransport::new(dynamix::comm::TcpTransport::new(stream).unwrap());
            let backend = Arc::new(NativeBackend::with_threads(1));
            if id == 1 {
                // serve() returns Err on the injected kill; dropping the
                // transport closes the socket either way.
                let _ = shard_worker::serve(KillableTransport { inner: t, kill }, backend);
            } else {
                let _ = shard_worker::serve(t, backend);
            }
        }));
        let stream = std::net::TcpStream::connect(addr).unwrap();
        links.push(Box::new(TcpShardTransport::new(
            dynamix::comm::TcpTransport::new(stream).unwrap(),
        )));
    }
    let sharded =
        ShardedBackend::over_transports(Arc::new(NativeBackend::with_threads(1)), links).unwrap();
    let native = NativeBackend::with_threads(1);

    let fd = native.schema().feature_dim;
    let mut ss = OptState::new(sharded.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
    let mut ns = OptState::new(native.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
    let mut clock = 0.0f64;
    let mut killed = false;
    let mut rejoined = false;
    for step in 0..7u64 {
        clock += 1.0;
        for (_, ev) in timeline.pop_due(clock) {
            match ev {
                ScenarioEvent::PreemptWorker { worker } => {
                    assert_eq!(worker, 1);
                    kill.store(true, Ordering::SeqCst);
                    killed = true;
                }
                // The reconnect/rejoin handshake: a fresh TCP shard
                // server comes up, the leader attaches the new link and
                // flips the shard back into the membership. No state
                // re-sync protocol — Step ships rows + params, so the
                // very next iteration trains through the rejoined shard.
                ScenarioEvent::RejoinWorker { worker } => {
                    assert_eq!(worker, 1);
                    assert!(killed, "rejoin fired before the kill");
                    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                    let addr = listener.local_addr().unwrap();
                    handles.push(std::thread::spawn(move || {
                        let (stream, _) = listener.accept().unwrap();
                        let t = TcpShardTransport::new(
                            dynamix::comm::TcpTransport::new(stream).unwrap(),
                        );
                        let _ = shard_worker::serve(t, Arc::new(NativeBackend::with_threads(1)));
                    }));
                    let stream = std::net::TcpStream::connect(addr).unwrap();
                    sharded
                        .reattach_transport(
                            1,
                            Box::new(TcpShardTransport::new(
                                dynamix::comm::TcpTransport::new(stream).unwrap(),
                            )),
                        )
                        .unwrap();
                    assert!(sharded.set_shard_active(1, true), "rejoin must re-enter membership");
                    rejoined = true;
                }
                other => panic!("unexpected scenario event {other:?}"),
            }
        }
        let mut rng = Rng::new(9000 + step);
        let bucket = 64usize;
        let x: Vec<f32> = (0..bucket * fd).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
        let mask = vec![1.0f32; bucket];

        let res = sharded.train_step(
            "vgg11_mini", Optimizer::Sgd, bucket, &mut ss, &x, &y, &mask, 0.05,
        );
        let got = match res {
            Ok(out) => out,
            Err(e) => {
                // The kill must surface as a clean, shard-tagged error —
                // not a hang, not a poisoned data plane.
                assert!(killed, "step {step} failed before the scenario event: {e:#}");
                let msg = format!("{e:#}");
                assert!(msg.contains("shard 1"), "error must name the shard: {msg}");
                // Reconnect path: drop the dead shard; survivors absorb
                // its rows. The failed step applied no update, so the
                // retry replays it exactly.
                assert!(sharded.set_shard_active(1, false));
                sharded
                    .train_step(
                        "vgg11_mini", Optimizer::Sgd, bucket, &mut ss, &x, &y, &mask, 0.05,
                    )
                    .expect("the data plane must keep working on the survivors")
            }
        };
        let want = native
            .train_step("vgg11_mini", Optimizer::Sgd, bucket, &mut ns, &x, &y, &mask, 0.05)
            .unwrap();
        assert_eq!(
            got.loss.to_bits(),
            want.loss.to_bits(),
            "step {step}: loss diverged after shard kill"
        );
        assert_eq!(
            ss.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
            ns.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
            "step {step}: params diverged after shard kill"
        );
    }
    assert!(killed, "the scenario timeline never fired");
    assert!(rejoined, "the rejoin event never fired");
    assert_eq!(
        sharded.shard_membership(),
        vec![true, true],
        "rejoined shard must be back in the membership"
    );
    // Shutdown to shard 0 and the rejoined shard 1 server; the killed
    // shard 1 thread already exited on the injected error.
    drop(sharded);
    for h in handles {
        let _ = h.join();
    }
}

#[test]
fn config_loading_rejects_garbage_files() {
    let d = temp_dir("badcfg");
    let p = d.join("cfg.json");
    std::fs::write(&p, "not json").unwrap();
    assert!(ExperimentConfig::load(&p).is_err());
    std::fs::write(&p, r#"{"n_workers": 999}"#).unwrap();
    assert!(ExperimentConfig::load(&p).is_err(), "validation must run on load");
    std::fs::remove_dir_all(&d).ok();
}
