//! Failure-injection tests: corrupted inputs, missing artifacts, protocol
//! abuse, resource-pressure edge cases. The system must fail loudly and
//! informatively, never hang or silently mis-train.

use dynamix::comm::{channel_pair, Msg, Transport};
use dynamix::config::{ClusterPreset, ExperimentConfig};
use dynamix::rl::state::StateVector;
use dynamix::runtime::{default_backend, Backend, Manifest};
use dynamix::trainer::BspTrainer;
use std::path::PathBuf;

fn store() -> Backend {
    default_backend().expect("backend selection failed")
}

fn temp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dynamix_fi_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_informative() {
    let d = temp_dir("nomanifest");
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupted_manifest_rejected() {
    let d = temp_dir("badmanifest");
    std::fs::write(d.join("manifest.json"), "{ not json !").unwrap();
    assert!(Manifest::load(&d).is_err());
    // Valid JSON, wrong schema:
    std::fs::write(d.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

// Artifact-file failure modes only exist on the XLA path; these skip
// cleanly on artifact-less (native) builds.
#[cfg(feature = "backend-xla")]
#[test]
fn missing_hlo_file_fails_at_compile_not_load() {
    use dynamix::runtime::ArtifactStore;
    // Store opens fine (lazy compile), then fails with the artifact name
    // when the file is gone.
    let s = ArtifactStore::open_default().expect("run `make artifacts` first");
    let real_dir = s.manifest.dir.clone();
    let d = temp_dir("missinghlo");
    std::fs::copy(real_dir.join("manifest.json"), d.join("manifest.json")).unwrap();
    // Copy init files but NO hlo files.
    for entry in std::fs::read_dir(&real_dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "f32").unwrap_or(false) {
            std::fs::copy(&p, d.join(p.file_name().unwrap())).unwrap();
        }
    }
    let broken = ArtifactStore::open(&d).unwrap();
    let err = match broken.get("policy_forward") {
        Ok(_) => panic!("compile should fail without the hlo file"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("policy_forward") || err.contains(".hlo.txt"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[cfg(feature = "backend-xla")]
#[test]
fn truncated_init_snapshot_rejected() {
    use dynamix::runtime::ArtifactStore;
    let s = ArtifactStore::open_default().expect("run `make artifacts` first");
    let d = temp_dir("shortinit");
    std::fs::copy(s.manifest.dir.join("manifest.json"), d.join("manifest.json")).unwrap();
    std::fs::write(d.join("init_vgg11_mini_seed0.f32"), [0u8; 10]).unwrap();
    let broken = ArtifactStore::open(&d).unwrap();
    assert!(broken.manifest.load_init_params("vgg11_mini", 0).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn native_backend_rejects_unknown_model_everywhere() {
    // Failure mode parity with the old missing-artifact errors: every
    // model-keyed entry point must name the offending model.
    let b = dynamix::runtime::native_backend();
    let err = b.init_params("vgg99_mini", 0).unwrap_err().to_string();
    assert!(err.contains("vgg99_mini"), "{err}");
    assert!(b.schema().model("nope").is_err());
    let mut cfg = ExperimentConfig::default();
    cfg.train.model = "nope".into();
    assert!(BspTrainer::new(&cfg, b).is_err());
}

#[test]
fn wire_rejects_corrupted_frames() {
    let good = Msg::StateReport {
        worker: 1,
        cycle: 2,
        state: StateVector(vec![0.5; 16]),
        reward: 1.0,
        sim_clock: 3.0,
    }
    .encode();
    // Truncations at every prefix length must error, not panic.
    for cut in 4..good.len() - 1 {
        assert!(Msg::decode(&good[4..cut]).is_err(), "cut={cut}");
    }
    // Bit flips in the header region must error (version/tag corruption).
    for i in 4..7 {
        let mut bad = good.clone();
        bad[i] ^= 0xFF;
        assert!(Msg::decode(&bad[4..]).is_err(), "flip at {i}");
    }
}

#[test]
fn transport_peer_disconnect_is_an_error_not_a_hang() {
    let (mut a, b) = channel_pair();
    drop(b);
    assert!(a.send(&Msg::Shutdown).is_err());
    assert!(a.recv().is_err());
}

#[test]
fn oversized_tcp_frame_rejected() {
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        // Claim a 100 MiB frame.
        s.write_all(&(100u32 << 20).to_le_bytes()).unwrap();
        s.write_all(&[0u8; 64]).unwrap();
    });
    let mut t = dynamix::comm::TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
    let err = t.recv().unwrap_err().to_string();
    assert!(err.contains("frame too large"), "{err}");
    h.join().unwrap();
}

#[test]
fn trainer_rejects_oversized_global_batch() {
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.n_workers = 4;
    let mut t = BspTrainer::new(&cfg, store()).unwrap();
    // Force a global batch beyond the bucket ladder.
    let &max_bucket = t.runtime.schema().buckets.last().unwrap();
    t.batches = vec![max_bucket; 4];
    let err = t.iterate().unwrap_err().to_string();
    assert!(err.contains("exceeds largest bucket"), "{err}");
}

#[test]
fn trainer_rejects_malformed_step_inputs() {
    let s = store();
    let mut rt = dynamix::trainer::ModelRuntime::new(
        s,
        "vgg11_mini",
        dynamix::config::Optimizer::Sgd,
        0.05,
        0,
    )
    .unwrap();
    let fd = rt.feature_dim;
    // xs too short for the bucket.
    assert!(rt.train_step(&vec![0.0; 31 * fd], &vec![0; 32], 32, 32).is_err());
    // n_valid > bucket.
    assert!(rt
        .train_step(&vec![0.0; 32 * fd], &vec![0; 32], 64, 32)
        .is_err());
}

#[test]
fn spot_market_burst_load_never_stalls_clock() {
    // Under the most hostile preset the BSP clock must strictly advance.
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.preset = ClusterPreset::SpotMarket;
    cfg.cluster.n_workers = 6;
    cfg.batch.initial = 64;
    let mut t = BspTrainer::new(&cfg, store()).unwrap();
    let mut prev = 0.0;
    for _ in 0..10 {
        let out = t.iterate().unwrap();
        assert!(out.sim_clock > prev, "clock stalled");
        assert!(out.sim_dt.is_finite() && out.sim_dt > 0.0);
        prev = out.sim_clock;
    }
}

#[test]
fn agent_rejects_wrong_state_dim() {
    let mut agent = dynamix::rl::agent::PpoAgent::new(
        store(),
        dynamix::config::RlConfig::default(),
        0,
    )
    .unwrap();
    let bad = vec![StateVector(vec![0.0; 7])];
    assert!(agent.act(&bad, true).is_err());
}

#[test]
fn agent_rejects_wrong_theta_len() {
    let mut agent = dynamix::rl::agent::PpoAgent::new(
        store(),
        dynamix::config::RlConfig::default(),
        0,
    )
    .unwrap();
    assert!(agent.load_theta(&[0.0; 3]).is_err());
}

#[test]
fn config_loading_rejects_garbage_files() {
    let d = temp_dir("badcfg");
    let p = d.join("cfg.json");
    std::fs::write(&p, "not json").unwrap();
    assert!(ExperimentConfig::load(&p).is_err());
    std::fs::write(&p, r#"{"n_workers": 999}"#).unwrap();
    assert!(ExperimentConfig::load(&p).is_err(), "validation must run on load");
    std::fs::remove_dir_all(&d).ok();
}
