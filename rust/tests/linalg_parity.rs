//! Parity suite for the native linalg kernel tiers.
//!
//! Three tiers exist (`DYNAMIX_KERNEL=scalar|blocked|simd`); the blocked
//! and simd tiers reorder floating-point accumulation on the forward /
//! input-gradient kernels (lane-wise partial sums, 4-way unrolls, FMA,
//! packed-panel axpy), so those are held to the scalar reference loops
//! within 1e-5 on randomized inputs — across awkward shapes (m=1, odd n,
//! off-lane n, k=1) and DYNAMIX_THREADS = 1, 2, 7. The reduce-sensitive
//! kernels (`matmul_at`, `col_sums`) preserve the sequential
//! per-output-element row fold in **every** tier and are asserted
//! **bitwise** identical across tiers and thread counts — the invariant
//! the sharded data plane's chained reduction stands on. The whole train
//! step is additionally held bitwise-stable across thread counts.

use dynamix::config::Optimizer;
use dynamix::runtime::native::exec::{simd_supported, KernelTier, Pool};
use dynamix::runtime::native::linalg::{self, scalar};
use dynamix::runtime::native::workspace::PanelCache;
use dynamix::runtime::native::NativeBackend;
use dynamix::runtime::{ComputeBackend, OptState};
use dynamix::util::rng::Rng;

/// Awkward shapes: unit dims, odd everything, off-lane/off-tile widths,
/// and one large-enough-to-actually-thread case.
const SHAPES: [(usize, usize, usize); 11] = [
    (1, 1, 1),
    (1, 7, 5),
    (3, 1, 9),
    (5, 13, 1),
    (2, 3, 8),
    (17, 31, 40),
    (7, 129, 33),
    (33, 64, 10),
    (64, 128, 64),
    (256, 65, 17),
    (512, 96, 40), // large enough to fan out across every thread count
];

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}[{i}]: {a} != {b}");
    }
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
            "{what}[{i}]: {a} vs {b}"
        );
    }
}

#[test]
fn all_tiers_match_scalar_reference_across_shapes_and_threads() {
    let mut rng = Rng::new(0xD1A);
    for &(m, k, n) in &SHAPES {
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let dy = rand_vec(&mut rng, m * n);

        let mut acc_ref = vec![0.0f32; m * n];
        scalar::matmul_acc(&x, &w, m, k, n, &mut acc_ref);
        let mut bt_ref = vec![0.0f32; m * k];
        scalar::matmul_bt(&dy, &w, m, k, n, &mut bt_ref);
        let mut at_ref = vec![0.0f32; k * n];
        scalar::matmul_at(&x, &dy, m, k, n, &mut at_ref);

        for tier in KernelTier::available() {
            for threads in [1usize, 2, 7] {
                let pool = Pool::with_config(threads, tier);
                let tag = format!("{}/m{m}k{k}n{n}t{threads}", tier.as_str());

                let mut acc = vec![0.0f32; m * n];
                linalg::matmul_acc(&pool, &x, &w, m, k, n, &mut acc);
                assert_close(&acc, &acc_ref, &format!("acc/{tag}"));

                let mut bt = vec![0.0f32; m * k];
                linalg::matmul_bt(&pool, &dy, &w, m, k, n, &mut bt);
                assert_close(&bt, &bt_ref, &format!("bt/{tag}"));

                // Packed-panel bt (the hot-path form) against the same
                // reference, through a fresh generation-tagged panel.
                let mut panels = PanelCache::default();
                let mut btp = vec![0.0f32; m * k];
                linalg::matmul_bt_ws(
                    &pool, &mut panels, 1, 0, &dy, &w, m, k, n, &mut btp,
                );
                assert_close(&btp, &bt_ref, &format!("bt_packed/{tag}"));

                let mut at = vec![0.0f32; k * n];
                linalg::matmul_at(&pool, &x, &dy, m, k, n, &mut at);
                assert_close(&at, &at_ref, &format!("at/{tag}"));
            }
        }
    }
}

#[test]
fn reduce_sensitive_kernels_are_bitwise_identical_across_tiers() {
    // matmul_at and col_sums carry the sharded data plane's bit-parity
    // contract: every tier folds rows sequentially per output element
    // with identical rounding (mul+add, never FMA). Bitwise, not 1e-5.
    let mut rng = Rng::new(0xB17);
    for &(m, k, n) in &[(1usize, 9usize, 12usize), (7, 1, 33), (33, 17, 1),
                        (64, 40, 24), (129, 65, 17)] {
        let x = rand_vec(&mut rng, m * k);
        let dy = rand_vec(&mut rng, m * n);
        let mut at_ref = vec![0.0f32; k * n];
        scalar::matmul_at(&x, &dy, m, k, n, &mut at_ref);
        for tier in KernelTier::available() {
            for threads in [1usize, 2, 7] {
                let pool = Pool::with_config(threads, tier);
                let mut at = vec![0.0f32; k * n];
                linalg::matmul_at(&pool, &x, &dy, m, k, n, &mut at);
                for (i, (a, b)) in at.iter().zip(&at_ref).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "at[{i}] {}/t{threads}: {a} != scalar {b}",
                        tier.as_str()
                    );
                }
            }
        }
        // col_sums: one shared implementation; chaining row slices in
        // order must replay the fused fold exactly (the property the
        // shard ring relies on).
        let seq = Pool::sequential();
        let mut fused = vec![0.0f32; n];
        linalg::col_sums(&seq, &dy, m, n, &mut fused);
        let mut chained = vec![0.0f32; n];
        let split = m / 2;
        linalg::col_sums(&seq, &dy[..split * n], split, n, &mut chained);
        linalg::col_sums(&seq, &dy[split * n..], m - split, n, &mut chained);
        for (a, b) in chained.iter().zip(&fused) {
            assert_eq!(a.to_bits(), b.to_bits(), "col_sums chain diverged");
        }
        // ...and the pooled/SIMD col_sums must replay the same fold bitwise
        // across every tier and thread count (column partition: each output
        // column is owned by exactly one chunk, folded in row order).
        for tier in KernelTier::available() {
            for threads in [1usize, 2, 7] {
                let pool = Pool::with_config(threads, tier);
                let mut cs = vec![0.0f32; n];
                linalg::col_sums(&pool, &dy, m, n, &mut cs);
                for (i, (a, b)) in cs.iter().zip(&fused).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "col_sums[{i}] {}/t{threads} diverged",
                        tier.as_str()
                    );
                }
            }
        }
    }
}

#[test]
fn elementwise_kernels_are_bitwise_identical_across_tiers_and_threads() {
    // relu/tanh(+backwards) and bias add are order-free per element: the
    // SIMD lanes use only correctly-rounded IEEE ops (no FMA) and the pool
    // partition assigns each element to exactly one chunk, so every tier ×
    // thread combination is held BITWISE to the scalar reference. Lengths
    // cover sub-lane, off-lane, and large-enough-to-actually-thread.
    let mut rng = Rng::new(0xE1E);
    for &len in &[1usize, 7, 33, 1000, 300_000] {
        let base = rand_vec(&mut rng, len);
        let act = rand_vec(&mut rng, len);

        let mut relu_ref = base.clone();
        scalar::relu(&mut relu_ref);
        let mut tanh_ref = base.clone();
        scalar::tanh(&mut tanh_ref);
        let mut rbwd_ref = base.clone();
        scalar::relu_backward(&mut rbwd_ref, &act);
        let mut tbwd_ref = base.clone();
        scalar::tanh_backward(&mut tbwd_ref, &act);

        for tier in KernelTier::available() {
            for threads in [1usize, 2, 7] {
                let pool = Pool::with_config(threads, tier);
                let tag = format!("{}/len{len}t{threads}", tier.as_str());

                let mut v = base.clone();
                linalg::relu(&pool, &mut v);
                assert_bits(&v, &relu_ref, &format!("relu/{tag}"));

                let mut v = base.clone();
                linalg::tanh(&pool, &mut v);
                assert_bits(&v, &tanh_ref, &format!("tanh/{tag}"));

                let mut v = base.clone();
                linalg::relu_backward(&pool, &mut v, &act);
                assert_bits(&v, &rbwd_ref, &format!("relu_bwd/{tag}"));

                let mut v = base.clone();
                linalg::tanh_backward(&pool, &mut v, &act);
                assert_bits(&v, &tbwd_ref, &format!("tanh_bwd/{tag}"));
            }
        }
    }
    // add_bias over awkward (m, n) shapes, including one past the
    // parallel cutoff.
    for &(m, n) in &[(1usize, 1usize), (3, 7), (17, 33), (700, 512)] {
        let b = rand_vec(&mut rng, n);
        let base = rand_vec(&mut rng, m * n);
        let mut bias_ref = base.clone();
        scalar::add_bias(&mut bias_ref, &b, m, n);
        for tier in KernelTier::available() {
            for threads in [1usize, 2, 7] {
                let pool = Pool::with_config(threads, tier);
                let mut v = base.clone();
                linalg::add_bias(&pool, &mut v, &b, m, n);
                assert_bits(
                    &v,
                    &bias_ref,
                    &format!("add_bias/{}/m{m}n{n}t{threads}", tier.as_str()),
                );
            }
        }
    }
}

#[test]
fn log_softmax_is_bitwise_identical_across_tiers_and_threads() {
    // The row fold (max, then exp-sum in column order) is sequential in
    // every tier — exp/ln are libm-bound, so the pooled form only
    // partitions ROWS across threads. Bitwise, not 1e-5.
    let mut rng = Rng::new(0x105);
    for &(m, n) in &[(1usize, 1usize), (3, 7), (40, 10), (1024, 64)] {
        let logits = rand_vec(&mut rng, m * n);
        let mut lp_ref = vec![0.0f32; m * n];
        scalar::log_softmax(&logits, m, n, &mut lp_ref);
        for tier in KernelTier::available() {
            for threads in [1usize, 2, 7] {
                let pool = Pool::with_config(threads, tier);
                let mut lp = vec![0.0f32; m * n];
                linalg::log_softmax(&pool, &logits, m, n, &mut lp);
                assert_bits(
                    &lp,
                    &lp_ref,
                    &format!("log_softmax/{}/m{m}n{n}t{threads}", tier.as_str()),
                );
            }
        }
    }
}

#[test]
fn optimizer_applies_are_bitwise_identical_across_tiers_and_threads() {
    // The sliced optimizer apply fans each parameter window across the
    // pool; every parameter is touched by exactly one chunk with the same
    // per-element arithmetic (no FMA in the SIMD lanes), so the result is
    // BITWISE equal to the fused sequential loop in every tier × thread
    // combination — the invariant the zero plane's per-rank slices and the
    // replica plane's fused apply both stand on.
    let mut rng = Rng::new(0xADA);
    for &len in &[1usize, 7, 33, 5000, 40_000] {
        let g = rand_vec(&mut rng, len);
        let p0 = rand_vec(&mut rng, len);
        let m0 = rand_vec(&mut rng, len);
        let v0: Vec<f32> = rand_vec(&mut rng, len).iter().map(|v| v.abs()).collect();

        let (mut p_ref, mut m_ref) = (p0.clone(), m0.clone());
        scalar::sgd_apply(&mut p_ref, &mut m_ref, &g, 0.05, 0.9);
        let (mut ap_ref, mut am_ref, mut av_ref) = (p0.clone(), m0.clone(), v0.clone());
        let (c1, c2) = (0.1f32, 0.001f32);
        scalar::adam_apply(
            &mut ap_ref, &mut am_ref, &mut av_ref, &g, 0.001, 0.9, 0.999, 1e-8, c1, c2,
        );

        for tier in KernelTier::available() {
            for threads in [1usize, 2, 7] {
                let pool = Pool::with_config(threads, tier);
                let tag = format!("{}/len{len}t{threads}", tier.as_str());

                let (mut p, mut mm) = (p0.clone(), m0.clone());
                linalg::sgd_apply(&pool, &mut p, &mut mm, &g, 0.05, 0.9);
                assert_bits(&p, &p_ref, &format!("sgd_p/{tag}"));
                assert_bits(&mm, &m_ref, &format!("sgd_m/{tag}"));

                let (mut p, mut mm, mut vv) = (p0.clone(), m0.clone(), v0.clone());
                linalg::adam_apply(
                    &pool, &mut p, &mut mm, &mut vv, &g, 0.001, 0.9, 0.999, 1e-8, c1, c2,
                );
                assert_bits(&p, &ap_ref, &format!("adam_p/{tag}"));
                assert_bits(&mm, &am_ref, &format!("adam_m/{tag}"));
                assert_bits(&vv, &av_ref, &format!("adam_v/{tag}"));
            }
        }
    }
}

#[test]
fn padded_zero_rows_cost_nothing_and_change_nothing() {
    // The row-level sparsity skip must be purely an optimization: results
    // with padded (all-zero) trailing rows equal the scalar reference.
    let mut rng = Rng::new(7);
    let (m, k, n) = (24usize, 33usize, 20usize);
    let valid = 9usize;
    let mut x = rand_vec(&mut rng, m * k);
    let mut dy = rand_vec(&mut rng, m * n);
    for v in &mut x[valid * k..] {
        *v = 0.0;
    }
    for v in &mut dy[valid * n..] {
        *v = 0.0;
    }
    let w = rand_vec(&mut rng, k * n);

    let mut acc_ref = vec![0.0f32; m * n];
    scalar::matmul_acc(&x, &w, m, k, n, &mut acc_ref);
    let mut at_ref = vec![0.0f32; k * n];
    scalar::matmul_at(&x, &dy, m, k, n, &mut at_ref);
    let mut bt_ref = vec![0.0f32; m * k];
    scalar::matmul_bt(&dy, &w, m, k, n, &mut bt_ref);

    for tier in KernelTier::available() {
        for threads in [1usize, 2, 7] {
            let pool = Pool::with_config(threads, tier);
            let mut acc = vec![0.0f32; m * n];
            linalg::matmul_acc(&pool, &x, &w, m, k, n, &mut acc);
            assert_close(&acc, &acc_ref, "acc/padded");
            // Padded output rows are exactly zero, not approximately.
            assert!(acc[valid * n..].iter().all(|&v| v == 0.0));

            let mut at = vec![0.0f32; k * n];
            linalg::matmul_at(&pool, &x, &dy, m, k, n, &mut at);
            assert_close(&at, &at_ref, "at/padded");

            let mut bt = vec![0.0f32; m * k];
            linalg::matmul_bt(&pool, &dy, &w, m, k, n, &mut bt);
            assert_close(&bt, &bt_ref, "bt/padded");
            assert!(bt[valid * k..].iter().all(|&v| v == 0.0));

            let mut panels = PanelCache::default();
            let mut btp = vec![0.0f32; m * k];
            linalg::matmul_bt_ws(&pool, &mut panels, 1, 0, &dy, &w, m, k, n, &mut btp);
            assert_close(&btp, &bt_ref, "bt_packed/padded");
            assert!(btp[valid * k..].iter().all(|&v| v == 0.0));
        }
    }
}

#[test]
fn accumulating_kernels_add_to_existing_partial_sums() {
    // matmul_acc / matmul_at accumulate; threading must not clobber the
    // caller's partial sums.
    let mut rng = Rng::new(11);
    let (m, k, n) = (128usize, 64usize, 40usize);
    let x = rand_vec(&mut rng, m * k);
    let w = rand_vec(&mut rng, k * n);
    let seed = rand_vec(&mut rng, m * n);

    let mut want = seed.clone();
    scalar::matmul_acc(&x, &w, m, k, n, &mut want);
    for tier in KernelTier::available() {
        for threads in [1usize, 3] {
            let mut got = seed.clone();
            linalg::matmul_acc(&Pool::with_config(threads, tier), &x, &w, m, k, n, &mut got);
            assert_close(&got, &want, "acc/partial");
        }
    }
}

#[test]
fn train_step_is_stable_across_thread_counts() {
    // Full train-step parity per tier: the row partition assigns every
    // output row to exactly one chunk and preserves per-row summation
    // order, so params and loss agree bitwise across DYNAMIX_THREADS.
    let mut rng = Rng::new(5);
    let bucket = 256usize;
    let fd = 128usize;
    let x: Vec<f32> = rand_vec(&mut rng, bucket * fd);
    let y: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
    let mask = vec![1.0f32; bucket];

    let run = |threads: usize, tier: KernelTier| -> (Vec<u32>, Vec<u32>) {
        let b = NativeBackend::with_kernel(threads, tier);
        let mut state = OptState::new(b.init_params("vgg11_mini", 3).unwrap(), Optimizer::Sgd);
        let mut losses = Vec::new();
        for _ in 0..3 {
            let out = b
                .train_step("vgg11_mini", Optimizer::Sgd, bucket, &mut state, &x, &y, &mask, 0.05)
                .unwrap();
            losses.push(out.loss.to_bits());
        }
        (losses, state.params.iter().map(|p| p.to_bits()).collect())
    };

    for tier in KernelTier::available() {
        let (loss1, params1) = run(1, tier);
        for threads in [2usize, 7] {
            let (loss_t, params_t) = run(threads, tier);
            assert_eq!(loss_t, loss1, "{}: loss diverged at t={threads}", tier.as_str());
            assert_eq!(
                params_t, params1,
                "{}: params diverged at t={threads}",
                tier.as_str()
            );
        }
    }
}

#[test]
fn tiers_agree_on_the_full_train_step_within_tolerance() {
    // Cross-tier: the same 3-step run through each tier lands within the
    // kernels' float tolerance of the scalar tier (the tiers reassociate
    // forward/input-grad arithmetic, so bits may differ; 1e-5 may not).
    let mut rng = Rng::new(29);
    let bucket = 128usize;
    let fd = 128usize;
    let x: Vec<f32> = rand_vec(&mut rng, bucket * fd);
    let y: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
    let mask = vec![1.0f32; bucket];
    let run = |tier: KernelTier| -> (Vec<f32>, Vec<f32>) {
        let b = NativeBackend::with_kernel(1, tier);
        let mut state = OptState::new(b.init_params("vgg11_mini", 3).unwrap(), Optimizer::Sgd);
        let mut losses = Vec::new();
        for _ in 0..3 {
            let out = b
                .train_step("vgg11_mini", Optimizer::Sgd, bucket, &mut state, &x, &y, &mask, 0.05)
                .unwrap();
            losses.push(out.loss);
        }
        (losses, state.params)
    };
    let (loss_s, params_s) = run(KernelTier::Scalar);
    for tier in [KernelTier::Blocked, KernelTier::Simd] {
        let (loss_t, params_t) = run(tier);
        for (a, b) in loss_t.iter().zip(&loss_s) {
            assert!((a - b).abs() <= 1e-4, "{tier:?}: loss {a} vs scalar {b}");
        }
        for (i, (a, b)) in params_t.iter().zip(&params_s).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "{tier:?}: param {i} {a} vs scalar {b}"
            );
        }
    }
}

#[test]
fn dynamix_env_controls_pool_config() {
    // This is the only test in this binary that touches the process env:
    // every other test pins thread counts and tiers via Pool::with_config
    // / NativeBackend::with_kernel, which never read the environment, so
    // set_var here cannot race a concurrent getenv. Pool::from_env is the
    // uncached reader; the cached Pool::global is deliberately NOT
    // re-read (one read per process is the contract).
    let prev_t = std::env::var("DYNAMIX_THREADS").ok(); // lint:allow(env-read): this test exercises the env plumbing itself and must save/restore raw values.
    let prev_k = std::env::var("DYNAMIX_KERNEL").ok(); // lint:allow(env-read): this test exercises the env plumbing itself and must save/restore raw values.
    std::env::set_var("DYNAMIX_THREADS", "7");
    assert_eq!(Pool::from_env().threads(), 7);
    std::env::set_var("DYNAMIX_THREADS", "not-a-number");
    assert!(Pool::from_env().threads() >= 1);
    std::env::set_var("DYNAMIX_KERNEL", "scalar");
    assert_eq!(Pool::from_env().tier(), KernelTier::Scalar);
    std::env::set_var("DYNAMIX_KERNEL", "simd");
    let want = if simd_supported() { KernelTier::Simd } else { KernelTier::Blocked };
    assert_eq!(Pool::from_env().tier(), want, "simd resolves to a supported tier");
    std::env::set_var("DYNAMIX_KERNEL", "nonsense");
    assert_ne!(Pool::from_env().tier(), KernelTier::Scalar, "garbage falls back to auto");
    match prev_t {
        Some(v) => std::env::set_var("DYNAMIX_THREADS", v),
        None => std::env::remove_var("DYNAMIX_THREADS"),
    }
    match prev_k {
        Some(v) => std::env::set_var("DYNAMIX_KERNEL", v),
        None => std::env::remove_var("DYNAMIX_KERNEL"),
    }
}
