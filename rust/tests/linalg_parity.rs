//! Parity suite for the blocked + threaded native linalg kernels.
//!
//! The blocked kernels reorder floating-point accumulation (lane-wise
//! partial sums, 4-way reduction unrolls) and fan rows out across scoped
//! threads, so they are held to the scalar reference loops within 1e-5 on
//! randomized inputs — across awkward shapes (m=1, odd n, n not a multiple
//! of the lane/tile width, k=1) and across DYNAMIX_THREADS = 1, 2, 7 —
//! and the whole train step is held bitwise-stable across thread counts.

use dynamix::config::Optimizer;
use dynamix::runtime::native::exec::Pool;
use dynamix::runtime::native::linalg::{self, scalar};
use dynamix::runtime::native::NativeBackend;
use dynamix::runtime::{ComputeBackend, OptState};
use dynamix::util::rng::Rng;

/// Awkward shapes: unit dims, odd everything, off-lane/off-tile widths,
/// and one large-enough-to-actually-thread case.
const SHAPES: [(usize, usize, usize); 11] = [
    (1, 1, 1),
    (1, 7, 5),
    (3, 1, 9),
    (5, 13, 1),
    (2, 3, 8),
    (17, 31, 40),
    (7, 129, 33),
    (33, 64, 10),
    (64, 128, 64),
    (256, 65, 17),
    (512, 96, 40), // large enough to fan out across every thread count
];

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
            "{what}[{i}]: {a} vs {b}"
        );
    }
}

#[test]
fn blocked_kernels_match_scalar_reference_across_shapes_and_threads() {
    let mut rng = Rng::new(0xD1A);
    for &(m, k, n) in &SHAPES {
        let x = rand_vec(&mut rng, m * k);
        let w = rand_vec(&mut rng, k * n);
        let dy = rand_vec(&mut rng, m * n);

        let mut acc_ref = vec![0.0f32; m * n];
        scalar::matmul_acc(&x, &w, m, k, n, &mut acc_ref);
        let mut bt_ref = vec![0.0f32; m * k];
        scalar::matmul_bt(&dy, &w, m, k, n, &mut bt_ref);
        let mut at_ref = vec![0.0f32; k * n];
        scalar::matmul_at(&x, &dy, m, k, n, &mut at_ref);

        for threads in [1usize, 2, 7] {
            let pool = Pool::with_threads(threads);
            let tag = format!("m{m}k{k}n{n}t{threads}");

            let mut acc = vec![0.0f32; m * n];
            linalg::matmul_acc(&pool, &x, &w, m, k, n, &mut acc);
            assert_close(&acc, &acc_ref, &format!("acc/{tag}"));

            let mut bt = vec![0.0f32; m * k];
            linalg::matmul_bt(&pool, &dy, &w, m, k, n, &mut bt);
            assert_close(&bt, &bt_ref, &format!("bt/{tag}"));

            let mut at = vec![0.0f32; k * n];
            linalg::matmul_at(&pool, &x, &dy, m, k, n, &mut at);
            assert_close(&at, &at_ref, &format!("at/{tag}"));
        }
    }
}

#[test]
fn padded_zero_rows_cost_nothing_and_change_nothing() {
    // The row-level sparsity skip must be purely an optimization: results
    // with padded (all-zero) trailing rows equal the scalar reference.
    let mut rng = Rng::new(7);
    let (m, k, n) = (24usize, 33usize, 20usize);
    let valid = 9usize;
    let mut x = rand_vec(&mut rng, m * k);
    let mut dy = rand_vec(&mut rng, m * n);
    for v in &mut x[valid * k..] {
        *v = 0.0;
    }
    for v in &mut dy[valid * n..] {
        *v = 0.0;
    }
    let w = rand_vec(&mut rng, k * n);

    let mut acc_ref = vec![0.0f32; m * n];
    scalar::matmul_acc(&x, &w, m, k, n, &mut acc_ref);
    let mut at_ref = vec![0.0f32; k * n];
    scalar::matmul_at(&x, &dy, m, k, n, &mut at_ref);
    let mut bt_ref = vec![0.0f32; m * k];
    scalar::matmul_bt(&dy, &w, m, k, n, &mut bt_ref);

    for threads in [1usize, 2, 7] {
        let pool = Pool::with_threads(threads);
        let mut acc = vec![0.0f32; m * n];
        linalg::matmul_acc(&pool, &x, &w, m, k, n, &mut acc);
        assert_close(&acc, &acc_ref, "acc/padded");
        // Padded output rows are exactly zero, not approximately.
        assert!(acc[valid * n..].iter().all(|&v| v == 0.0));

        let mut at = vec![0.0f32; k * n];
        linalg::matmul_at(&pool, &x, &dy, m, k, n, &mut at);
        assert_close(&at, &at_ref, "at/padded");

        let mut bt = vec![0.0f32; m * k];
        linalg::matmul_bt(&pool, &dy, &w, m, k, n, &mut bt);
        assert_close(&bt, &bt_ref, "bt/padded");
        assert!(bt[valid * k..].iter().all(|&v| v == 0.0));
    }
}

#[test]
fn accumulating_kernels_add_to_existing_partial_sums() {
    // matmul_acc / matmul_at accumulate; threading must not clobber the
    // caller's partial sums.
    let mut rng = Rng::new(11);
    let (m, k, n) = (128usize, 64usize, 40usize);
    let x = rand_vec(&mut rng, m * k);
    let w = rand_vec(&mut rng, k * n);
    let seed = rand_vec(&mut rng, m * n);

    let mut want = seed.clone();
    scalar::matmul_acc(&x, &w, m, k, n, &mut want);
    for threads in [1usize, 3] {
        let mut got = seed.clone();
        linalg::matmul_acc(&Pool::with_threads(threads), &x, &w, m, k, n, &mut got);
        assert_close(&got, &want, "acc/partial");
    }
}

#[test]
fn train_step_is_stable_across_thread_counts() {
    // Full train-step parity: the row partition assigns every output row to
    // exactly one thread and preserves per-row summation order, so params
    // and loss agree across DYNAMIX_THREADS settings (well within the 1e-5
    // contract; bitwise in practice).
    let mut rng = Rng::new(5);
    let bucket = 256usize;
    let fd = 128usize;
    let x: Vec<f32> = rand_vec(&mut rng, bucket * fd);
    let y: Vec<i32> = (0..bucket).map(|_| rng.below(10) as i32).collect();
    let mask = vec![1.0f32; bucket];

    let run = |threads: usize| -> (Vec<f32>, Vec<f32>) {
        let b = NativeBackend::with_threads(threads);
        let mut state = OptState::new(b.init_params("vgg11_mini", 3).unwrap(), Optimizer::Sgd);
        let mut losses = Vec::new();
        for _ in 0..3 {
            let out = b
                .train_step("vgg11_mini", Optimizer::Sgd, bucket, &mut state, &x, &y, &mask, 0.05)
                .unwrap();
            losses.push(out.loss);
        }
        (losses, state.params)
    };

    let (loss1, params1) = run(1);
    for threads in [2usize, 7] {
        let (loss_t, params_t) = run(threads);
        for (a, b) in loss_t.iter().zip(&loss1) {
            assert!((a - b).abs() <= 1e-5, "loss diverged at t={threads}: {a} vs {b}");
        }
        for (i, (a, b)) in params_t.iter().zip(&params1).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "param {i} diverged at t={threads}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn dynamix_threads_env_controls_pool_size() {
    // This is the only test in this binary that touches the process env:
    // every other test pins thread counts via Pool::with_threads /
    // NativeBackend::with_threads, which never read DYNAMIX_THREADS, so
    // set_var here cannot race a concurrent getenv.
    let prev = std::env::var("DYNAMIX_THREADS").ok();
    std::env::set_var("DYNAMIX_THREADS", "7");
    assert_eq!(Pool::from_env().threads(), 7);
    std::env::set_var("DYNAMIX_THREADS", "not-a-number");
    assert!(Pool::from_env().threads() >= 1);
    match prev {
        Some(v) => std::env::set_var("DYNAMIX_THREADS", v),
        None => std::env::remove_var("DYNAMIX_THREADS"),
    }
}
