//! Wire-codec hot-path parity suite.
//!
//! The top-k encoder replaced its full sort with an O(n) quickselect
//! partition, and the q8 codec grew AVX2 lanes behind the process-wide
//! kernel tier. Neither is allowed to change a single wire byte: the
//! partial select is pinned bit-identical to the sort-based reference on
//! random AND adversarial-tie inputs, the dispatched q8 codec is pinned
//! byte-identical to an in-test scalar transliteration on every length
//! crossing a lane boundary (the CI kernel sweep runs this binary under
//! `DYNAMIX_KERNEL=scalar|blocked|simd`, so the SIMD lanes are held to
//! the same bytes as the scalar loops), and the `_into` variants must be
//! indistinguishable from the owned wrappers even when their buffers are
//! recycled across differently-shaped calls. The last test pins the
//! zero-allocation property the `_into` family exists for: a shard
//! server's decode/fold/re-encode scratch stops growing after warmup.

use dynamix::comm::wire;
use dynamix::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// The historical sort-based top-k reference: order EVERY index by
/// (|v| bits desc, index asc), keep the first k, emit in index order.
fn topk_sort_ref(x: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let k = wire::topk_k(x.len());
    let mut order: Vec<u32> = (0..x.len() as u32).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(x[i as usize].abs().to_bits()), i));
    let mut idx: Vec<u32> = order[..k].to_vec();
    idx.sort_unstable();
    let val = idx.iter().map(|&i| x[i as usize]).collect();
    (idx, val)
}

/// Scalar transliteration of the q8 encoder (the pre-SIMD loop).
fn q8_scalar_ref(x: &[f32]) -> (f32, Vec<i8>) {
    let max_bits = x.iter().map(|v| v.abs().to_bits()).max().unwrap_or(0);
    let e = ((max_bits >> 23) & 0xFF) as i32 - 127;
    if max_bits == 0 || !(-120..=127).contains(&e) {
        return (0.0, vec![0; x.len()]);
    }
    let scale = f32::from_bits(((e - 6 + 127) as u32) << 23);
    let q = x
        .iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (scale, q)
}

fn assert_topk_eq(x: &[f32], what: &str) {
    let (idx, val) = wire::topk_encode(x);
    let (ridx, rval) = topk_sort_ref(x);
    assert_eq!(idx, ridx, "{what}: kept index set diverged from sort reference");
    let got: Vec<u32> = val.iter().map(|v| v.to_bits()).collect();
    let want: Vec<u32> = rval.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got, want, "{what}: kept values diverged from sort reference");
}

#[test]
fn topk_partial_select_matches_sort_reference_on_random_inputs() {
    let mut rng = Rng::new(0x70CC);
    for &len in &[1usize, 2, 3, 4, 5, 7, 8, 9, 31, 64, 100, 1000, 4097] {
        for round in 0..4 {
            let x = rand_vec(&mut rng, len);
            assert_topk_eq(&x, &format!("random len={len} round={round}"));
        }
    }
}

#[test]
fn topk_partial_select_matches_sort_reference_on_adversarial_ties() {
    // Magnitude ties are where an unstable partition could legally differ
    // from an unstable sort — the (|v| bits, index) key must make the
    // outcome unique anyway.
    let cases: Vec<(&str, Vec<f32>)> = vec![
        ("all equal", vec![1.0; 37]),
        ("all zero", vec![0.0; 16]),
        ("signed zeros", vec![0.0, -0.0, 0.0, -0.0, 0.0, -0.0, 0.0, -0.0]),
        ("sign-only ties", vec![2.0, -2.0, 2.0, -2.0, 2.0, -2.0, 2.0, -2.0, 2.0]),
        (
            "two magnitude classes straddling k",
            // k = 3 of 12; five elements tie at the cut magnitude.
            vec![9.0, 5.0, 5.0, -5.0, 5.0, -5.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
        ),
        ("tie exactly at the cut", vec![3.0, 3.0, 3.0, 3.0]),
        (
            "non-finite payloads",
            vec![f32::INFINITY, f32::NAN, -f32::INFINITY, f32::NAN, 1.0, 0.0, -1.0, f32::MAX],
        ),
        ("descending already", (0..33).map(|i| 33.0 - i as f32).collect()),
        ("ascending worst case", (0..33).map(|i| i as f32).collect()),
    ];
    for (what, x) in &cases {
        assert_topk_eq(x, what);
    }
    // Dense tie grids at every small length (select pivot paths differ by
    // length parity and k position).
    for len in 1..=24usize {
        let x: Vec<f32> = (0..len).map(|i| if i % 2 == 0 { 4.0 } else { -4.0 }).collect();
        assert_topk_eq(&x, &format!("tie grid len={len}"));
    }
}

#[test]
fn q8_codec_matches_scalar_reference_bytes() {
    let mut rng = Rng::new(0x9B);
    // Every length crossing the 8-lane boundary, random payloads.
    for len in 1..=33usize {
        for round in 0..3 {
            let x = rand_vec(&mut rng, len);
            let (scale, q) = wire::q8_encode(&x);
            let (rs, rq) = q8_scalar_ref(&x);
            assert_eq!(scale.to_bits(), rs.to_bits(), "scale len={len} round={round}");
            assert_eq!(q, rq, "bytes len={len} round={round}");
            // Decode parity: q·scale is one exact multiply in every lane.
            let dec = wire::q8_decode(scale, &q).unwrap();
            for (i, (d, &b)) in dec.iter().zip(&q).enumerate() {
                assert_eq!(
                    d.to_bits(),
                    (b as f32 * scale).to_bits(),
                    "decode[{i}] len={len}"
                );
            }
        }
    }
    // Engineered rounding ties: max |v| = 64.0 pins e = 6, scale = 1.0,
    // so each t = v/scale tie sits exactly on a half. Half-away-from-zero
    // must survive the SIMD lane's half-to-even roundps + correction.
    let ties = vec![
        64.0, 2.5, -2.5, 0.5, -0.5, 1.5, -1.5, 63.5, -63.5, 3.5, -3.5, 10.5, -10.5, 0.0, -0.0,
        7.5, -7.5,
    ];
    let (scale, q) = wire::q8_encode(&ties);
    assert_eq!(scale, 1.0, "64.0 window must quantize at scale 1.0");
    let (rs, rq) = q8_scalar_ref(&ties);
    assert_eq!(scale.to_bits(), rs.to_bits());
    assert_eq!(q, rq, "tie bytes diverged from round-half-away reference");
    assert_eq!(q[1], 3, "2.5 rounds away from zero");
    assert_eq!(q[2], -3, "-2.5 rounds away from zero");
    assert_eq!(q[3], 1, "0.5 rounds away from zero");
    assert_eq!(q[4], -1, "-0.5 rounds away from zero");
    // Same ties at a non-unit power-of-two scale (max 128.0 → scale 2.0).
    let scaled: Vec<f32> = ties.iter().map(|v| v * 2.0).collect();
    let (scale2, q2) = wire::q8_encode(&scaled);
    assert_eq!(scale2, 2.0);
    assert_eq!(q2, rq, "scaling by the wire's own power of two must not move any byte");
    // Degenerate windows flush identically through both paths.
    for degenerate in [vec![0.0f32; 9], vec![f32::NAN; 5], vec![1e-39f32; 7]] {
        let (scale, q) = wire::q8_encode(&degenerate);
        let (rs, rq) = q8_scalar_ref(&degenerate);
        assert_eq!(scale.to_bits(), rs.to_bits());
        assert_eq!(q, rq);
        assert_eq!(scale, 0.0, "degenerate window must flush to scale 0");
        assert!(q.iter().all(|&b| b == 0));
    }
}

#[test]
fn into_variants_match_owned_wrappers_across_recycled_buffers() {
    // One set of buffers, reused across differently-sized windows in both
    // directions (grow, shrink, grow) — every call must behave exactly
    // like a fresh owned-wrapper call.
    let mut rng = Rng::new(0x1E70);
    let (mut order, mut idx, mut val) = (Vec::new(), Vec::new(), Vec::new());
    let (mut q, mut dense) = (Vec::new(), Vec::new());
    for &len in &[100usize, 9, 1000, 1, 64, 0, 33] {
        let x = rand_vec(&mut rng, len);

        wire::topk_encode_into(&x, &mut order, &mut idx, &mut val);
        let (oidx, oval) = wire::topk_encode(&x);
        assert_eq!(idx, oidx, "topk_encode_into len={len}");
        assert_eq!(
            val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            oval.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "topk_encode_into values len={len}"
        );
        wire::topk_decode_into(len, &idx, &val, &mut dense).unwrap();
        let owned = wire::topk_decode(len, &idx, &val).unwrap();
        assert_eq!(
            dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            owned.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "topk_decode_into len={len}"
        );

        let scale = wire::q8_encode_into(&x, &mut q);
        let (oscale, oq) = wire::q8_encode(&x);
        assert_eq!(scale.to_bits(), oscale.to_bits(), "q8_encode_into scale len={len}");
        assert_eq!(q, oq, "q8_encode_into bytes len={len}");
        wire::q8_decode_into(scale, &q, &mut dense).unwrap();
        let owned = wire::q8_decode(scale, &q).unwrap();
        assert_eq!(
            dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            owned.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "q8_decode_into len={len}"
        );
    }
    // A failed decode must not poison the buffers for the next call.
    assert!(wire::topk_decode_into(50, &[60], &[1.0; 1], &mut dense).is_err());
    assert!(wire::q8_decode_into(f32::NAN, &[1, 2, 3], &mut dense).is_err());
    wire::q8_decode_into(0.5, &[2, -4], &mut dense).unwrap();
    assert_eq!(dense, vec![1.0, -2.0]);
}

#[test]
fn worker_slice_hops_allocate_nothing_at_steady_state() {
    use dynamix::comm::ShardRows;
    use dynamix::runtime::native::NativeBackend;
    use dynamix::runtime::sharded::transport::ShardMsg;
    use dynamix::runtime::sharded::worker::ShardServer;
    use dynamix::runtime::ComputeBackend;
    use std::sync::Arc;

    let b = Arc::new(NativeBackend::with_threads(1));
    let fd = b.schema().feature_dim;
    let params = Arc::new(b.init_params("vgg11_mini", 0).unwrap());
    let pc = params.len();
    let mut s = ShardServer::new(b);
    let mut rng = Rng::new(0xA110C);

    let mut warm_capacity = 0usize;
    for hop in 0..8u64 {
        let seq = hop + 1;
        s.handle(ShardMsg::Step {
            seq,
            denom: 2.0,
            train: true,
            rows: Some(ShardRows {
                model: "vgg11_mini".into(),
                x: (0..2 * fd).map(|_| rng.normal() as f32).collect(),
                y: vec![0, 1],
                mask: vec![1.0, 1.0],
            }),
            params: Some(Arc::clone(&params)),
        })
        .unwrap()
        .unwrap();
        // Alternate the compressed wire modes so BOTH decode paths and
        // both re-encodes run through the same scratch.
        let window = rand_vec(&mut rng, pc);
        let reply = if hop % 2 == 0 {
            let (idx, val) = wire::topk_encode(&window);
            s.handle_slice(ShardMsg::GradTopK { seq, slice: 0, offset: 0, len: pc, idx, val })
                .unwrap()
        } else {
            let (scale, q) = wire::q8_encode(&window);
            s.handle_slice(ShardMsg::GradQ8 { seq, slice: 0, offset: 0, scale, q }).unwrap()
        };
        match reply {
            ShardMsg::GradTopK { len, .. } => assert_eq!(len, pc),
            ShardMsg::GradQ8 { ref q, .. } => assert_eq!(q.len(), pc),
            other => panic!("unexpected slice reply {other:?}"),
        }
        s.bucket_retire(seq).unwrap();

        // Both wire modes have passed through once after hop 1: from then
        // on the decode/fold/re-encode scratch must never grow again.
        if hop == 1 {
            warm_capacity = s.scratch_capacity_bytes();
            assert!(warm_capacity > 0, "scratch should be warm after both wire modes");
        } else if hop > 1 {
            assert_eq!(
                s.scratch_capacity_bytes(),
                warm_capacity,
                "steady-state hop {hop} grew the decode/fold scratch"
            );
        }
    }
}
