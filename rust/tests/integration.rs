//! Cross-module integration tests: the full L3 stack over real artifacts.
//!
//! These exercise the same paths as the experiment harness at miniature
//! scale: coordinator episodes, frozen-policy inference, baselines on the
//! identical substrate, the distributed TCP deployment, and the
//! manifest/artifact contract.

use dynamix::baselines::{run_baseline, GnsHeuristicPolicy, SmithSchedulePolicy, StaticPolicy};
use dynamix::config::{presets, ExperimentConfig, Optimizer, PpoVariant, Scale, Topology};
use dynamix::coordinator::Coordinator;
use dynamix::metrics::RunRecord;
use dynamix::runtime::{default_backend, Backend};

fn store() -> Backend {
    default_backend().expect("backend selection failed")
}

fn tiny_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.cluster.n_workers = 3;
    c.batch.initial = 64;
    c.rl.k = 2;
    c.steps_per_episode = 3;
    c.train.max_steps = 60;
    c
}

#[test]
fn full_rl_pipeline_train_then_infer() {
    let mut coord = Coordinator::new(tiny_cfg(), store()).unwrap();
    let eps = coord.train_rl(2).unwrap();
    assert_eq!(eps.len(), 2);
    let mut record = RunRecord::new("int-infer");
    let summary = coord.run_inference(4, &mut record).unwrap();
    assert!(summary.total_iters >= 2);
    assert!(record.points.iter().all(|p| p.eval_acc >= 0.0 && p.eval_acc <= 1.0));
    // Batch sizes always within the paper's constraints after any cycle.
    assert!(coord.trainer.batches.iter().all(|&b| (32..=1024).contains(&b)));
}

#[test]
fn policy_transfer_roundtrip_across_models() {
    // Train on vgg11, transfer to vgg16 (different param count model,
    // same policy artifact) — the Fig.6 mechanism end to end.
    let s = store();
    let mut src = Coordinator::new(tiny_cfg(), s.clone()).unwrap();
    src.train_rl(1).unwrap();
    let theta = src.agent.theta_snapshot().unwrap();

    let mut cfg = tiny_cfg();
    cfg.train.model = "vgg16_mini".into();
    let mut dst = Coordinator::new(cfg, s).unwrap();
    dst.agent.load_theta(&theta).unwrap();
    let mut record = RunRecord::new("int-transfer");
    let summary = dst.run_inference(3, &mut record).unwrap();
    assert!(summary.final_eval_acc > 0.0);
}

#[test]
fn baselines_and_dynamix_share_substrate() {
    // Same config, same seed: static baseline vs coordinator run must see
    // the exact same simulated cluster cost structure (deterministic).
    let cfg = tiny_cfg();
    let mut r1 = RunRecord::new("int-static-a");
    let mut r2 = RunRecord::new("int-static-b");
    let s1 = run_baseline(&cfg, store(), &mut StaticPolicy(64), 3, &mut r1).unwrap();
    let s2 = run_baseline(&cfg, store(), &mut StaticPolicy(64), 3, &mut r2).unwrap();
    assert_eq!(s1.total_iters, s2.total_iters);
    // The training math is bit-deterministic (same seeds, same artifacts);
    // simulated time varies slightly because the cost model is calibrated
    // from a real wall-clock PJRT measurement at startup.
    let rel = (s1.total_sim_time - s2.total_sim_time).abs() / s1.total_sim_time;
    assert!(rel < 0.5, "sim time drifted too far: {} vs {}", s1.total_sim_time, s2.total_sim_time);
    for (a, b) in r1.points.iter().zip(&r2.points) {
        assert_eq!(a.eval_acc, b.eval_acc, "training math must be deterministic");
        assert_eq!(a.loss, b.loss);
    }
}

#[test]
fn heuristic_baselines_run_end_to_end() {
    let cfg = tiny_cfg();
    let mut rec = RunRecord::new("int-smith");
    let mut smith = SmithSchedulePolicy { initial: 64, factor: 2, every: 1 };
    let s = run_baseline(&cfg, store(), &mut smith, 3, &mut rec).unwrap();
    assert!(s.total_iters > 0);
    // Batch should have grown across cycles.
    assert!(rec.points.last().unwrap().batch_mean > rec.points[0].batch_mean);

    let mut rec = RunRecord::new("int-gns");
    let mut gns = GnsHeuristicPolicy::default();
    run_baseline(&cfg, store(), &mut gns, 3, &mut rec).unwrap();
    assert_eq!(rec.points.len(), 3);
}

#[test]
fn parameter_server_topology_runs() {
    let mut cfg = tiny_cfg();
    cfg.cluster.topology = Topology::ParameterServer { servers: 2 };
    cfg.cluster.preset = dynamix::config::ClusterPreset::FabricHetero;
    cfg.cluster.n_workers = 4;
    let mut coord = Coordinator::new(cfg, store()).unwrap();
    let mut record = RunRecord::new("int-ps");
    let summary = coord.run_inference(3, &mut record).unwrap();
    assert!(summary.total_sim_time > 0.0);
}

#[test]
fn adam_pipeline_runs_with_eta_penalty() {
    let mut cfg = tiny_cfg();
    cfg.train.optimizer = Optimizer::Adam;
    cfg.train.lr = 0.002;
    let mut coord = Coordinator::new(cfg, store()).unwrap();
    let eps = coord.train_rl(1).unwrap();
    assert!(eps[0].mean_return.is_finite());
}

#[test]
fn simplified_ppo_variant_full_loop() {
    let mut cfg = tiny_cfg();
    cfg.rl.variant = PpoVariant::Simplified;
    let mut coord = Coordinator::new(cfg, store()).unwrap();
    let eps = coord.train_rl(1).unwrap();
    assert!(eps[0].update.minibatches > 0);
}

#[test]
fn feature_ablations_zero_state_features() {
    let mut cfg = tiny_cfg();
    cfg.rl.use_network_features = false;
    cfg.rl.use_grad_stats_features = false;
    let mut coord = Coordinator::new(cfg, store()).unwrap();
    // Must still train/act without those features.
    let eps = coord.train_rl(1).unwrap();
    assert_eq!(eps.len(), 1);
}

#[test]
fn distributed_tcp_leader_and_workers() {
    use dynamix::comm::leader;
    let bind = "127.0.0.1:17911";
    let lh = std::thread::spawn(move || leader::serve_n(bind, "vgg11-sgd", Scale::Quick, 2, 3));
    std::thread::sleep(std::time::Duration::from_millis(300));
    let mut ws = Vec::new();
    for id in 0..2u32 {
        ws.push(std::thread::spawn(move || {
            leader::worker(bind, "vgg11-sgd", Scale::Quick, id)
        }));
    }
    for w in ws {
        w.join().unwrap().unwrap();
    }
    lh.join().unwrap().unwrap();
}

#[test]
fn every_preset_constructs_a_coordinator() {
    // Catch preset/artifact drift: every named preset must map onto
    // existing artifacts and validate.
    let s = store();
    for name in presets::ALL {
        let cfg = presets::scaled(presets::by_name(name).unwrap(), Scale::Quick);
        let coord = Coordinator::new(cfg, s.clone());
        assert!(coord.is_ok(), "preset {name}: {:?}", coord.err());
    }
}

#[test]
fn backend_schema_is_uniform_and_ladder_shaped() {
    let s = store();
    let schema = s.schema();
    assert!(schema.buckets.windows(2).all(|w| w[0] < w[1]), "buckets unsorted");
    assert_eq!(schema.state_dim, 16);
    assert_eq!(schema.n_actions, 5);
    // Depth ladders within each family must order parameter counts, so the
    // Fig. 6 transfer pairs (shallow -> deep) stay meaningful.
    let pc = |m: &str| schema.model(m).unwrap().param_count;
    assert!(pc("vgg11_mini") < pc("vgg16_mini"));
    assert!(pc("vgg16_mini") < pc("vgg19_mini"));
    assert!(pc("resnet34_mini") < pc("resnet50_mini"));
    // Every model's init snapshot matches its declared parameter count.
    for (name, info) in &schema.models {
        let p = s.init_params(name, 0).unwrap();
        assert_eq!(p.len(), info.param_count, "{name}");
        assert!(p.iter().all(|v| v.is_finite()), "{name}");
    }
    let pol = s.init_policy(0).unwrap();
    assert_eq!(pol.len(), schema.policy_param_count);
}

#[test]
fn run_records_persist_and_reload() {
    let cfg = tiny_cfg();
    let mut record = RunRecord::new("int-persist");
    run_baseline(&cfg, store(), &mut StaticPolicy(96), 2, &mut record).unwrap();
    let dir = std::env::temp_dir().join("dynamix_int_persist");
    std::fs::create_dir_all(&dir).unwrap();
    let jpath = dir.join("r.json");
    let cpath = dir.join("r.csv");
    record.save_json(&jpath).unwrap();
    record.save_csv(&cpath).unwrap();
    let loaded = dynamix::util::json::Json::parse(&std::fs::read_to_string(&jpath).unwrap()).unwrap();
    assert_eq!(
        loaded.get("points").unwrap().as_arr().unwrap().len(),
        record.points.len()
    );
    std::fs::remove_dir_all(&dir).ok();
}
