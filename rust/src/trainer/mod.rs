//! BSP distributed-training loop over the backend train-step kernels.
//!
//! Two pieces:
//!
//! * [`ModelRuntime`]  — owns the flat model/optimizer state and drives the
//!   backend's per-bucket train/eval steps.
//! * [`BspTrainer`]    — one global BSP iteration at a time:
//!   1. every worker draws its shard indices (`data::ShardSampler`);
//!   2. the per-worker batches are concatenated, padded to the bucket
//!      ladder and masked, and executed as ONE train step — mathematically
//!      identical to per-worker gradients + all-reduce averaging
//!      (DESIGN.md §Fused-global); per-sample outputs are sliced back into
//!      worker ranges for per-worker metrics;
//!   3. the cluster simulator prices each worker's compute time and the
//!      netsim prices the collective; the BSP clock advances by the
//!      straggler + sync + barrier;
//!   4. every worker's `WindowAggregator` receives its iteration sample.
//!
//! The trainer knows nothing about RL — the coordinator (or a baseline
//! schedule) mutates `batches` between iterations.

use crate::cluster::SimCluster;
use crate::config::{ExperimentConfig, Optimizer, Topology};
use crate::data::{ShardSampler, SyntheticDataset};
use crate::netsim::NetworkSim;
use crate::runtime::{Backend, OptState, Schema, TrainOut};
use crate::sysmetrics::{Collector, WindowAggregator};
use std::time::Instant;

/// Scalar outputs of one fused train step (global view). Per-sample
/// correctness stays in the runtime's reused output buffer — read it via
/// [`ModelRuntime::last_correct`] — so the hot loop copies nothing.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f64,
    pub acc: f64,
    pub sigma_norm: f64,
    pub sigma_norm2: f64,
    pub grad_l2: f64,
    /// Real wall-clock of the backend execution (perf accounting only).
    pub exec_seconds: f64,
}

/// Owns model + optimizer state; executes train/eval steps on a backend.
pub struct ModelRuntime {
    backend: Backend,
    pub model: String,
    pub optimizer: Optimizer,
    state: OptState,
    lr: f32,
    pub param_count: usize,
    pub feature_dim: usize,
    /// Total backend execution seconds + count (for §Perf / overhead).
    pub exec_seconds_total: f64,
    pub exec_count: usize,
    eval_cache: Option<(Vec<f32>, Vec<i32>, Vec<f32>)>,
    /// Persistent padding mask, rebuilt only when (n_valid, bucket) moves.
    mask_buf: Vec<f32>,
    mask_shape: (usize, usize),
    /// Reused backend output (zero steady-state allocations).
    out_buf: TrainOut,
}

impl ModelRuntime {
    pub fn new(
        backend: Backend,
        model: &str,
        optimizer: Optimizer,
        lr: f32,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let info = backend.schema().model(model)?.clone();
        let params = backend.init_params(model, seed)?;
        Ok(ModelRuntime {
            model: model.to_string(),
            optimizer,
            state: OptState::new(params, optimizer),
            lr,
            param_count: info.param_count,
            feature_dim: info.feature_dim,
            exec_seconds_total: 0.0,
            exec_count: 0,
            eval_cache: None,
            mask_buf: Vec::new(),
            mask_shape: (usize::MAX, usize::MAX),
            out_buf: TrainOut::default(),
            backend,
        })
    }

    pub fn schema(&self) -> &Schema {
        self.backend.schema()
    }

    /// Reset model + optimizer state to the seeded init snapshot
    /// (Algorithm 1 / §VI-C: every episode restarts from scratch).
    pub fn reset(&mut self, seed: u64) -> anyhow::Result<()> {
        let params = self.backend.init_params(&self.model, seed)?;
        self.state = OptState::new(params, self.optimizer);
        Ok(())
    }

    /// Gradient bytes exchanged per sync (the netsim's payload). The
    /// simulated cluster runs the paper's full-size models, so the wire
    /// payload is the full-size parameter count, not the mini stand-in's
    /// (DESIGN.md substitution table).
    pub fn grad_bytes(&self) -> usize {
        full_size_param_count(&self.model) * 4
    }

    /// Execute one fused train step on `n_valid` samples padded to
    /// `bucket`. `xs`/`ys` must already be bucket-sized. The padding mask
    /// and the backend output live in persistent buffers: at a steady
    /// (n_valid, bucket) operating point this path performs zero heap
    /// allocations and zero redundant mask writes.
    pub fn train_step(
        &mut self,
        xs: &[f32],
        ys: &[i32],
        n_valid: usize,
        bucket: usize,
    ) -> anyhow::Result<StepMetrics> {
        anyhow::ensure!(xs.len() == bucket * self.feature_dim, "xs wrong size");
        anyhow::ensure!(ys.len() == bucket, "ys wrong size");
        anyhow::ensure!(n_valid <= bucket, "n_valid > bucket");
        if self.mask_shape != (n_valid, bucket) {
            self.mask_buf.clear();
            self.mask_buf.resize(bucket, 0.0);
            self.mask_buf[..n_valid].fill(1.0);
            self.mask_shape = (n_valid, bucket);
        }

        let t0 = Instant::now();
        self.backend.train_step_into(
            &self.model,
            self.optimizer,
            bucket,
            &mut self.state,
            xs,
            ys,
            &self.mask_buf,
            self.lr,
            &mut self.out_buf,
        )?;
        let exec_seconds = t0.elapsed().as_secs_f64();
        self.exec_seconds_total += exec_seconds;
        self.exec_count += 1;

        Ok(StepMetrics {
            loss: self.out_buf.loss as f64,
            acc: self.out_buf.acc as f64,
            sigma_norm: self.out_buf.sigma_norm as f64,
            sigma_norm2: self.out_buf.sigma_norm2 as f64,
            grad_l2: self.out_buf.grad_l2 as f64,
            exec_seconds,
        })
    }

    /// Per-sample masked correctness of the most recent train step
    /// (length = that step's bucket).
    pub fn last_correct(&self) -> &[f32] {
        &self.out_buf.correct
    }

    /// Held-out evaluation on the dataset's fixed eval batch.
    pub fn eval(&mut self, dataset: &SyntheticDataset) -> anyhow::Result<(f64, f64)> {
        let eb = self.backend.schema().eval_batch;
        if self.eval_cache.is_none() {
            let (xs, ys) = dataset.eval_batch(eb);
            self.eval_cache = Some((xs, ys, vec![1.0; eb]));
        }
        let (xs, ys, mask) = self.eval_cache.as_ref().unwrap();
        let (loss, acc) = self
            .backend
            .eval_step(&self.model, &self.state.params, xs, ys, mask)?;
        Ok((loss as f64, acc as f64))
    }
}

/// Analytic full-size compute cost (A100-class reference GPU) per sample:
/// ~3x forward FLOPs / ~40 TFLOPS effective. The simulated cluster prices
/// compute with the PAPER's architectures, not the mini stand-ins, so the
/// compute/communication balance (the signal DYNAMIX exploits: larger
/// batches amortize sync) matches the real testbeds. Values in
/// microseconds per sample; fixed term = per-iteration framework/launch
/// overhead.
pub fn full_size_cost(model: &str) -> (f64, f64) {
    let us_per_sample = match model {
        "vgg11_mini" => 12.0,      // VGG11 CIFAR: ~0.46 GFLOP/sample train
        "vgg16_mini" => 24.0,      // VGG16: ~0.95 GFLOP
        "vgg19_mini" => 30.0,      // VGG19: ~1.2 GFLOP
        "resnet34_mini" => 28.0,   // ResNet34 CIFAR: ~1.1 GFLOP
        "resnet50_mini" => 34.0,   // ResNet50: ~1.3 GFLOP
        _ => 20.0,
    };
    (us_per_sample, 8_000.0) // 8 ms launch/framework overhead per iteration
}

/// Full-size parameter counts of the paper's architectures (for the
/// network payload model; the mini stand-ins keep compute CPU-feasible
/// but the fabric should carry VGG/ResNet-sized gradients).
pub fn full_size_param_count(model: &str) -> usize {
    match model {
        "vgg11_mini" => 9_231_114,        // VGG11 (CIFAR head)
        "vgg16_mini" => 14_728_266,       // VGG16
        "vgg19_mini" => 20_040_522,       // VGG19
        "resnet34_mini" => 21_328_292,    // ResNet34 (100-way head)
        "resnet50_mini" => 23_712_932,    // ResNet50
        _ => 10_000_000,
    }
}

/// One global iteration's record (consumed by metrics + the coordinator).
#[derive(Clone, Debug)]
pub struct IterationOutcome {
    pub iter: usize,
    /// Simulated wall-clock after this iteration (seconds).
    pub sim_clock: f64,
    /// Simulated duration of this iteration.
    pub sim_dt: f64,
    pub loss: f64,
    /// Global (all-worker) batch accuracy.
    pub acc: f64,
    pub sync_seconds: f64,
    pub retransmissions: u64,
    /// Global batch size this iteration (sum of worker batches).
    pub global_batch: usize,
}

/// The BSP trainer: cluster + netsim + data + model, one step at a time.
pub struct BspTrainer {
    pub runtime: ModelRuntime,
    pub cluster: SimCluster,
    pub net: NetworkSim,
    pub topology: Topology,
    pub dataset: SyntheticDataset,
    samplers: Vec<ShardSampler>,
    collectors: Vec<Collector>,
    /// Current per-worker batch sizes (mutated by coordinator/baselines).
    pub batches: Vec<usize>,
    /// Per-worker k-iteration aggregation windows.
    pub windows: Vec<WindowAggregator>,
    pub iter: usize,
    // Scratch buffers reused across iterations (hot loop stays
    // allocation-free after the first step at each bucket).
    idx_scratch: Vec<u64>,
    xs_scratch: Vec<f32>,
    ys_scratch: Vec<i32>,
    offsets_scratch: Vec<usize>,
}

impl BspTrainer {
    pub fn new(cfg: &ExperimentConfig, backend: Backend) -> anyhow::Result<Self> {
        cfg.validate()?;
        let info = backend.schema().model(&cfg.train.model)?.clone();
        let dataset = crate::data::by_name(&info.dataset, info.feature_dim, cfg.train.seed)?;
        let runtime = ModelRuntime::new(
            backend,
            &cfg.train.model,
            cfg.train.optimizer,
            cfg.train.lr,
            cfg.train.seed,
        )?;
        let n = cfg.cluster.n_workers;
        let cluster = SimCluster::new(cfg.cluster.preset, n, cfg.cluster.seed);
        let net = match cfg.cluster.preset {
            crate::config::ClusterPreset::FabricHetero
            | crate::config::ClusterPreset::SpotMarket => NetworkSim::noisy(cfg.cluster.seed),
            _ => NetworkSim::new(cfg.cluster.seed),
        };
        let samplers = (0..n)
            .map(|w| ShardSampler::new(w, n, dataset.train_size, cfg.train.seed))
            .collect();
        Ok(BspTrainer {
            runtime,
            cluster,
            net,
            topology: cfg.cluster.topology,
            dataset,
            samplers,
            collectors: (0..n).map(|_| Collector::default()).collect(),
            batches: vec![cfg.batch.initial; n],
            windows: (0..n).map(|_| WindowAggregator::default()).collect(),
            iter: 0,
            idx_scratch: Vec::new(),
            xs_scratch: Vec::new(),
            ys_scratch: Vec::new(),
            offsets_scratch: Vec::new(),
        })
    }

    pub fn n_workers(&self) -> usize {
        self.batches.len()
    }

    /// Reset for a new episode: model params, clock, load/congestion
    /// processes, per-worker batches, windows (Algorithm 1 / §VI-C).
    pub fn reset_episode(&mut self, seed: u64, initial_batch: usize) -> anyhow::Result<()> {
        self.runtime.reset(seed)?;
        self.cluster.reset(seed);
        self.net.reset(seed);
        let n = self.n_workers();
        self.samplers = (0..n)
            .map(|w| ShardSampler::new(w, n, self.dataset.train_size, seed))
            .collect();
        self.batches.fill(initial_batch);
        for w in &mut self.windows {
            *w = WindowAggregator::default();
        }
        self.iter = 0;
        Ok(())
    }

    /// Execute one global BSP iteration.
    pub fn iterate(&mut self) -> anyhow::Result<IterationOutcome> {
        let n_workers = self.n_workers();
        let fd = self.runtime.feature_dim;
        let total: usize = self.batches.iter().sum();
        let bucket = self.runtime.schema().bucket_for(total)?;

        // --- assemble the fused global batch ---
        self.xs_scratch.resize(bucket * fd, 0.0);
        self.ys_scratch.resize(bucket, 0);
        for v in &mut self.xs_scratch[total * fd..] {
            *v = 0.0;
        }
        for v in &mut self.ys_scratch[total..] {
            *v = 0;
        }
        self.offsets_scratch.clear();
        let mut row = 0usize;
        for w in 0..n_workers {
            self.offsets_scratch.push(row);
            let b = self.batches[w];
            self.samplers[w].next_indices(b, &mut self.idx_scratch);
            for (j, &idx) in self.idx_scratch.iter().enumerate() {
                let r = row + j;
                self.ys_scratch[r] = self
                    .dataset
                    .sample_into(idx, &mut self.xs_scratch[r * fd..(r + 1) * fd]);
            }
            row += b;
        }
        self.offsets_scratch.push(row);

        // --- one fused backend execution (== per-worker grads + all-reduce) ---
        let metrics = self
            .runtime
            .train_step(&self.xs_scratch, &self.ys_scratch, total, bucket)?;

        // --- price the iteration on the simulated cluster + fabric ---
        let outcomes = self.cluster.compute_phase(&self.batches);
        let profiles: Vec<_> = (0..n_workers).map(|w| self.cluster.profile(w).clone()).collect();
        let sync = self
            .net
            .sync(self.topology, &profiles, self.runtime.grad_bytes());
        let sim_dt = self.cluster.advance_iteration(&outcomes, sync.time_s);
        self.net.advance(sim_dt);

        // --- per-worker window samples ---
        let retx_per_worker = sync.retransmissions as f64 / n_workers as f64;
        for w in 0..n_workers {
            let lo = self.offsets_scratch[w];
            let hi = self.offsets_scratch[w + 1];
            let local_n = (hi - lo).max(1);
            let local_correct: f32 = self.runtime.last_correct()[lo..hi].iter().sum();
            let local_acc = local_correct as f64 / local_n as f64;
            let iter_time = outcomes[w].compute_s + sync.time_s + self.cluster.barrier_s;
            let sys = self.collectors[w].sample(
                self.cluster.profile(w),
                &outcomes[w],
                full_size_param_count(&self.runtime.model),
                self.batches[w],
            );
            self.windows[w].push_iteration(
                local_acc,
                metrics.loss,
                iter_time,
                sync.throughput_gbps,
                retx_per_worker.round() as u64,
                sys,
                metrics.sigma_norm,
                metrics.sigma_norm2,
            );
        }

        self.iter += 1;
        Ok(IterationOutcome {
            iter: self.iter,
            sim_clock: self.cluster.clock,
            sim_dt,
            loss: metrics.loss,
            acc: metrics.acc,
            sync_seconds: sync.time_s,
            retransmissions: sync.retransmissions,
            global_batch: total,
        })
    }

    /// Held-out eval accuracy: (loss, acc).
    pub fn eval(&mut self) -> anyhow::Result<(f64, f64)> {
        self.runtime.eval(&self.dataset)
    }

    /// Per-worker memory ceiling for the batch rule (§IV-C OOM clamp).
    pub fn mem_cap(&self, worker: usize, max: usize) -> usize {
        self.cluster
            .max_batch(worker, full_size_param_count(&self.runtime.model), max)
    }

    /// Calibrate the cluster cost model: simulated compute is priced from
    /// the analytic full-size table (see [`full_size_cost`]) so the
    /// compute/communication balance matches the paper's testbeds; the
    /// real backend step is still measured here and logged for §Perf.
    pub fn calibrate(&mut self) -> anyhow::Result<()> {
        let (us_per_sample, fixed_us) = full_size_cost(&self.runtime.model);
        self.cluster.cost.base_us_per_sample = us_per_sample;
        self.cluster.cost.fixed_us = fixed_us;
        // Warm the common bucket path + record a real measurement.
        let fd = self.runtime.feature_dim;
        let bucket = 256;
        let xs = vec![0.1f32; bucket * fd];
        let ys = vec![0i32; bucket];
        self.runtime.train_step(&xs, &ys, bucket, bucket)?;
        self.runtime.train_step(&xs, &ys, bucket, bucket)?;
        self.runtime.reset(0)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterPreset, ExperimentConfig};
    use crate::runtime::{native_backend, Backend};

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.n_workers = 4;
        cfg.batch.initial = 64;
        cfg.train.max_steps = 50;
        cfg
    }

    fn backend() -> Backend {
        native_backend()
    }

    #[test]
    fn iterate_advances_clock_and_learns() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        let mut first_acc = 0.0;
        let mut last_acc = 0.0;
        for i in 0..30 {
            let out = t.iterate().unwrap();
            assert!(out.sim_dt > 0.0);
            assert_eq!(out.global_batch, 4 * 64);
            if i == 0 {
                first_acc = out.acc;
            }
            last_acc = out.acc;
        }
        assert!(t.cluster.clock > 0.0);
        assert!(
            last_acc > first_acc + 0.1,
            "training did not learn: {first_acc} -> {last_acc}"
        );
    }

    #[test]
    fn per_worker_windows_fill_and_track_accuracy() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        for _ in 0..5 {
            t.iterate().unwrap();
        }
        for w in 0..4 {
            let s = t.windows[w].finish();
            assert_eq!(s.iters, 5);
            assert!(s.acc_mean >= 0.0 && s.acc_mean <= 1.0);
            assert!(s.iter_time_mean > 0.0);
        }
    }

    #[test]
    fn unequal_batches_slice_correctly() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        t.batches = vec![32, 64, 96, 128];
        let out = t.iterate().unwrap();
        assert_eq!(out.global_batch, 320);
        for w in 0..4 {
            let s = t.windows[w].finish();
            assert!((0.0..=1.0).contains(&s.acc_mean), "w{w}: {}", s.acc_mean);
        }
    }

    #[test]
    fn eval_improves_with_training() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        let (_, acc0) = t.eval().unwrap();
        for _ in 0..40 {
            t.iterate().unwrap();
        }
        let (_, acc1) = t.eval().unwrap();
        assert!(
            acc1 > acc0 + 0.15,
            "eval accuracy did not improve: {acc0} -> {acc1}"
        );
    }

    #[test]
    fn reset_episode_restores_initial_state() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        for _ in 0..10 {
            t.iterate().unwrap();
        }
        let (_, trained) = t.eval().unwrap();
        t.reset_episode(0, 64).unwrap();
        assert_eq!(t.iter, 0);
        assert_eq!(t.cluster.clock, 0.0);
        let (_, reset_acc) = t.eval().unwrap();
        assert!(
            reset_acc < trained,
            "reset did not restore params: {reset_acc} vs {trained}"
        );
        assert!(t.batches.iter().all(|&b| b == 64));
    }

    #[test]
    fn hetero_cluster_iteration_time_composition() {
        let mut cfg = small_cfg();
        cfg.cluster.preset = ClusterPreset::FabricHetero;
        cfg.cluster.n_workers = 8;
        let mut t = BspTrainer::new(&cfg, backend()).unwrap();
        t.iterate().unwrap();
        let w_fast = t.windows[0].finish();
        let w_slow = t.windows[7].finish();
        assert!(w_slow.iter_time_mean >= w_fast.iter_time_mean);
    }

    #[test]
    fn calibrate_prices_full_size_compute() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        t.calibrate().unwrap();
        assert_eq!(t.cluster.cost.base_us_per_sample, full_size_cost("vgg11_mini").0);
        assert!(t.runtime.exec_count >= 2, "real step still measured for §Perf");
    }

    #[test]
    fn full_size_cost_orders_by_architecture_depth() {
        assert!(full_size_cost("vgg11_mini").0 < full_size_cost("vgg16_mini").0);
        assert!(full_size_cost("vgg16_mini").0 < full_size_cost("vgg19_mini").0);
        assert!(full_size_cost("resnet34_mini").0 < full_size_cost("resnet50_mini").0);
    }

    #[test]
    fn full_size_params_match_paper_architectures() {
        assert!(full_size_param_count("vgg11_mini") < full_size_param_count("vgg16_mini"));
        assert!(full_size_param_count("vgg16_mini") < full_size_param_count("vgg19_mini"));
        assert!(full_size_param_count("resnet34_mini") < full_size_param_count("resnet50_mini"));
    }
}
