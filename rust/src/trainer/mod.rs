//! BSP distributed-training loop over the backend train-step kernels.
//!
//! Two pieces:
//!
//! * [`ModelRuntime`]  — owns the flat model/optimizer state and drives the
//!   backend's per-bucket train/eval steps.
//! * [`BspTrainer`]    — one global BSP iteration at a time:
//!   1. every worker draws its shard indices (`data::ShardSampler`);
//!   2. the per-worker batches are concatenated, padded to the bucket
//!      ladder and masked, and executed as ONE train step — mathematically
//!      identical to per-worker gradients + all-reduce averaging
//!      (DESIGN.md §Fused-global); per-sample outputs are sliced back into
//!      worker ranges for per-worker metrics;
//!   3. the cluster simulator prices each worker's compute time and the
//!      netsim prices the collective; the BSP clock advances by the
//!      straggler + sync + barrier;
//!   4. every worker's `WindowAggregator` receives its iteration sample.
//!
//! The trainer knows nothing about RL — the coordinator (or a baseline
//! schedule) mutates `batches` between iterations.

use crate::cluster::{ClusterState, SimCluster};
use crate::config::{ExperimentConfig, Optimizer, Topology};
use crate::data::{SamplerState, ShardSampler, SyntheticDataset};
use crate::metrics::RunRecord;
use crate::netsim::{NetSimState, NetworkSim};
use crate::runtime::{Backend, OptState, Schema, TrainOut};
use crate::sim::elastic;
use crate::sim::engine::QueueState;
use crate::sim::scenario::{ScenarioEvent, ScenarioRuntime, ScenarioScript};
use crate::sysmetrics::{Collector, WindowAggregator};
use crate::util::json::Json;
use std::time::Instant;

/// Scalar outputs of one fused train step (global view). Per-sample
/// correctness stays in the runtime's reused output buffer — read it via
/// [`ModelRuntime::last_correct`] — so the hot loop copies nothing.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f64,
    pub acc: f64,
    pub sigma_norm: f64,
    pub sigma_norm2: f64,
    pub grad_l2: f64,
    /// Real wall-clock of the backend execution (perf accounting only).
    pub exec_seconds: f64,
}

/// Owns model + optimizer state; executes train/eval steps on a backend.
pub struct ModelRuntime {
    backend: Backend,
    pub model: String,
    pub optimizer: Optimizer,
    state: OptState,
    lr: f32,
    pub param_count: usize,
    pub feature_dim: usize,
    /// Total backend execution seconds + count (for §Perf / overhead).
    pub exec_seconds_total: f64,
    pub exec_count: usize,
    eval_cache: Option<(Vec<f32>, Vec<i32>, Vec<f32>)>,
    /// Persistent padding mask, rebuilt only when (n_valid, bucket) moves.
    mask_buf: Vec<f32>,
    mask_shape: (usize, usize),
    /// Reused backend output (zero steady-state allocations).
    out_buf: TrainOut,
}

impl ModelRuntime {
    pub fn new(
        backend: Backend,
        model: &str,
        optimizer: Optimizer,
        lr: f32,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let info = backend.schema().model(model)?.clone();
        let params = backend.init_params(model, seed)?;
        Ok(ModelRuntime {
            model: model.to_string(),
            optimizer,
            state: OptState::new(params, optimizer),
            lr,
            param_count: info.param_count,
            feature_dim: info.feature_dim,
            exec_seconds_total: 0.0,
            exec_count: 0,
            eval_cache: None,
            mask_buf: Vec::new(),
            mask_shape: (usize::MAX, usize::MAX),
            out_buf: TrainOut::default(),
            backend,
        })
    }

    pub fn schema(&self) -> &Schema {
        self.backend.schema()
    }

    /// The compute backend this runtime executes on (data-plane
    /// introspection: shard count/membership mirroring).
    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    /// Reset model + optimizer state to the seeded init snapshot
    /// (Algorithm 1 / §VI-C: every episode restarts from scratch).
    pub fn reset(&mut self, seed: u64) -> anyhow::Result<()> {
        let params = self.backend.init_params(&self.model, seed)?;
        self.state = OptState::new(params, self.optimizer);
        Ok(())
    }

    /// Borrow the flat model/optimizer state (checkpointing).
    pub fn opt_state(&self) -> &OptState {
        &self.state
    }

    /// Overwrite the model/optimizer state from a checkpoint image.
    pub fn restore_opt_state(&mut self, s: &OptState) -> anyhow::Result<()> {
        anyhow::ensure!(
            s.params.len() == self.state.params.len(),
            "opt snapshot has {} params, model {:?} has {}",
            s.params.len(),
            self.model,
            self.state.params.len()
        );
        self.state = s.clone();
        Ok(())
    }

    /// Gradient bytes exchanged per sync (the netsim's payload). The
    /// simulated cluster runs the paper's full-size models, so the wire
    /// payload is the full-size parameter count, not the mini stand-in's
    /// (DESIGN.md substitution table).
    pub fn grad_bytes(&self) -> usize {
        full_size_param_count(&self.model) * 4
    }

    /// [`Self::grad_bytes`] under a slice codec: the analytic per-element
    /// payload of `DYNAMIX_WIRE` applied to the full-size parameter count
    /// (dense = 4 bytes/param, topk = 8 bytes per kept element, q8 = 1
    /// byte/param + scale). Framing is excluded on purpose — the netsim
    /// prices payload movement, and the committed `zero/bytes-per-step`
    /// bench session uses the same accounting.
    pub fn wire_bytes(&self, mode: crate::comm::wire::WireMode) -> usize {
        mode.payload_bytes(full_size_param_count(&self.model))
    }

    /// Execute one fused train step on `n_valid` samples padded to
    /// `bucket`. `xs`/`ys` must already be bucket-sized. The padding mask
    /// and the backend output live in persistent buffers: at a steady
    /// (n_valid, bucket) operating point this path performs zero heap
    /// allocations and zero redundant mask writes.
    pub fn train_step(
        &mut self,
        xs: &[f32],
        ys: &[i32],
        n_valid: usize,
        bucket: usize,
    ) -> anyhow::Result<StepMetrics> {
        anyhow::ensure!(xs.len() == bucket * self.feature_dim, "xs wrong size");
        anyhow::ensure!(ys.len() == bucket, "ys wrong size");
        anyhow::ensure!(n_valid <= bucket, "n_valid > bucket");
        if self.mask_shape != (n_valid, bucket) {
            self.mask_buf.clear();
            self.mask_buf.resize(bucket, 0.0);
            self.mask_buf[..n_valid].fill(1.0);
            self.mask_shape = (n_valid, bucket);
        }

        let t0 = Instant::now();
        self.backend.train_step_into(
            &self.model,
            self.optimizer,
            bucket,
            &mut self.state,
            xs,
            ys,
            &self.mask_buf,
            self.lr,
            &mut self.out_buf,
        )?;
        let exec_seconds = t0.elapsed().as_secs_f64();
        self.exec_seconds_total += exec_seconds;
        self.exec_count += 1;

        Ok(StepMetrics {
            loss: self.out_buf.loss as f64,
            acc: self.out_buf.acc as f64,
            sigma_norm: self.out_buf.sigma_norm as f64,
            sigma_norm2: self.out_buf.sigma_norm2 as f64,
            grad_l2: self.out_buf.grad_l2 as f64,
            exec_seconds,
        })
    }

    /// Per-sample masked correctness of the most recent train step
    /// (length = that step's bucket).
    pub fn last_correct(&self) -> &[f32] {
        &self.out_buf.correct
    }

    /// Held-out evaluation on the dataset's fixed eval batch.
    pub fn eval(&mut self, dataset: &SyntheticDataset) -> anyhow::Result<(f64, f64)> {
        let eb = self.backend.schema().eval_batch;
        if self.eval_cache.is_none() {
            let (xs, ys) = dataset.eval_batch(eb);
            self.eval_cache = Some((xs, ys, vec![1.0; eb]));
        }
        let (xs, ys, mask) = self.eval_cache.as_ref().unwrap();
        let (loss, acc) = self
            .backend
            .eval_step(&self.model, &self.state.params, xs, ys, mask)?;
        Ok((loss as f64, acc as f64))
    }
}

/// Analytic full-size compute cost (A100-class reference GPU) per sample:
/// ~3x forward FLOPs / ~40 TFLOPS effective. The simulated cluster prices
/// compute with the PAPER's architectures, not the mini stand-ins, so the
/// compute/communication balance (the signal DYNAMIX exploits: larger
/// batches amortize sync) matches the real testbeds. Values in
/// microseconds per sample; fixed term = per-iteration framework/launch
/// overhead.
pub fn full_size_cost(model: &str) -> (f64, f64) {
    let us_per_sample = match model {
        "vgg11_mini" => 12.0,      // VGG11 CIFAR: ~0.46 GFLOP/sample train
        "vgg16_mini" => 24.0,      // VGG16: ~0.95 GFLOP
        "vgg19_mini" => 30.0,      // VGG19: ~1.2 GFLOP
        "resnet34_mini" => 28.0,   // ResNet34 CIFAR: ~1.1 GFLOP
        "resnet50_mini" => 34.0,   // ResNet50: ~1.3 GFLOP
        _ => 20.0,
    };
    (us_per_sample, 8_000.0) // 8 ms launch/framework overhead per iteration
}

/// Full-size parameter counts of the paper's architectures (for the
/// network payload model; the mini stand-ins keep compute CPU-feasible
/// but the fabric should carry VGG/ResNet-sized gradients).
pub fn full_size_param_count(model: &str) -> usize {
    match model {
        "vgg11_mini" => 9_231_114,        // VGG11 (CIFAR head)
        "vgg16_mini" => 14_728_266,       // VGG16
        "vgg19_mini" => 20_040_522,       // VGG19
        "resnet34_mini" => 21_328_292,    // ResNet34 (100-way head)
        "resnet50_mini" => 23_712_932,    // ResNet50
        _ => 10_000_000,
    }
}

/// One global iteration's record (consumed by metrics + the coordinator).
#[derive(Clone, Debug)]
pub struct IterationOutcome {
    pub iter: usize,
    /// Simulated wall-clock after this iteration (seconds).
    pub sim_clock: f64,
    /// Simulated duration of this iteration.
    pub sim_dt: f64,
    pub loss: f64,
    /// Global (all-worker) batch accuracy.
    pub acc: f64,
    pub sync_seconds: f64,
    pub retransmissions: u64,
    /// Global batch size this iteration (sum of worker batches).
    pub global_batch: usize,
}

/// The BSP trainer: cluster + netsim + data + model, one step at a time.
///
/// Membership is **elastic**: a `ScenarioScript` (threaded through
/// `ExperimentConfig`) can preempt and rejoin workers mid-run. A preempted
/// worker contributes no data, no compute and no collective participant;
/// its batch budget redistributes across survivors and the dataset
/// re-shards over the active set. All of it is deterministic in
/// (seed, script) — the scripted timeline replays bit-for-bit.
pub struct BspTrainer {
    pub runtime: ModelRuntime,
    pub cluster: SimCluster,
    pub net: NetworkSim,
    pub topology: Topology,
    pub dataset: SyntheticDataset,
    samplers: Vec<ShardSampler>,
    collectors: Vec<Collector>,
    /// Current per-worker batch sizes (mutated by coordinator/baselines).
    /// A preempted worker's entry is frozen at its last value so a rejoin
    /// can resume from it; only active workers count toward the global
    /// batch.
    pub batches: Vec<usize>,
    /// Per-worker k-iteration aggregation windows.
    pub windows: Vec<WindowAggregator>,
    pub iter: usize,
    /// Scripted environment timeline (empty for stationary runs).
    scenario: ScenarioRuntime,
    /// `(script time, event description)` of every event applied this
    /// episode, in application order — the run record's scenario trace.
    pub events_applied: Vec<(f64, String)>,
    /// Batch bounds from the config (redistribution/rejoin clamps).
    batch_min: usize,
    batch_max: usize,
    /// Root seed for shard permutations; membership revisions fold in.
    shard_seed: u64,
    membership_rev: u64,
    // Scratch buffers reused across iterations (hot loop stays
    // allocation-free after the first step at each bucket).
    idx_scratch: Vec<u64>,
    xs_scratch: Vec<f32>,
    ys_scratch: Vec<i32>,
    offsets_scratch: Vec<usize>,
    /// Price the collective with the pipelined (comm/compute-overlapped)
    /// timeline instead of the serialized one. Mirrors the data plane's
    /// `DYNAMIX_OVERLAP` knob, read once at construction, so the RL comm
    /// features (sync time, throughput) see the same savings the real
    /// bucketed ring delivers.
    overlap_sync: bool,
    /// Target bytes per gradient bucket for the overlap timeline
    /// (`DYNAMIX_BUCKET_KB`, same default as the data plane).
    bucket_bytes: usize,
    /// Slice codec the collective pricing assumes (`DYNAMIX_WIRE`, read
    /// once at construction) — compressed modes shrink the priced
    /// payload exactly as they shrink the data plane's frames.
    wire_sync: crate::comm::wire::WireMode,
}

impl BspTrainer {
    pub fn new(cfg: &ExperimentConfig, backend: Backend) -> anyhow::Result<Self> {
        cfg.validate()?;
        let info = backend.schema().model(&cfg.train.model)?.clone();
        let dataset = crate::data::by_name(&info.dataset, info.feature_dim, cfg.train.seed)?;
        let runtime = ModelRuntime::new(
            backend,
            &cfg.train.model,
            cfg.train.optimizer,
            cfg.train.lr,
            cfg.train.seed,
        )?;
        let n = cfg.cluster.n_workers;
        let cluster = SimCluster::new(cfg.cluster.preset, n, cfg.cluster.seed);
        let net = match cfg.cluster.preset {
            crate::config::ClusterPreset::FabricHetero
            | crate::config::ClusterPreset::SpotMarket => NetworkSim::noisy(cfg.cluster.seed),
            _ => NetworkSim::new(cfg.cluster.seed),
        };
        let samplers = (0..n)
            .map(|w| ShardSampler::new(w, n, dataset.train_size, cfg.train.seed))
            .collect();
        let scenario = match &cfg.scenario {
            Some(s) => ScenarioRuntime::new(s.clone()),
            None => ScenarioRuntime::empty(),
        };
        Ok(BspTrainer {
            runtime,
            cluster,
            net,
            topology: cfg.cluster.topology,
            dataset,
            samplers,
            collectors: (0..n).map(|_| Collector::default()).collect(),
            batches: vec![cfg.batch.initial; n],
            windows: (0..n).map(|_| WindowAggregator::default()).collect(),
            iter: 0,
            scenario,
            events_applied: Vec::new(),
            batch_min: cfg.batch.min,
            batch_max: cfg.batch.max,
            shard_seed: cfg.train.seed,
            membership_rev: 0,
            idx_scratch: Vec::new(),
            xs_scratch: Vec::new(),
            ys_scratch: Vec::new(),
            offsets_scratch: Vec::new(),
            overlap_sync: crate::config::env::overlap().unwrap_or(true),
            bucket_bytes: crate::config::env::bucket_kb()
                .map(|kb| kb * 1024)
                .unwrap_or(32 << 10),
            wire_sync: crate::config::env::wire_mode()
                .unwrap_or(crate::comm::wire::WireMode::Dense),
        })
    }

    /// Pin the collective pricing model (tests compare the two timelines
    /// without touching the process environment).
    pub fn set_overlap_sync(&mut self, on: bool) {
        self.overlap_sync = on;
    }

    /// Pin the priced slice codec (tests compare wire modes without
    /// touching the process environment).
    pub fn set_wire_sync(&mut self, mode: crate::comm::wire::WireMode) {
        self.wire_sync = mode;
    }

    /// Wire-codec label of the priced slice codec (checkpoint headers
    /// fingerprint it so a resume under a different codec is rejected).
    pub fn wire_label(&self) -> &'static str {
        self.wire_sync.label()
    }

    pub fn n_workers(&self) -> usize {
        self.batches.len()
    }

    // --- elastic membership ---

    pub fn is_active(&self, w: usize) -> bool {
        self.cluster.is_active(w)
    }

    pub fn n_active(&self) -> usize {
        self.cluster.n_active()
    }

    pub fn active_mask(&self) -> Vec<bool> {
        self.cluster.active_mask()
    }

    /// Batch sizes of the currently active workers.
    pub fn active_batches(&self) -> Vec<usize> {
        (0..self.n_workers())
            .filter(|&w| self.cluster.is_active(w))
            .map(|w| self.batches[w])
            .collect()
    }

    /// Global batch = sum of the ACTIVE workers' batches. Allocation-free:
    /// this runs once per BSP iteration on the hot loop.
    pub fn global_batch(&self) -> usize {
        (0..self.n_workers())
            .filter(|&w| self.cluster.is_active(w))
            .map(|w| self.batches[w])
            .sum()
    }

    /// The scripted timeline this trainer replays (empty if stationary).
    pub fn scenario_script(&self) -> &ScenarioScript {
        self.scenario.script()
    }

    /// Spot-preempt worker `w`: it leaves the collective, its batch budget
    /// redistributes across survivors (clamped by their memory caps) and
    /// the dataset re-shards over the active set. Refused (returns false)
    /// when `w` is already absent or is the last active worker.
    pub fn preempt_worker(&mut self, w: usize) -> bool {
        if !self.cluster.is_active(w) || self.cluster.n_active() <= 1 {
            return false;
        }
        self.cluster.set_active(w, false);
        let n = self.n_workers();
        let caps: Vec<usize> = (0..n).map(|i| self.mem_cap(i, self.batch_max)).collect();
        let active = self.cluster.active_mask();
        elastic::redistribute_freed(
            self.batches[w],
            &mut self.batches,
            &active,
            &caps,
            self.batch_max,
        );
        self.reshard();
        // Mirror into the compute data plane — but only under the
        // one-shard-per-worker deployment, where worker index == shard
        // index is meaningful. With any other shard count the data plane
        // keeps its full membership (the math is identical either way;
        // only who computes which rows would change).
        if self.runtime.backend().shard_count() == self.n_workers() {
            self.runtime.backend().set_shard_active(w, false);
        }
        true
    }

    /// Rejoin a preempted worker: it resumes with its pre-preemption batch
    /// clamped to the batch bounds and its memory ceiling.
    pub fn rejoin_worker(&mut self, w: usize) -> bool {
        if self.cluster.is_active(w) {
            return false;
        }
        self.cluster.set_active(w, true);
        let cap = self.mem_cap(w, self.batch_max);
        self.batches[w] = elastic::rejoin_batch(self.batches[w], cap, self.batch_min, self.batch_max);
        self.reshard();
        if self.runtime.backend().shard_count() == self.n_workers() {
            self.runtime.backend().set_shard_active(w, true);
        }
        true
    }

    /// Rebuild the shard samplers over the active set: active worker of
    /// rank r draws shard (r, n_active). The membership revision folds
    /// into the seed so each epoch of membership gets a fresh — but fully
    /// deterministic — permutation stream.
    fn reshard(&mut self) {
        self.membership_rev += 1;
        let active: Vec<usize> = (0..self.n_workers())
            .filter(|&w| self.cluster.is_active(w))
            .collect();
        let n_active = active.len().max(1);
        let seed = self
            .shard_seed
            .wrapping_add(self.membership_rev.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for (rank, &w) in active.iter().enumerate() {
            self.samplers[w] = ShardSampler::new(rank, n_active, self.dataset.train_size, seed);
        }
    }

    // --- scripted scenario events ---

    /// Pop and apply every scripted event due at the current sim clock.
    fn apply_due_events(&mut self) {
        let now = self.cluster.clock;
        for (at, ev) in self.scenario.pop_due(now) {
            self.apply_event(at, ev);
        }
    }

    /// Apply one scenario event (`at_s` = its script time, recorded in the
    /// trace). Out-of-range worker indices are skipped defensively —
    /// config validation rejects them up front for scripted runs.
    pub fn apply_event(&mut self, at_s: f64, ev: ScenarioEvent) {
        let n = self.n_workers();
        let desc = ev.describe();
        let applied = match ev {
            ScenarioEvent::SlowdownWorker { worker, factor } if worker < n => {
                self.cluster.scale_speed(worker, factor);
                true
            }
            ScenarioEvent::BandwidthDrop { factor } => {
                self.cluster.scale_bandwidth_all(factor);
                true
            }
            ScenarioEvent::CongestionStorm { level, duration_s } => {
                self.net.storm(level);
                // Relax `duration_s` after the storm actually APPLIES (the
                // sim clock), not after its nominal script time — a storm
                // that lands late still lasts its full duration.
                self.scenario.schedule(
                    self.cluster.clock + duration_s.max(0.0),
                    ScenarioEvent::CongestionRelax,
                );
                true
            }
            ScenarioEvent::CongestionRelax => {
                self.net.relax();
                true
            }
            ScenarioEvent::PreemptWorker { worker } if worker < n => self.preempt_worker(worker),
            ScenarioEvent::RejoinWorker { worker } if worker < n => self.rejoin_worker(worker),
            ScenarioEvent::LoadShift { worker, load_mean } if worker < n => {
                self.cluster.set_load_mean(worker, load_mean);
                true
            }
            _ => false,
        };
        if applied {
            self.events_applied.push((at_s, desc));
        }
    }

    /// Attach the data-plane + scenario traces to a run record: which
    /// backend executed the run (with shard count/membership when the data
    /// plane is sharded), the full scripted timeline (identical across
    /// policies for the same config — the apples-to-apples guarantee), and
    /// the events actually applied within this run's horizon.
    pub fn annotate_record(&self, record: &mut RunRecord) {
        let bk = self.runtime.backend();
        if bk.shard_count() > 1 {
            let membership: Vec<Json> =
                bk.shard_membership().into_iter().map(Json::Bool).collect();
            record.extra.insert(
                "data_plane".into(),
                crate::jobj! {
                    "backend" => bk.name(),
                    "shard_count" => bk.shard_count(),
                    "shard_active" => Json::Arr(membership),
                },
            );
        }
        if self.scenario_script().is_empty() {
            return;
        }
        record
            .extra
            .insert("scenario".into(), Json::Str(self.scenario_script().name.clone()));
        record
            .extra
            .insert("scenario_timeline".into(), self.scenario_script().to_json());
        let applied: Vec<Json> = self
            .events_applied
            .iter()
            .map(|(t, d)| crate::jobj! { "at_s" => *t, "event" => d.clone() })
            .collect();
        record.extra.insert("events_applied".into(), Json::Arr(applied));
    }

    /// Reset for a new episode: model params, clock, load/congestion
    /// processes, membership, per-worker batches, windows, and the
    /// scenario timeline (Algorithm 1 / §VI-C).
    pub fn reset_episode(&mut self, seed: u64, initial_batch: usize) -> anyhow::Result<()> {
        self.runtime.reset(seed)?;
        self.cluster.reset(seed);
        self.net.reset(seed);
        let n = self.n_workers();
        self.samplers = (0..n)
            .map(|w| ShardSampler::new(w, n, self.dataset.train_size, seed))
            .collect();
        self.batches.fill(initial_batch);
        for w in &mut self.windows {
            *w = WindowAggregator::default();
        }
        self.iter = 0;
        self.scenario.rearm();
        self.events_applied.clear();
        self.shard_seed = seed;
        self.membership_rev = 0;
        // The data plane's membership resets with the cluster's, so a
        // re-armed scenario replays against a full shard set.
        for s in 0..self.runtime.backend().shard_count() {
            self.runtime.backend().set_shard_active(s, true);
        }
        Ok(())
    }

    /// Execute one global BSP iteration.
    ///
    /// Scripted scenario events due at the current sim clock apply first,
    /// so membership/profile changes take effect for this iteration.
    pub fn iterate(&mut self) -> anyhow::Result<IterationOutcome> {
        self.apply_due_events();
        let n_workers = self.n_workers();
        let fd = self.runtime.feature_dim;
        let total: usize = self.global_batch();
        let bucket = self.runtime.schema().bucket_for(total)?;

        // --- assemble the fused global batch (active workers only) ---
        self.xs_scratch.resize(bucket * fd, 0.0);
        self.ys_scratch.resize(bucket, 0);
        for v in &mut self.xs_scratch[total * fd..] {
            *v = 0.0;
        }
        for v in &mut self.ys_scratch[total..] {
            *v = 0;
        }
        self.offsets_scratch.clear();
        let mut row = 0usize;
        for w in 0..n_workers {
            self.offsets_scratch.push(row);
            if !self.cluster.is_active(w) {
                continue; // zero-width range: absent worker holds no rows
            }
            let b = self.batches[w];
            self.samplers[w].next_indices(b, &mut self.idx_scratch);
            for (j, &idx) in self.idx_scratch.iter().enumerate() {
                let r = row + j;
                self.ys_scratch[r] = self
                    .dataset
                    .sample_into(idx, &mut self.xs_scratch[r * fd..(r + 1) * fd]);
            }
            row += b;
        }
        self.offsets_scratch.push(row);

        // --- one fused backend execution (== per-worker grads + all-reduce) ---
        let metrics = self
            .runtime
            .train_step(&self.xs_scratch, &self.ys_scratch, total, bucket)?;

        // --- price the iteration on the simulated cluster + fabric ---
        // The collective only spans the machines that are present.
        let outcomes = self.cluster.compute_phase(&self.batches);
        let profiles = self.cluster.active_profiles();
        let grad_bytes = self.runtime.wire_bytes(self.wire_sync);
        let sync = if self.overlap_sync {
            // Pipelined pricing: buckets stream out as the straggler's
            // backward produces them, so only the tail of the collective
            // is exposed beyond compute. Bucket count mirrors the data
            // plane's plan granularity (capped — a real plan never has
            // more buckets than completion stages).
            let nb = grad_bytes.div_ceil(self.bucket_bytes.max(1)).clamp(1, 64);
            let straggler_s = outcomes
                .iter()
                .map(|o| o.compute_s)
                .fold(0.0f64, f64::max);
            self.net
                .sync_overlapped(self.topology, &profiles, grad_bytes, straggler_s, nb)
        } else {
            self.net.sync(self.topology, &profiles, grad_bytes)
        };
        let sim_dt = self.cluster.advance_iteration(&outcomes, sync.time_s);
        self.net.advance(sim_dt);

        // --- per-worker window samples (absent workers observe nothing) ---
        let retx_per_worker = sync.retransmissions as f64 / self.cluster.n_active().max(1) as f64;
        for w in 0..n_workers {
            if !self.cluster.is_active(w) {
                continue;
            }
            let lo = self.offsets_scratch[w];
            let hi = self.offsets_scratch[w + 1];
            let local_n = (hi - lo).max(1);
            let local_correct: f32 = self.runtime.last_correct()[lo..hi].iter().sum();
            let local_acc = local_correct as f64 / local_n as f64;
            let iter_time = outcomes[w].compute_s + sync.time_s + self.cluster.barrier_s;
            let sys = self.collectors[w].sample(
                self.cluster.profile(w),
                &outcomes[w],
                full_size_param_count(&self.runtime.model),
                self.batches[w],
            );
            self.windows[w].push_iteration(
                local_acc,
                metrics.loss,
                iter_time,
                sync.throughput_gbps,
                retx_per_worker.round() as u64,
                sys,
                metrics.sigma_norm,
                metrics.sigma_norm2,
            );
        }

        self.iter += 1;
        Ok(IterationOutcome {
            iter: self.iter,
            sim_clock: self.cluster.clock,
            sim_dt,
            loss: metrics.loss,
            acc: metrics.acc,
            sync_seconds: sync.time_s,
            retransmissions: sync.retransmissions,
            global_batch: total,
        })
    }

    /// Capture every piece of mutable trainer state a resumed run needs to
    /// continue bit-for-bit: optimizer, cluster, fabric, samplers, batch
    /// assignments, the remaining scenario timeline (including events the
    /// runtime derived mid-run, e.g. a storm's auto-relax) and the applied
    /// trace. Take it at a window boundary (every [`WindowAggregator`]
    /// freshly finished) — window contents are NOT captured.
    pub fn snapshot(&self) -> TrainerState {
        TrainerState {
            opt: self.runtime.opt_state().clone(),
            cluster: self.cluster.snapshot(),
            net: self.net.snapshot(),
            samplers: self.samplers.iter().map(|s| s.snapshot()).collect(),
            batches: self.batches.clone(),
            iter: self.iter,
            scenario_queue: self.scenario.snapshot_queue(),
            events_applied: self.events_applied.clone(),
            shard_seed: self.shard_seed,
            membership_rev: self.membership_rev,
            overlap_sync: self.overlap_sync,
            bucket_bytes: self.bucket_bytes,
            wire_sync: self.wire_sync,
        }
    }

    /// Overwrite this trainer from a [`TrainerState`]. Windows reset to
    /// empty (snapshots are taken at window boundaries) and the data
    /// plane's shard membership is re-aligned to the restored cluster.
    pub fn restore(&mut self, s: &TrainerState) -> anyhow::Result<()> {
        let n = self.n_workers();
        anyhow::ensure!(
            s.batches.len() == n && s.samplers.len() == n,
            "trainer snapshot is for {} workers, this trainer has {n}",
            s.batches.len()
        );
        self.runtime.restore_opt_state(&s.opt)?;
        self.cluster.restore(&s.cluster)?;
        self.net.restore(&s.net);
        self.samplers = s.samplers.iter().map(ShardSampler::from_snapshot).collect();
        self.batches = s.batches.clone();
        self.iter = s.iter;
        self.scenario.restore_queue(s.scenario_queue.clone());
        self.events_applied = s.events_applied.clone();
        self.shard_seed = s.shard_seed;
        self.membership_rev = s.membership_rev;
        self.overlap_sync = s.overlap_sync;
        self.bucket_bytes = s.bucket_bytes;
        self.wire_sync = s.wire_sync;
        for w in &mut self.windows {
            *w = WindowAggregator::default();
        }
        if self.runtime.backend().shard_count() == n {
            for w in 0..n {
                self.runtime
                    .backend()
                    .set_shard_active(w, self.cluster.is_active(w));
            }
        }
        Ok(())
    }

    /// Held-out eval accuracy: (loss, acc).
    pub fn eval(&mut self) -> anyhow::Result<(f64, f64)> {
        self.runtime.eval(&self.dataset)
    }

    /// Per-worker memory ceiling for the batch rule (§IV-C OOM clamp).
    pub fn mem_cap(&self, worker: usize, max: usize) -> usize {
        self.cluster
            .max_batch(worker, full_size_param_count(&self.runtime.model), max)
    }

    /// Calibrate the cluster cost model: simulated compute is priced from
    /// the analytic full-size table (see [`full_size_cost`]) so the
    /// compute/communication balance matches the paper's testbeds; the
    /// real backend step is still measured here and logged for §Perf.
    pub fn calibrate(&mut self) -> anyhow::Result<()> {
        let (us_per_sample, fixed_us) = full_size_cost(&self.runtime.model);
        self.cluster.cost.base_us_per_sample = us_per_sample;
        self.cluster.cost.fixed_us = fixed_us;
        // Warm the common bucket path + record a real measurement.
        let fd = self.runtime.feature_dim;
        let bucket = 256;
        let xs = vec![0.1f32; bucket * fd];
        let ys = vec![0i32; bucket];
        self.runtime.train_step(&xs, &ys, bucket, bucket)?;
        self.runtime.train_step(&xs, &ys, bucket, bucket)?;
        self.runtime.reset(0)?;
        Ok(())
    }
}

/// Serializable checkpoint image of a [`BspTrainer`]'s mutable state.
#[derive(Clone, Debug)]
pub struct TrainerState {
    /// Flat model params + optimizer moments + step counter.
    pub opt: OptState,
    pub cluster: ClusterState,
    pub net: NetSimState,
    /// One per worker (preempted workers keep a stale shard — exactly as
    /// the live trainer does until the next reshard).
    pub samplers: Vec<SamplerState>,
    pub batches: Vec<usize>,
    pub iter: usize,
    /// Remaining scenario events, original seqs + pop frontier included.
    pub scenario_queue: QueueState<ScenarioEvent>,
    pub events_applied: Vec<(f64, String)>,
    pub shard_seed: u64,
    pub membership_rev: u64,
    pub overlap_sync: bool,
    pub bucket_bytes: usize,
    pub wire_sync: crate::comm::wire::WireMode,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterPreset, ExperimentConfig};
    use crate::runtime::{native_backend, Backend};

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.n_workers = 4;
        cfg.batch.initial = 64;
        cfg.train.max_steps = 50;
        cfg
    }

    fn backend() -> Backend {
        native_backend()
    }

    #[test]
    fn iterate_advances_clock_and_learns() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        let mut first_acc = 0.0;
        let mut last_acc = 0.0;
        for i in 0..30 {
            let out = t.iterate().unwrap();
            assert!(out.sim_dt > 0.0);
            assert_eq!(out.global_batch, 4 * 64);
            if i == 0 {
                first_acc = out.acc;
            }
            last_acc = out.acc;
        }
        assert!(t.cluster.clock > 0.0);
        assert!(
            last_acc > first_acc + 0.1,
            "training did not learn: {first_acc} -> {last_acc}"
        );
    }

    #[test]
    fn per_worker_windows_fill_and_track_accuracy() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        for _ in 0..5 {
            t.iterate().unwrap();
        }
        for w in 0..4 {
            let s = t.windows[w].finish();
            assert_eq!(s.iters, 5);
            assert!(s.acc_mean >= 0.0 && s.acc_mean <= 1.0);
            assert!(s.iter_time_mean > 0.0);
        }
    }

    #[test]
    fn unequal_batches_slice_correctly() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        t.batches = vec![32, 64, 96, 128];
        let out = t.iterate().unwrap();
        assert_eq!(out.global_batch, 320);
        for w in 0..4 {
            let s = t.windows[w].finish();
            assert!((0.0..=1.0).contains(&s.acc_mean), "w{w}: {}", s.acc_mean);
        }
    }

    #[test]
    fn eval_improves_with_training() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        let (_, acc0) = t.eval().unwrap();
        for _ in 0..40 {
            t.iterate().unwrap();
        }
        let (_, acc1) = t.eval().unwrap();
        assert!(
            acc1 > acc0 + 0.15,
            "eval accuracy did not improve: {acc0} -> {acc1}"
        );
    }

    #[test]
    fn reset_episode_restores_initial_state() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        for _ in 0..10 {
            t.iterate().unwrap();
        }
        let (_, trained) = t.eval().unwrap();
        t.reset_episode(0, 64).unwrap();
        assert_eq!(t.iter, 0);
        assert_eq!(t.cluster.clock, 0.0);
        let (_, reset_acc) = t.eval().unwrap();
        assert!(
            reset_acc < trained,
            "reset did not restore params: {reset_acc} vs {trained}"
        );
        assert!(t.batches.iter().all(|&b| b == 64));
    }

    #[test]
    fn hetero_cluster_iteration_time_composition() {
        let mut cfg = small_cfg();
        cfg.cluster.preset = ClusterPreset::FabricHetero;
        cfg.cluster.n_workers = 8;
        let mut t = BspTrainer::new(&cfg, backend()).unwrap();
        t.iterate().unwrap();
        let w_fast = t.windows[0].finish();
        let w_slow = t.windows[7].finish();
        assert!(w_slow.iter_time_mean >= w_fast.iter_time_mean);
    }

    #[test]
    fn calibrate_prices_full_size_compute() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        t.calibrate().unwrap();
        assert_eq!(t.cluster.cost.base_us_per_sample, full_size_cost("vgg11_mini").0);
        assert!(t.runtime.exec_count >= 2, "real step still measured for §Perf");
    }

    #[test]
    fn full_size_cost_orders_by_architecture_depth() {
        assert!(full_size_cost("vgg11_mini").0 < full_size_cost("vgg16_mini").0);
        assert!(full_size_cost("vgg16_mini").0 < full_size_cost("vgg19_mini").0);
        assert!(full_size_cost("resnet34_mini").0 < full_size_cost("resnet50_mini").0);
    }

    #[test]
    fn full_size_params_match_paper_architectures() {
        assert!(full_size_param_count("vgg11_mini") < full_size_param_count("vgg16_mini"));
        assert!(full_size_param_count("vgg16_mini") < full_size_param_count("vgg19_mini"));
        assert!(full_size_param_count("resnet34_mini") < full_size_param_count("resnet50_mini"));
    }

    #[test]
    fn preempt_redistributes_budget_and_shrinks_global_batch() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        assert_eq!(t.global_batch(), 4 * 64);
        assert!(t.preempt_worker(2));
        assert_eq!(t.n_active(), 3);
        // 64 freed across 3 survivors: 22/21/21.
        assert_eq!(t.active_batches().iter().sum::<usize>(), 4 * 64);
        assert_eq!(t.batches[2], 64, "frozen for rejoin");
        let out = t.iterate().unwrap();
        assert_eq!(out.global_batch, 4 * 64);
        // Preempting the same worker again (or the last survivor) refuses.
        assert!(!t.preempt_worker(2));
        t.preempt_worker(0);
        t.preempt_worker(1);
        assert!(!t.preempt_worker(3), "never empty the cluster");
        assert_eq!(t.n_active(), 1);
    }

    #[test]
    fn rejoin_resumes_with_valid_batch_and_windows_skip_absent() {
        let mut t = BspTrainer::new(&small_cfg(), backend()).unwrap();
        t.preempt_worker(1);
        for _ in 0..3 {
            t.iterate().unwrap();
        }
        assert_eq!(t.windows[1].finish().iters, 0, "absent worker observed nothing");
        assert_eq!(t.windows[0].finish().iters, 3);
        assert!(t.rejoin_worker(1));
        assert!((32..=1024).contains(&t.batches[1]));
        let cap = t.mem_cap(1, 1024);
        assert!(t.batches[1] <= cap.max(32));
        t.iterate().unwrap();
        assert_eq!(t.windows[1].finish().iters, 1, "rejoined worker observes again");
        assert!(!t.rejoin_worker(1), "already active");
    }

    #[test]
    fn scripted_scenario_fires_on_the_sim_clock_and_rearms() {
        use crate::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
        let mut cfg = small_cfg();
        cfg.scenario = Some(ScenarioScript {
            name: "t".into(),
            events: vec![
                TimedEvent {
                    at_s: 0.0,
                    event: ScenarioEvent::PreemptWorker { worker: 3 },
                },
                TimedEvent {
                    at_s: 0.05,
                    event: ScenarioEvent::LoadShift {
                        worker: 0,
                        load_mean: 0.6,
                    },
                },
                TimedEvent {
                    at_s: 1e6,
                    event: ScenarioEvent::RejoinWorker { worker: 3 },
                },
            ],
        });
        let mut t = BspTrainer::new(&cfg, backend()).unwrap();
        t.iterate().unwrap();
        assert_eq!(t.n_active(), 3, "t=0 preemption applies on the first iteration");
        assert_eq!(t.events_applied.len(), 1);
        while t.cluster.clock < 0.1 {
            t.iterate().unwrap();
        }
        assert_eq!(t.events_applied.len(), 2, "load shift fired by t=0.1");
        assert_eq!(t.events_applied[1].1, "load_shift w0 mean=0.6");
        // The far-future rejoin never fires within this horizon.
        assert_eq!(t.n_active(), 3);
        // Episode reset restores membership and re-arms the timeline.
        t.reset_episode(0, 64).unwrap();
        assert_eq!(t.n_active(), 4);
        assert!(t.events_applied.is_empty());
        t.iterate().unwrap();
        assert_eq!(t.n_active(), 3, "re-armed script preempts again");
    }

    #[test]
    fn congestion_storm_schedules_its_own_relax() {
        use crate::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
        let mut cfg = small_cfg();
        cfg.scenario = Some(ScenarioScript {
            name: "storm".into(),
            events: vec![TimedEvent {
                at_s: 0.0,
                event: ScenarioEvent::CongestionStorm {
                    level: 0.8,
                    duration_s: 0.05,
                },
            }],
        });
        let mut t = BspTrainer::new(&cfg, backend()).unwrap();
        t.iterate().unwrap();
        assert!((t.net.congestion_mean() - 0.8).abs() < 1e-12, "storm raised the mean");
        while t.cluster.clock < 0.2 {
            t.iterate().unwrap();
        }
        assert!(t.net.congestion_mean() < 0.1, "auto-relax restored the baseline");
        assert_eq!(t.events_applied.len(), 2, "storm + derived relax recorded");
    }

    #[test]
    fn trainer_snapshot_restore_resumes_bitwise_mid_scenario() {
        use crate::sim::scenario::{ScenarioEvent, ScenarioScript, TimedEvent};
        let mut cfg = small_cfg();
        cfg.scenario = Some(ScenarioScript {
            name: "ckpt".into(),
            events: vec![
                TimedEvent {
                    at_s: 0.0,
                    event: ScenarioEvent::PreemptWorker { worker: 3 },
                },
                TimedEvent {
                    at_s: 0.02,
                    event: ScenarioEvent::CongestionStorm {
                        level: 0.7,
                        duration_s: 0.1,
                    },
                },
                TimedEvent {
                    at_s: 0.3,
                    event: ScenarioEvent::RejoinWorker { worker: 3 },
                },
            ],
        });
        let mut t = BspTrainer::new(&cfg, backend()).unwrap();
        // Past the preempt + storm: the snapshot must carry the shrunken
        // membership, the storm-shifted fabric AND the derived auto-relax
        // event still pending in the queue.
        for _ in 0..6 {
            t.iterate().unwrap();
        }
        let snap = t.snapshot();
        let tail = |t: &mut BspTrainer| {
            (0..20)
                .map(|_| {
                    let o = t.iterate().unwrap();
                    (
                        o.loss.to_bits(),
                        o.acc.to_bits(),
                        o.sim_clock.to_bits(),
                        o.retransmissions,
                        o.global_batch,
                    )
                })
                .collect::<Vec<_>>()
        };
        let want = tail(&mut t);
        let mut r = BspTrainer::new(&cfg, backend()).unwrap();
        r.restore(&snap).unwrap();
        assert_eq!(tail(&mut r), want);
        assert_eq!(r.events_applied.len(), t.events_applied.len());
    }

    #[test]
    fn preempt_rejoin_mirror_into_sharded_data_plane() {
        use crate::runtime::ShardedBackend;
        use std::sync::Arc;
        let backend: Backend = Arc::new(ShardedBackend::loopback_with_threads(4, 1));
        let mut t = BspTrainer::new(&small_cfg(), backend.clone()).unwrap();
        assert_eq!(backend.shard_count(), 4);
        assert!(t.preempt_worker(2));
        assert_eq!(backend.shard_membership(), vec![true, true, false, true]);
        // The step still completes: worker 2's rows redistribute across
        // the surviving shards inside the fused train step.
        let out = t.iterate().unwrap();
        assert_eq!(out.global_batch, 4 * 64, "survivors absorbed the budget");
        assert!(t.rejoin_worker(2));
        assert_eq!(backend.shard_membership(), vec![true; 4]);
        // Episode reset restores a full shard set even after churn.
        t.preempt_worker(0);
        t.reset_episode(0, 64).unwrap();
        assert_eq!(backend.shard_membership(), vec![true; 4]);
        // The record carries the data-plane annotation.
        let mut rec = RunRecord::new("dp");
        t.annotate_record(&mut rec);
        let dp = rec.extra.get("data_plane").expect("data_plane annotated");
        assert_eq!(dp.get("backend").and_then(Json::as_str), Some("sharded"));
        assert_eq!(dp.get("shard_count").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn annotate_record_carries_the_timeline() {
        use crate::sim::scenario::ScenarioScript;
        let mut cfg = small_cfg();
        cfg.scenario = Some(ScenarioScript::by_name("load_shift").unwrap());
        let t = BspTrainer::new(&cfg, backend()).unwrap();
        let mut rec = RunRecord::new("scenario-annotate");
        t.annotate_record(&mut rec);
        assert_eq!(
            rec.extra.get("scenario").and_then(Json::as_str),
            Some("load_shift")
        );
        assert!(rec.extra.contains_key("scenario_timeline"));
        assert!(rec.extra.contains_key("events_applied"));
        // Stationary runs stay unannotated.
        let plain = BspTrainer::new(&small_cfg(), backend()).unwrap();
        let mut rec2 = RunRecord::new("plain");
        plain.annotate_record(&mut rec2);
        assert!(rec2.extra.is_empty());
    }
}
