//! Elastic worker membership helpers.
//!
//! When a worker is preempted its batch budget redistributes across the
//! survivors; when it rejoins it must resume with a batch that honors the
//! paper's [32, 1024] bounds *and* its memory ceiling (§IV-C OOM rule).
//! These are pure functions so the trainer and the property-based
//! invariants suite exercise the exact same logic.

/// Redistribute a preempted worker's freed batch budget across the active
/// workers: each active worker receives an equal share (the first
/// `freed % n` get one extra), clamped to `min(caps[w], max)`. Returns the
/// budget actually reabsorbed, which is `<= freed` when memory caps bind —
/// a smaller global batch is the honest outcome of losing capacity.
///
/// The preempted worker's own `batches` entry is left untouched so a later
/// rejoin can resume from it (see [`rejoin_batch`]).
pub fn redistribute_freed(
    freed: usize,
    batches: &mut [usize],
    active: &[bool],
    caps: &[usize],
    max: usize,
) -> usize {
    assert_eq!(batches.len(), active.len());
    assert_eq!(batches.len(), caps.len());
    let targets: Vec<usize> = (0..batches.len()).filter(|&w| active[w]).collect();
    if targets.is_empty() || freed == 0 {
        return 0;
    }
    let share = freed / targets.len();
    let extra = freed % targets.len();
    let mut absorbed = 0;
    for (rank, &w) in targets.iter().enumerate() {
        let want = batches[w] + share + usize::from(rank < extra);
        // Clamp to the worker's ceiling but never shrink a survivor: a cap
        // below its current batch just means it absorbs nothing.
        let got = want.min(max.min(caps[w])).max(batches[w]);
        absorbed += got - batches[w];
        batches[w] = got;
    }
    absorbed
}

/// Batch size a rejoining worker resumes with: its pre-preemption batch
/// clamped to `[min, min(max, cap)]` (the cap never pushes below `min`,
/// matching `BatchRule::apply`'s floor semantics).
pub fn rejoin_batch(prev: usize, cap: usize, min: usize, max: usize) -> usize {
    prev.clamp(min, max.min(cap.max(min)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn redistribute_splits_evenly_with_remainder_first() {
        let mut b = vec![100, 100, 100, 100];
        let active = vec![true, false, true, true];
        let caps = vec![1024; 4];
        let absorbed = redistribute_freed(100, &mut b, &active, &caps, 1024);
        assert_eq!(absorbed, 100);
        // 3 targets: shares 34, 33, 33.
        assert_eq!(b, vec![134, 100, 133, 133]);
    }

    #[test]
    fn redistribute_respects_caps_and_max() {
        let mut b = vec![1000, 1000, 64];
        let active = vec![true, true, false];
        let caps = vec![1024, 1008, 1024];
        let absorbed = redistribute_freed(64, &mut b, &active, &caps, 1024);
        // Worker 0 absorbs 24 (hits max 1024), worker 1 absorbs 8 (cap).
        assert_eq!(b[0], 1024);
        assert_eq!(b[1], 1008);
        assert_eq!(absorbed, 24 + 8);
        // Preempted worker's entry untouched (rejoin resumes from it).
        assert_eq!(b[2], 64);
    }

    #[test]
    fn redistribute_never_shrinks_a_survivor() {
        // A cap below a survivor's current batch must not claw it back.
        let mut b = vec![512, 128];
        let active = vec![true, false];
        let caps = vec![256, 1024];
        let absorbed = redistribute_freed(128, &mut b, &active, &caps, 1024);
        assert_eq!(absorbed, 0);
        assert_eq!(b[0], 512);
    }

    #[test]
    fn redistribute_no_targets_is_a_noop() {
        let mut b = vec![64];
        assert_eq!(redistribute_freed(64, &mut b, &[false], &[1024], 1024), 0);
        assert_eq!(b, vec![64]);
    }

    #[test]
    fn rejoin_clamps_into_valid_range() {
        assert_eq!(rejoin_batch(256, 1024, 32, 1024), 256, "resumes as-is");
        assert_eq!(rejoin_batch(2000, 1024, 32, 1024), 1024, "max binds");
        assert_eq!(rejoin_batch(256, 128, 32, 1024), 128, "mem cap binds");
        assert_eq!(rejoin_batch(0, 1024, 32, 1024), 32, "floor binds");
        assert_eq!(rejoin_batch(256, 8, 32, 1024), 32, "cap never below min");
    }
}
