//! Monotone discrete-event queue keyed on the sim clock.
//!
//! [`EventQueue`] is the scheduling substrate of the scenario system: push
//! `(time, item)` pairs in any order, pop them strictly in nondecreasing
//! time order (FIFO among equal timestamps, so scripted event sequences
//! replay verbatim). The queue is deterministic — no wall clock, no
//! hashing — which is what makes scripted runs bitwise reproducible.
//!
//! Late insertions (an event scheduled behind the last popped time, e.g. a
//! storm-relax whose storm fired after its nominal expiry) are clamped
//! forward to the last popped time: they fire at the next drain instead of
//! violating the monotone-pop invariant.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry. Ordering is by `(time, seq)` only — the payload does not
/// participate, so `T` needs no trait bounds.
struct Entry<T> {
    time: f64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse so the earliest time (then the
        // lowest sequence number) sits on top.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A monotone event queue over sim time.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    /// Largest time ever popped; pops are asserted nondecreasing against
    /// it and late pushes are clamped up to it.
    last_popped: f64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            last_popped: 0.0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.last_popped = 0.0;
    }

    /// Schedule `item` at sim time `time` (seconds). Non-finite or negative
    /// times are clamped to 0; times behind the pop frontier are clamped to
    /// it (the event fires at the next drain).
    pub fn push(&mut self, time: f64, item: T) {
        let t = if time.is_finite() { time.max(0.0) } else { 0.0 };
        let t = t.max(self.last_popped);
        self.heap.push(Entry {
            time: t,
            seq: self.seq,
            item,
        });
        self.seq += 1;
    }

    /// Earliest scheduled time, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest event if its time is `<= now`.
    pub fn pop_due(&mut self, now: f64) -> Option<(f64, T)> {
        match self.heap.peek() {
            Some(e) if e.time <= now => {
                let e = self.heap.pop().unwrap();
                debug_assert!(e.time >= self.last_popped, "event queue popped backwards");
                self.last_popped = e.time;
                Some((e.time, e.item))
            }
            _ => None,
        }
    }

    /// Pop every event with time `<= now`, in nondecreasing time order
    /// (FIFO among ties).
    pub fn drain_due(&mut self, now: f64) -> Vec<(f64, T)> {
        let mut out = Vec::new();
        while let Some(ev) = self.pop_due(now) {
            out.push(ev);
        }
        out
    }

    /// Checkpoint image: every pending `(time, seq, item)` sorted by
    /// `(time, seq)`, plus the sequence counter and the pop frontier.
    pub fn snapshot(&self) -> QueueState<T>
    where
        T: Clone,
    {
        let mut entries: Vec<(f64, u64, T)> = self
            .heap
            .iter()
            .map(|e| (e.time, e.seq, e.item.clone()))
            .collect();
        entries.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        QueueState {
            entries,
            seq: self.seq,
            last_popped: self.last_popped,
        }
    }

    /// Rebuild the queue from a [`QueueState`]. Entries keep their
    /// ORIGINAL sequence numbers (a plain `push` would renumber them and
    /// perturb FIFO tie order), and the counter/frontier are restored
    /// verbatim, so the drained timeline continues exactly where the
    /// snapshot left off.
    pub fn restore(&mut self, state: QueueState<T>) {
        self.heap.clear();
        for (time, seq, item) in state.entries {
            self.heap.push(Entry { time, seq, item });
        }
        self.seq = state.seq;
        self.last_popped = state.last_popped;
    }
}

/// Serializable checkpoint image of an [`EventQueue`].
#[derive(Clone, Debug)]
pub struct QueueState<T> {
    /// Pending entries as `(time, original seq, item)`, `(time, seq)`-sorted.
    pub entries: Vec<(f64, u64, T)>,
    /// The queue's next-sequence counter.
    pub seq: u64,
    /// Largest time ever popped (the monotone frontier).
    pub last_popped: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_regardless_of_push_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let all = q.drain_due(10.0);
        assert_eq!(all, vec![(1.0, "a"), (2.0, "b"), (3.0, "c")]);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, 0);
        q.push(1.0, 1);
        q.push(1.0, 2);
        let all: Vec<i32> = q.drain_due(1.0).into_iter().map(|(_, x)| x).collect();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn drain_due_respects_horizon() {
        let mut q = EventQueue::new();
        q.push(0.5, "early");
        q.push(5.0, "late");
        assert_eq!(q.drain_due(1.0), vec![(0.5, "early")]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_time(), Some(5.0));
        assert!(q.pop_due(1.0).is_none());
        assert_eq!(q.pop_due(5.0), Some((5.0, "late")));
    }

    #[test]
    fn late_insertions_clamp_to_pop_frontier() {
        let mut q = EventQueue::new();
        q.push(2.0, "first");
        assert_eq!(q.pop_due(3.0), Some((2.0, "first")));
        // Scheduled in the past relative to the frontier: clamped to 2.0.
        q.push(1.0, "late");
        let (t, item) = q.pop_due(3.0).unwrap();
        assert_eq!((t, item), (2.0, "late"));
    }

    #[test]
    fn garbage_times_clamp_to_zero() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, "nan");
        q.push(-5.0, "neg");
        q.push(f64::INFINITY, "inf");
        let all = q.drain_due(0.0);
        assert_eq!(all.len(), 3);
        assert!(all.iter().all(|(t, _)| *t == 0.0));
    }

    #[test]
    fn snapshot_restore_preserves_order_frontier_and_seq() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        q.push(3.0, "c1");
        q.push(3.0, "c2"); // FIFO tie — original seqs must survive restore
        assert_eq!(q.pop_due(2.0), Some((1.0, "a")));
        let snap = q.snapshot();
        let mut r: EventQueue<&str> = EventQueue::new();
        r.restore(snap);
        // Late pushes clamp to the restored frontier, not to zero.
        r.push(0.5, "late");
        assert_eq!(
            r.drain_due(10.0),
            vec![(1.0, "late"), (3.0, "c1"), (3.0, "c2")]
        );
        // The restored seq counter keeps post-restore pushes behind the
        // snapshot's entries among ties.
        let mut q2 = EventQueue::new();
        q2.push(2.0, "x");
        let snap2 = q2.snapshot();
        let mut r2: EventQueue<&str> = EventQueue::new();
        r2.restore(snap2);
        r2.push(2.0, "y");
        assert_eq!(r2.drain_due(2.0), vec![(2.0, "x"), (2.0, "y")]);
    }

    #[test]
    fn clear_resets_frontier() {
        let mut q = EventQueue::new();
        q.push(4.0, ());
        q.drain_due(10.0);
        q.clear();
        q.push(1.0, ());
        assert_eq!(q.peek_time(), Some(1.0));
    }
}
