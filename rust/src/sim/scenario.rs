//! Scripted dynamic-environment scenarios.
//!
//! A [`ScenarioScript`] is a named list of [`TimedEvent`]s — environment
//! perturbations at fixed sim times — that turn the simulator from a
//! replayer of the paper's three stationary testbeds into a scenario
//! generator: spot preemption, mid-run contention shifts, bandwidth
//! collapse, congestion storms, node churn (paper §I/§II-B motivation;
//! cf. Tyagi & Sharma's dynamic batching on transient clusters).
//!
//! Scripts serialize to/from JSON (`util::json`; no serde in the offline
//! build) and a catalogue of named built-ins ([`ScenarioScript::by_name`])
//! backs the `fig7_dynamics` harness and the `--scenario` CLI flag. The
//! [`ScenarioRuntime`] arms the script onto a monotone
//! [`EventQueue`](crate::sim::engine::EventQueue); the trainer drains due
//! events as the BSP clock advances and re-arms on episode reset, so the
//! same seed replays the same timeline bit-for-bit — for the RL policy and
//! every baseline alike.
//!
//! JSON schema (times in simulated seconds):
//!
//! ```json
//! {
//!   "name": "my-scenario",
//!   "events": [
//!     {"at_s": 0.5, "event": "slowdown_worker", "worker": 1, "factor": 0.4},
//!     {"at_s": 1.0, "event": "bandwidth_drop", "factor": 0.25},
//!     {"at_s": 1.5, "event": "congestion_storm", "level": 0.7, "duration_s": 2.0},
//!     {"at_s": 2.0, "event": "preempt_worker", "worker": 3},
//!     {"at_s": 4.0, "event": "rejoin_worker", "worker": 3},
//!     {"at_s": 5.0, "event": "load_shift", "worker": 0, "load_mean": 0.5}
//!   ]
//! }
//! ```

use crate::sim::engine::EventQueue;
use crate::util::json::Json;
use std::path::Path;

/// One scripted environment perturbation.
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioEvent {
    /// Scale worker `worker`'s compute speed to `factor ×` its base
    /// profile speed (`factor = 1.0` restores it).
    SlowdownWorker { worker: usize, factor: f64 },
    /// Scale every worker's NIC bandwidth to `factor ×` its base profile
    /// value (`factor = 1.0` restores the fabric).
    BandwidthDrop { factor: f64 },
    /// Jump the shared congestion process to `level` (level and mean) for
    /// `duration_s` seconds; a `CongestionRelax` is auto-scheduled at
    /// expiry to restore the baseline mean.
    CongestionStorm { level: f64, duration_s: f64 },
    /// Restore the congestion mean to its baseline (the level decays back
    /// through the OU dynamics). Usually auto-scheduled by a storm, but
    /// scriptable directly.
    CongestionRelax,
    /// Spot-style preemption: the worker leaves the cluster; its shard and
    /// batch budget redistribute across the survivors.
    PreemptWorker { worker: usize },
    /// The preempted worker returns and resumes with a valid batch.
    RejoinWorker { worker: usize },
    /// Shift worker `worker`'s background-load OU mean to `load_mean`
    /// (a tenant arriving on / leaving the shared host).
    LoadShift { worker: usize, load_mean: f64 },
}

impl ScenarioEvent {
    /// Stable kind tag (the JSON `"event"` value).
    pub fn kind(&self) -> &'static str {
        match self {
            ScenarioEvent::SlowdownWorker { .. } => "slowdown_worker",
            ScenarioEvent::BandwidthDrop { .. } => "bandwidth_drop",
            ScenarioEvent::CongestionStorm { .. } => "congestion_storm",
            ScenarioEvent::CongestionRelax => "congestion_relax",
            ScenarioEvent::PreemptWorker { .. } => "preempt_worker",
            ScenarioEvent::RejoinWorker { .. } => "rejoin_worker",
            ScenarioEvent::LoadShift { .. } => "load_shift",
        }
    }

    /// Human/trace description (stable: recorded in run records).
    pub fn describe(&self) -> String {
        match self {
            ScenarioEvent::SlowdownWorker { worker, factor } => {
                format!("slowdown_worker w{worker} x{factor}")
            }
            ScenarioEvent::BandwidthDrop { factor } => format!("bandwidth_drop x{factor}"),
            ScenarioEvent::CongestionStorm { level, duration_s } => {
                format!("congestion_storm level={level} dur={duration_s}s")
            }
            ScenarioEvent::CongestionRelax => "congestion_relax".into(),
            ScenarioEvent::PreemptWorker { worker } => format!("preempt_worker w{worker}"),
            ScenarioEvent::RejoinWorker { worker } => format!("rejoin_worker w{worker}"),
            ScenarioEvent::LoadShift { worker, load_mean } => {
                format!("load_shift w{worker} mean={load_mean}")
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut obj = crate::jobj! { "event" => self.kind() };
        if let Json::Obj(m) = &mut obj {
            match *self {
                ScenarioEvent::SlowdownWorker { worker, factor } => {
                    m.insert("worker".into(), Json::from(worker));
                    m.insert("factor".into(), Json::Num(factor));
                }
                ScenarioEvent::BandwidthDrop { factor } => {
                    m.insert("factor".into(), Json::Num(factor));
                }
                ScenarioEvent::CongestionStorm { level, duration_s } => {
                    m.insert("level".into(), Json::Num(level));
                    m.insert("duration_s".into(), Json::Num(duration_s));
                }
                ScenarioEvent::CongestionRelax => {}
                ScenarioEvent::PreemptWorker { worker } => {
                    m.insert("worker".into(), Json::from(worker));
                }
                ScenarioEvent::RejoinWorker { worker } => {
                    m.insert("worker".into(), Json::from(worker));
                }
                ScenarioEvent::LoadShift { worker, load_mean } => {
                    m.insert("worker".into(), Json::from(worker));
                    m.insert("load_mean".into(), Json::Num(load_mean));
                }
            }
        }
        obj
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let kind = v
            .get("event")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("scenario event missing \"event\" kind"))?;
        let worker = || {
            v.get("worker")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("{kind}: missing/invalid \"worker\""))
        };
        let num = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("{kind}: missing/invalid \"{key}\""))
        };
        Ok(match kind {
            "slowdown_worker" => ScenarioEvent::SlowdownWorker {
                worker: worker()?,
                factor: num("factor")?,
            },
            "bandwidth_drop" => ScenarioEvent::BandwidthDrop {
                factor: num("factor")?,
            },
            "congestion_storm" => ScenarioEvent::CongestionStorm {
                level: num("level")?,
                duration_s: num("duration_s")?,
            },
            "congestion_relax" => ScenarioEvent::CongestionRelax,
            "preempt_worker" => ScenarioEvent::PreemptWorker { worker: worker()? },
            "rejoin_worker" => ScenarioEvent::RejoinWorker { worker: worker()? },
            "load_shift" => ScenarioEvent::LoadShift {
                worker: worker()?,
                load_mean: num("load_mean")?,
            },
            other => anyhow::bail!(
                "unknown scenario event {other:?} (valid: slowdown_worker bandwidth_drop \
                 congestion_storm congestion_relax preempt_worker rejoin_worker load_shift)"
            ),
        })
    }

    /// Structural validity against a cluster of `n_workers`.
    fn validate(&self, n_workers: usize) -> anyhow::Result<()> {
        let chk_worker = |w: usize| {
            anyhow::ensure!(
                w < n_workers,
                "{}: worker {w} out of range (n_workers = {n_workers})",
                self.kind()
            );
            Ok(())
        };
        match *self {
            ScenarioEvent::SlowdownWorker { worker, factor } => {
                chk_worker(worker)?;
                anyhow::ensure!(
                    factor.is_finite() && factor > 0.0 && factor <= 4.0,
                    "slowdown_worker: factor {factor} outside (0, 4]"
                );
            }
            ScenarioEvent::BandwidthDrop { factor } => {
                anyhow::ensure!(
                    factor.is_finite() && factor > 0.0 && factor <= 4.0,
                    "bandwidth_drop: factor {factor} outside (0, 4]"
                );
            }
            ScenarioEvent::CongestionStorm { level, duration_s } => {
                anyhow::ensure!(
                    (0.0..=0.9).contains(&level),
                    "congestion_storm: level {level} outside [0, 0.9]"
                );
                anyhow::ensure!(
                    duration_s.is_finite() && duration_s > 0.0,
                    "congestion_storm: duration {duration_s} must be positive"
                );
            }
            ScenarioEvent::CongestionRelax => {}
            ScenarioEvent::PreemptWorker { worker } | ScenarioEvent::RejoinWorker { worker } => {
                chk_worker(worker)?;
            }
            ScenarioEvent::LoadShift { worker, load_mean } => {
                chk_worker(worker)?;
                anyhow::ensure!(
                    (0.0..=0.95).contains(&load_mean),
                    "load_shift: load_mean {load_mean} outside [0, 0.95]"
                );
            }
        }
        Ok(())
    }
}

/// An event scheduled at a sim time.
#[derive(Clone, Debug, PartialEq)]
pub struct TimedEvent {
    pub at_s: f64,
    pub event: ScenarioEvent,
}

/// A named, ordered set of timed events.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ScenarioScript {
    pub name: String,
    pub events: Vec<TimedEvent>,
}

/// Built-in scenario names (the `fig7_dynamics` catalogue).
pub const BUILTIN_SCENARIOS: &[&str] = &[
    "preempt_rejoin",
    "bandwidth_collapse",
    "congestion_storm",
    "load_shift",
    "spot_chaos",
];

impl ScenarioScript {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Validate every event against a cluster size; event times must be
    /// finite and nonnegative (ordering is NOT required — the runtime's
    /// event queue sorts).
    pub fn validate(&self, n_workers: usize) -> anyhow::Result<()> {
        for (i, te) in self.events.iter().enumerate() {
            anyhow::ensure!(
                te.at_s.is_finite() && te.at_s >= 0.0,
                "scenario {:?} event {i}: at_s {} must be finite and >= 0",
                self.name,
                te.at_s
            );
            te.event
                .validate(n_workers)
                .map_err(|e| anyhow::anyhow!("scenario {:?} event {i}: {e}", self.name))?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|te| {
                let mut ev = te.event.to_json();
                if let Json::Obj(m) = &mut ev {
                    m.insert("at_s".into(), Json::Num(te.at_s));
                }
                ev
            })
            .collect();
        crate::jobj! {
            "name" => self.name.clone(),
            "events" => Json::Arr(events),
        }
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("unnamed")
            .to_string();
        let events = v
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("scenario {name:?}: missing \"events\" array"))?;
        let events = events
            .iter()
            .enumerate()
            .map(|(i, ev)| {
                let at_s = ev
                    .get("at_s")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("scenario {name:?} event {i}: missing at_s"))?;
                Ok(TimedEvent {
                    at_s,
                    event: ScenarioEvent::from_json(ev)?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ScenarioScript { name, events })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("scenario file {path:?}: {e}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Resolve a CLI argument: an existing file path is loaded as JSON,
    /// anything else is looked up in the built-in catalogue.
    pub fn resolve(arg: &str) -> anyhow::Result<Self> {
        let p = Path::new(arg);
        if p.is_file() {
            Self::load(p)
        } else {
            Self::by_name(arg)
        }
    }

    /// Named built-in scenarios. Times are tuned for the quick-scale
    /// harness runs (sim horizons of a few seconds); worker indices stay
    /// below 4 so every preset with >= 4 workers can run them.
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        use ScenarioEvent::*;
        let at = |at_s: f64, event: ScenarioEvent| TimedEvent { at_s, event };
        let events = match name {
            // Spot-market churn: two overlapping preemptions + rejoins and
            // a late single-worker loss.
            "preempt_rejoin" => vec![
                at(0.6, PreemptWorker { worker: 3 }),
                at(1.2, PreemptWorker { worker: 1 }),
                at(2.4, RejoinWorker { worker: 3 }),
                at(3.6, RejoinWorker { worker: 1 }),
                at(6.0, PreemptWorker { worker: 2 }),
                at(9.0, RejoinWorker { worker: 2 }),
            ],
            // The fabric loses most of its capacity twice, recovering in
            // between (link flaps / oversubscription).
            "bandwidth_collapse" => vec![
                at(0.8, BandwidthDrop { factor: 0.15 }),
                at(2.5, BandwidthDrop { factor: 1.0 }),
                at(5.0, BandwidthDrop { factor: 0.3 }),
                at(8.0, BandwidthDrop { factor: 1.0 }),
            ],
            // Escalating cross-traffic storms on the shared fabric.
            "congestion_storm" => vec![
                at(
                    0.5,
                    CongestionStorm {
                        level: 0.6,
                        duration_s: 1.5,
                    },
                ),
                at(
                    3.0,
                    CongestionStorm {
                        level: 0.8,
                        duration_s: 2.0,
                    },
                ),
                at(
                    7.0,
                    CongestionStorm {
                        level: 0.7,
                        duration_s: 3.0,
                    },
                ),
            ],
            // Multi-tenant contention arriving and leaving, plus a thermal
            // throttle on worker 2.
            "load_shift" => vec![
                at(
                    0.5,
                    LoadShift {
                        worker: 0,
                        load_mean: 0.6,
                    },
                ),
                at(
                    0.7,
                    LoadShift {
                        worker: 1,
                        load_mean: 0.5,
                    },
                ),
                at(
                    2.0,
                    SlowdownWorker {
                        worker: 2,
                        factor: 0.35,
                    },
                ),
                at(
                    3.5,
                    LoadShift {
                        worker: 0,
                        load_mean: 0.05,
                    },
                ),
                at(
                    4.0,
                    SlowdownWorker {
                        worker: 2,
                        factor: 1.0,
                    },
                ),
                at(
                    6.0,
                    LoadShift {
                        worker: 1,
                        load_mean: 0.1,
                    },
                ),
            ],
            // Everything at once: the stress scenario static baselines are
            // expected to lose on.
            "spot_chaos" => vec![
                at(
                    0.4,
                    LoadShift {
                        worker: 0,
                        load_mean: 0.5,
                    },
                ),
                at(0.8, PreemptWorker { worker: 3 }),
                at(1.5, BandwidthDrop { factor: 0.25 }),
                at(
                    2.2,
                    CongestionStorm {
                        level: 0.7,
                        duration_s: 1.5,
                    },
                ),
                at(3.0, RejoinWorker { worker: 3 }),
                at(
                    3.5,
                    SlowdownWorker {
                        worker: 1,
                        factor: 0.4,
                    },
                ),
                at(4.5, BandwidthDrop { factor: 1.0 }),
                at(5.5, PreemptWorker { worker: 0 }),
                at(
                    6.5,
                    SlowdownWorker {
                        worker: 1,
                        factor: 1.0,
                    },
                ),
                at(8.0, RejoinWorker { worker: 0 }),
            ],
            _ => anyhow::bail!(
                "unknown scenario {name:?}; built-ins: {}",
                BUILTIN_SCENARIOS.join(" ")
            ),
        };
        Ok(ScenarioScript {
            name: name.to_string(),
            events,
        })
    }

    /// Synthetic high-frequency churn script for event-queue overhead
    /// benchmarks: every `period_s` an event fires — rotating preempt /
    /// rejoin pairs interleaved with load shifts. Never empties the
    /// cluster (each preempt is rejoined before the next strikes).
    pub fn synthetic_churn(n_workers: usize, n_events: usize, period_s: f64) -> Self {
        use ScenarioEvent::*;
        assert!(n_workers >= 2);
        let mut events = Vec::with_capacity(n_events);
        for i in 0..n_events {
            let t = (i + 1) as f64 * period_s;
            let w = 1 + (i / 3) % (n_workers - 1);
            let event = match i % 3 {
                0 => PreemptWorker { worker: w },
                1 => RejoinWorker { worker: w },
                _ => LoadShift {
                    worker: w,
                    load_mean: if (i / 3) % 2 == 0 { 0.5 } else { 0.1 },
                },
            };
            events.push(TimedEvent { at_s: t, event });
        }
        ScenarioScript {
            name: format!("synthetic-churn-{n_events}x{period_s}s"),
            events,
        }
    }
}

/// A script armed onto the event queue, drained by the trainer as the BSP
/// clock advances. Re-armable for episodic runs.
pub struct ScenarioRuntime {
    script: ScenarioScript,
    queue: EventQueue<ScenarioEvent>,
}

impl ScenarioRuntime {
    pub fn new(script: ScenarioScript) -> Self {
        let mut rt = ScenarioRuntime {
            script,
            queue: EventQueue::new(),
        };
        rt.rearm();
        rt
    }

    /// A runtime with no events (the stationary default).
    pub fn empty() -> Self {
        Self::new(ScenarioScript::default())
    }

    pub fn script(&self) -> &ScenarioScript {
        &self.script
    }

    /// Reload the full script onto a fresh queue (episode reset).
    pub fn rearm(&mut self) {
        self.queue.clear();
        for te in &self.script.events {
            self.queue.push(te.at_s, te.event.clone());
        }
    }

    /// Schedule a derived event mid-run (e.g. a storm's auto-relax). Not
    /// part of the script: it does not survive a rearm.
    pub fn schedule(&mut self, at_s: f64, event: ScenarioEvent) {
        self.queue.push(at_s, event);
    }

    /// Pop every event due at sim time `now`, in nondecreasing time order.
    pub fn pop_due(&mut self, now: f64) -> Vec<(f64, ScenarioEvent)> {
        self.queue.drain_due(now)
    }

    /// Events still scheduled.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Checkpoint image of the armed queue — pending entries with their
    /// ORIGINAL sequence numbers plus the pop frontier, so derived
    /// (mid-run [`ScenarioRuntime::schedule`]d) events like a storm's
    /// auto-relax survive a checkpoint/restore even though they would not
    /// survive a [`ScenarioRuntime::rearm`].
    pub fn snapshot_queue(&self) -> crate::sim::engine::QueueState<ScenarioEvent> {
        self.queue.snapshot()
    }

    /// Restore the armed queue mid-timeline (checkpoint restore). The
    /// script itself is rebuilt by the caller from config; this overwrites
    /// whatever `rearm` loaded with the snapshot's exact pending set.
    pub fn restore_queue(&mut self, state: crate::sim::engine::QueueState<ScenarioEvent>) {
        self.queue.restore(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_parse_validate_and_roundtrip() {
        for name in BUILTIN_SCENARIOS {
            let s = ScenarioScript::by_name(name).unwrap();
            assert!(!s.is_empty(), "{name} empty");
            s.validate(8).unwrap();
            let back = ScenarioScript::from_json(&s.to_json()).unwrap();
            assert_eq!(back, s, "{name} JSON roundtrip drifted");
        }
        assert!(ScenarioScript::by_name("nope").is_err());
    }

    #[test]
    fn preempt_rejoin_contains_churn() {
        let s = ScenarioScript::by_name("preempt_rejoin").unwrap();
        let preempts = s
            .events
            .iter()
            .filter(|e| matches!(e.event, ScenarioEvent::PreemptWorker { .. }))
            .count();
        let rejoins = s
            .events
            .iter()
            .filter(|e| matches!(e.event, ScenarioEvent::RejoinWorker { .. }))
            .count();
        assert!(preempts >= 1 && rejoins >= 1);
        assert_eq!(preempts, rejoins, "every preemption pairs with a rejoin");
    }

    #[test]
    fn validate_rejects_bad_events() {
        let mk = |event| ScenarioScript {
            name: "t".into(),
            events: vec![TimedEvent { at_s: 1.0, event }],
        };
        assert!(mk(ScenarioEvent::PreemptWorker { worker: 9 }).validate(4).is_err());
        assert!(mk(ScenarioEvent::SlowdownWorker { worker: 0, factor: 0.0 })
            .validate(4)
            .is_err());
        assert!(mk(ScenarioEvent::BandwidthDrop { factor: -1.0 }).validate(4).is_err());
        assert!(mk(ScenarioEvent::CongestionStorm { level: 2.0, duration_s: 1.0 })
            .validate(4)
            .is_err());
        assert!(mk(ScenarioEvent::LoadShift { worker: 0, load_mean: 1.5 })
            .validate(4)
            .is_err());
        // Negative time.
        let bad = ScenarioScript {
            name: "t".into(),
            events: vec![TimedEvent {
                at_s: -1.0,
                event: ScenarioEvent::CongestionRelax,
            }],
        };
        assert!(bad.validate(4).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let s = ScenarioScript::by_name("spot_chaos").unwrap();
        let dir = std::env::temp_dir().join(format!("dynamix_scn_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("chaos.json");
        s.save(&p).unwrap();
        let back = ScenarioScript::load(&p).unwrap();
        assert_eq!(back, s);
        // resolve() prefers the file path, falls back to the catalogue.
        assert_eq!(ScenarioScript::resolve(p.to_str().unwrap()).unwrap(), s);
        assert_eq!(
            ScenarioScript::resolve("load_shift").unwrap().name,
            "load_shift"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn runtime_drains_in_order_and_rearms() {
        let s = ScenarioScript::by_name("preempt_rejoin").unwrap();
        let n = s.events.len();
        let mut rt = ScenarioRuntime::new(s);
        assert_eq!(rt.pending(), n);
        assert!(rt.pop_due(0.0).is_empty());
        let first = rt.pop_due(1.5);
        assert_eq!(first.len(), 2, "events at 0.6 and 1.2 due by t=1.5");
        assert!(first[0].0 <= first[1].0);
        let rest = rt.pop_due(1e9);
        assert_eq!(first.len() + rest.len(), n);
        rt.rearm();
        assert_eq!(rt.pending(), n, "rearm restores the full script");
    }

    #[test]
    fn derived_events_do_not_survive_rearm() {
        let mut rt = ScenarioRuntime::empty();
        rt.schedule(1.0, ScenarioEvent::CongestionRelax);
        assert_eq!(rt.pending(), 1);
        rt.rearm();
        assert_eq!(rt.pending(), 0);
    }

    #[test]
    fn queue_snapshot_preserves_derived_events_mid_timeline() {
        let s = ScenarioScript::by_name("preempt_rejoin").unwrap();
        let mut rt = ScenarioRuntime::new(s.clone());
        let drained = rt.pop_due(1.5);
        assert_eq!(drained.len(), 2);
        // A derived event (storm auto-relax style) that rearm would drop.
        rt.schedule(2.0, ScenarioEvent::CongestionRelax);
        let snap = rt.snapshot_queue();
        let expect: Vec<(f64, ScenarioEvent)> = rt.pop_due(1e9);
        // Fresh runtime as a restore would build it: rearm then overwrite.
        let mut rt2 = ScenarioRuntime::new(s);
        rt2.restore_queue(snap);
        assert_eq!(rt2.pop_due(1e9), expect);
    }

    #[test]
    fn synthetic_churn_is_valid_and_paired() {
        let s = ScenarioScript::synthetic_churn(8, 300, 0.02);
        assert_eq!(s.events.len(), 300);
        s.validate(8).unwrap();
        // Alternating preempt/rejoin on the same worker: the cluster can
        // never lose more than one worker at a time.
        for w in s.events.windows(3).step_by(3) {
            if let (ScenarioEvent::PreemptWorker { worker: a }, ScenarioEvent::RejoinWorker { worker: b }) =
                (&w[0].event, &w[1].event)
            {
                assert_eq!(a, b);
            } else {
                panic!("unexpected churn pattern");
            }
        }
    }
}
