//! Stochastic background-dynamics processes.
//!
//! One [`DynamicsProcess`] trait over the two processes the simulators
//! evolve per sim-time step, previously duplicated across
//! `cluster::WorkerState::advance` and `netsim::NetworkSim::advance`:
//!
//! * [`OuProcess`]         — a clamped Ornstein–Uhlenbeck level (the shared
//!   fabric congestion process);
//! * [`ContentionProcess`] — OU *plus* Poisson bursts (per-worker
//!   background load: multi-tenant neighbours arriving).
//!
//! Both keep their own [`Rng`] stream, so scenario events that mutate the
//! process parameters mid-run (load shifts, congestion storms) never
//! perturb any other component's randomness — the determinism contract the
//! scripted-scenario experiments rely on.

use crate::util::rng::Rng;

/// A mean-reverting scalar process advanced by sim time.
pub trait DynamicsProcess {
    /// Current level.
    fn value(&self) -> f64;
    /// Advance by `dt` simulated seconds.
    fn advance(&mut self, dt: f64);
    /// Long-run mean the process reverts toward (mutable mid-run by
    /// scenario events: `LoadShift`, `CongestionStorm`).
    fn mean(&self) -> f64;
    fn set_mean(&mut self, mean: f64);
    /// Force the level directly (clamped to the process bounds).
    fn set_level(&mut self, level: f64);
}

/// Checkpoint image of one process: every scalar plus the raw RNG state.
/// Shared by [`OuProcess`] and [`ContentionProcess`] (the OU subset —
/// burst parameters ride in the contention-specific fields and are zero
/// for a plain OU process).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ProcessState {
    pub level: f64,
    pub mean: f64,
    pub rate: f64,
    pub vol: f64,
    pub burst_rate: f64,
    pub burst_level: f64,
    pub lo: f64,
    pub hi: f64,
    pub rng: [u64; 4],
}

/// Clamped Ornstein–Uhlenbeck process:
/// `dX = rate·(mean − X)·dt + vol·√dt·N(0,1)`, clamped to `[lo, hi]`.
#[derive(Clone, Debug)]
pub struct OuProcess {
    level: f64,
    mean: f64,
    pub rate: f64,
    pub vol: f64,
    lo: f64,
    hi: f64,
    rng: Rng,
}

impl OuProcess {
    pub fn new(mean: f64, rate: f64, vol: f64, lo: f64, hi: f64, rng: Rng) -> Self {
        OuProcess {
            level: mean.clamp(lo, hi),
            mean,
            rate,
            vol,
            lo,
            hi,
            rng,
        }
    }

    /// Capture the full process state (checkpointing).
    pub fn snapshot(&self) -> ProcessState {
        ProcessState {
            level: self.level,
            mean: self.mean,
            rate: self.rate,
            vol: self.vol,
            burst_rate: 0.0,
            burst_level: 0.0,
            lo: self.lo,
            hi: self.hi,
            rng: self.rng.state(),
        }
    }

    /// Overwrite every field from a [`ProcessState`]: the restored
    /// process continues the original trajectory bit-for-bit.
    pub fn restore(&mut self, s: &ProcessState) {
        self.level = s.level;
        self.mean = s.mean;
        self.rate = s.rate;
        self.vol = s.vol;
        self.lo = s.lo;
        self.hi = s.hi;
        self.rng = Rng::from_state(s.rng);
    }
}

impl DynamicsProcess for OuProcess {
    fn value(&self) -> f64 {
        self.level
    }

    fn advance(&mut self, dt: f64) {
        let drift = self.rate * (self.mean - self.level) * dt;
        let diffusion = self.vol * dt.sqrt() * self.rng.normal();
        self.level = (self.level + drift + diffusion).clamp(self.lo, self.hi);
    }

    fn mean(&self) -> f64 {
        self.mean
    }

    fn set_mean(&mut self, mean: f64) {
        self.mean = mean.clamp(self.lo, self.hi);
    }

    fn set_level(&mut self, level: f64) {
        self.level = level.clamp(self.lo, self.hi);
    }
}

/// OU contention level plus Poisson bursts (per-worker background load).
///
/// Per advance: OU drift + diffusion, then a Poisson draw at
/// `burst_rate·dt` which, when it fires, adds `burst_level`; the sum is
/// clamped to `[lo, hi]`. Draw order (normal, then Poisson) matches the
/// original `cluster::WorkerState::advance`, so load trajectories are
/// unchanged for a given RNG stream.
#[derive(Clone, Debug)]
pub struct ContentionProcess {
    level: f64,
    mean: f64,
    pub rate: f64,
    pub vol: f64,
    pub burst_rate: f64,
    pub burst_level: f64,
    lo: f64,
    hi: f64,
    rng: Rng,
}

impl ContentionProcess {
    pub fn new(
        mean: f64,
        rate: f64,
        vol: f64,
        burst_rate: f64,
        burst_level: f64,
        lo: f64,
        hi: f64,
        rng: Rng,
    ) -> Self {
        ContentionProcess {
            level: mean.clamp(lo, hi),
            mean,
            rate,
            vol,
            burst_rate,
            burst_level,
            lo,
            hi,
            rng,
        }
    }

    /// Capture the full process state (checkpointing).
    pub fn snapshot(&self) -> ProcessState {
        ProcessState {
            level: self.level,
            mean: self.mean,
            rate: self.rate,
            vol: self.vol,
            burst_rate: self.burst_rate,
            burst_level: self.burst_level,
            lo: self.lo,
            hi: self.hi,
            rng: self.rng.state(),
        }
    }

    /// Overwrite every field from a [`ProcessState`]: the restored
    /// process continues the original trajectory bit-for-bit.
    pub fn restore(&mut self, s: &ProcessState) {
        self.level = s.level;
        self.mean = s.mean;
        self.rate = s.rate;
        self.vol = s.vol;
        self.burst_rate = s.burst_rate;
        self.burst_level = s.burst_level;
        self.lo = s.lo;
        self.hi = s.hi;
        self.rng = Rng::from_state(s.rng);
    }
}

impl DynamicsProcess for ContentionProcess {
    fn value(&self) -> f64 {
        self.level
    }

    fn advance(&mut self, dt: f64) {
        let drift = self.rate * (self.mean - self.level) * dt;
        let diffusion = self.vol * dt.sqrt() * self.rng.normal();
        self.level += drift + diffusion;
        let bursts = self.rng.poisson(self.burst_rate * dt);
        if bursts > 0 {
            self.level += self.burst_level;
        }
        self.level = self.level.clamp(self.lo, self.hi);
    }

    fn mean(&self) -> f64 {
        self.mean
    }

    fn set_mean(&mut self, mean: f64) {
        self.mean = mean.clamp(self.lo, self.hi);
    }

    fn set_level(&mut self, level: f64) {
        self.level = level.clamp(self.lo, self.hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ou(mean: f64, vol: f64, seed: u64) -> OuProcess {
        OuProcess::new(mean, 0.5, vol, 0.0, 0.9, Rng::new(seed))
    }

    #[test]
    fn ou_stays_bounded_and_moves() {
        let mut p = ou(0.2, 0.1, 1);
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for _ in 0..500 {
            p.advance(0.5);
            assert!((0.0..=0.9).contains(&p.value()));
            lo = lo.min(p.value());
            hi = hi.max(p.value());
        }
        assert!(hi - lo > 0.02, "process frozen: [{lo},{hi}]");
    }

    #[test]
    fn ou_mean_reverts_after_shock() {
        let mut p = ou(0.1, 0.0, 2);
        p.set_level(0.85);
        for _ in 0..200 {
            p.advance(1.0);
        }
        assert!(p.value() < 0.2, "did not revert: {}", p.value());
    }

    #[test]
    fn set_mean_shifts_the_attractor() {
        let mut p = ou(0.05, 0.0, 3);
        p.set_mean(0.6);
        assert_eq!(p.mean(), 0.6);
        for _ in 0..200 {
            p.advance(1.0);
        }
        assert!((p.value() - 0.6).abs() < 0.05, "level {}", p.value());
        // Means clamp to the process bounds.
        p.set_mean(5.0);
        assert_eq!(p.mean(), 0.9);
    }

    #[test]
    fn contention_bursts_push_level_up() {
        let mut quiet =
            ContentionProcess::new(0.1, 0.4, 0.0, 0.0, 0.5, 0.0, 0.95, Rng::new(4));
        let mut bursty =
            ContentionProcess::new(0.1, 0.4, 0.0, 5.0, 0.5, 0.0, 0.95, Rng::new(4));
        let mut sum_q = 0.0;
        let mut sum_b = 0.0;
        for _ in 0..300 {
            quiet.advance(0.1);
            bursty.advance(0.1);
            sum_q += quiet.value();
            sum_b += bursty.value();
            assert!((0.0..=0.95).contains(&bursty.value()));
        }
        assert!(sum_b > sum_q * 1.5, "bursts had no effect: {sum_b} vs {sum_q}");
    }

    #[test]
    fn snapshot_restore_resumes_bitwise() {
        let mut p = ContentionProcess::new(0.2, 0.4, 0.1, 0.05, 0.4, 0.0, 0.95, Rng::new(11));
        for _ in 0..37 {
            p.advance(0.3);
        }
        let snap = p.snapshot();
        let tail: Vec<u64> = (0..50)
            .map(|_| {
                p.advance(0.3);
                p.value().to_bits()
            })
            .collect();
        let mut q = ContentionProcess::new(0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, Rng::new(0));
        q.restore(&snap);
        let tail2: Vec<u64> = (0..50)
            .map(|_| {
                q.advance(0.3);
                q.value().to_bits()
            })
            .collect();
        assert_eq!(tail, tail2);

        let mut o = OuProcess::new(0.3, 0.5, 0.2, 0.0, 0.9, Rng::new(12));
        o.advance(1.0);
        let snap = o.snapshot();
        let mut o2 = OuProcess::new(0.0, 0.0, 0.0, 0.0, 1.0, Rng::new(0));
        o2.restore(&snap);
        o.advance(0.7);
        o2.advance(0.7);
        assert_eq!(o.value().to_bits(), o2.value().to_bits());
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut p =
                ContentionProcess::new(0.2, 0.4, 0.1, 0.05, 0.4, 0.0, 0.95, Rng::new(seed));
            (0..50).map(|_| {
                p.advance(0.3);
                p.value()
            }).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
