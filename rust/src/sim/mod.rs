//! Discrete-event simulation core.
//!
//! DYNAMIX's pitch is adaptation to *heterogeneous, dynamic* environments
//! (paper §I, §II-B), but the original simulator could only express
//! stationary dynamics: OU/Poisson parameters were frozen at construction
//! inside `cluster` and `netsim`. This subsystem makes time-varying
//! environments first-class:
//!
//! * [`engine`]   — a monotone event queue keyed on the sim clock; the
//!   substrate every scripted scenario drains from.
//! * [`process`]  — the [`process::DynamicsProcess`] trait plus the OU and
//!   OU+Poisson-burst processes previously duplicated across
//!   `cluster::WorkerState` and `netsim::NetworkSim`.
//! * [`scenario`] — the `ScenarioScript` DSL: timed events (worker
//!   slowdowns, bandwidth drops, congestion storms, preemption/rejoin,
//!   load shifts) parseable from JSON, with named built-in scenarios.
//! * [`elastic`]  — pure helpers for elastic worker membership: batch
//!   budget redistribution on preemption and valid-batch restoration on
//!   rejoin (shared by the trainer and the invariants test-suite).
//!
//! The layering is strict: `sim` depends only on `util` (json, rng), so
//! `cluster`, `netsim`, `trainer` and `config` can all build on it without
//! cycles.

pub mod elastic;
pub mod engine;
pub mod process;
pub mod scenario;
