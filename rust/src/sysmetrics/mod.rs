//! Simulated eBPF system-metrics collector + temporal aggregation.
//!
//! The paper's data-collection module runs eBPF programs in-kernel to
//! sample CPU-time ratios and memory utilization with negligible overhead
//! (§V). The simulator substitutes a collector driven by the same signals
//! the eBPF programs would observe — the worker's compute activity and
//! background contention — while keeping the metric *schema* identical:
//!
//! * `cpu_time_ratio`  — total CPU time / wall time over the window
//!   (> 1 means effective multi-core parallelism, §IV-B);
//! * `mem_util`        — fraction of device/host memory in use.
//!
//! [`WindowAggregator`] implements the paper's k-iteration temporal
//! aggregation (§III-C): decisions consume window statistics (mean/std),
//! never single-iteration samples.

use crate::cluster::{ComputeOutcome, WorkerProfile};

/// One iteration's raw system-metric sample for one worker.
#[derive(Clone, Copy, Debug, Default)]
pub struct SysSample {
    pub cpu_time_ratio: f64,
    pub mem_util: f64,
}

/// Simulated collector for one worker.
pub struct Collector {
    /// Parallel efficiency of the training process on this worker
    /// (how many core-seconds per wall-second it achieves unloaded).
    pub parallel_width: f64,
}

impl Default for Collector {
    fn default() -> Self {
        // The paper's workers drive one GPU from a multi-core host; the
        // host side typically sustains 2-4 busy cores (dataloader + NCCL).
        Collector { parallel_width: 3.0 }
    }
}

impl Collector {
    /// Sample the window given the worker's compute outcome and batch.
    ///
    /// Contention steals cores (ratio drops toward 1-load); memory tracks
    /// parameter + activation footprint against the profile's capacity.
    pub fn sample(
        &self,
        profile: &WorkerProfile,
        outcome: &ComputeOutcome,
        param_count: usize,
        batch: usize,
    ) -> SysSample {
        let cpu_time_ratio = (self.parallel_width * (1.0 - outcome.load)).max(0.05);
        let param_mib = (param_count * 4 * 3) as f64 / (1024.0 * 1024.0);
        let act_mib = batch as f64 * 12.0;
        let mem_util = ((param_mib + act_mib) / profile.mem_mib + outcome.load * 0.1)
            .clamp(0.0, 1.0);
        SysSample {
            cpu_time_ratio,
            mem_util,
        }
    }
}

/// Streaming mean/std accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stat {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stat {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn reset(&mut self) {
        *self = Stat::default();
    }
}

/// The paper's k-iteration aggregation window for one worker (§III-C).
///
/// Collects every per-iteration signal the RL state needs; `finish()`
/// yields the window summary and clears for the next cycle.
#[derive(Clone, Debug, Default)]
pub struct WindowAggregator {
    pub batch_acc: Stat,
    pub iter_time: Stat,
    pub throughput_gbps: Stat,
    pub cpu_time_ratio: Stat,
    pub mem_util: Stat,
    pub sigma_norm: Stat,
    pub sigma_norm2: Stat,
    pub loss: Stat,
    pub retransmissions: f64,
    /// z-scored batch-accuracy series for the paper's sliding-window
    /// accuracy-gain statistic (§IV-B).
    acc_series: Vec<f64>,
}

/// Window summary handed to the RL state builder.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowSummary {
    pub acc_mean: f64,
    pub acc_std: f64,
    /// Sliding-window accuracy gain ΔA (§IV-B).
    pub acc_gain: f64,
    pub iter_time_mean: f64,
    pub throughput_mean: f64,
    pub retransmissions: f64,
    pub cpu_time_ratio: f64,
    pub mem_util: f64,
    pub sigma_norm: f64,
    pub sigma_norm2: f64,
    pub loss_mean: f64,
    pub iters: u64,
}

impl WindowAggregator {
    pub fn push_iteration(
        &mut self,
        acc: f64,
        loss: f64,
        iter_time_s: f64,
        throughput_gbps: f64,
        retx: u64,
        sys: SysSample,
        sigma_norm: f64,
        sigma_norm2: f64,
    ) {
        self.batch_acc.push(acc);
        self.loss.push(loss);
        self.iter_time.push(iter_time_s);
        self.throughput_gbps.push(throughput_gbps);
        self.retransmissions += retx as f64;
        self.cpu_time_ratio.push(sys.cpu_time_ratio);
        self.mem_util.push(sys.mem_util);
        self.sigma_norm.push(sigma_norm);
        self.sigma_norm2.push(sigma_norm2);
        self.acc_series.push(acc);
    }

    /// ΔA per §IV-B: z-score the window's accuracy series, average the
    /// first and last thirds, return (last − first).
    fn acc_gain(&self) -> f64 {
        let n = self.acc_series.len();
        if n < 3 {
            return 0.0;
        }
        let mean = self.batch_acc.mean();
        let std = self.batch_acc.std().max(1e-6);
        let z: Vec<f64> = self.acc_series.iter().map(|a| (a - mean) / std).collect();
        let w = (n / 3).max(1);
        let first: f64 = z[..w].iter().sum::<f64>() / w as f64;
        let last: f64 = z[n - w..].iter().sum::<f64>() / w as f64;
        last - first
    }

    /// Produce the window summary and reset for the next k iterations.
    pub fn finish(&mut self) -> WindowSummary {
        let s = WindowSummary {
            acc_mean: self.batch_acc.mean(),
            acc_std: self.batch_acc.std(),
            acc_gain: self.acc_gain(),
            iter_time_mean: self.iter_time.mean(),
            throughput_mean: self.throughput_gbps.mean(),
            retransmissions: self.retransmissions,
            cpu_time_ratio: self.cpu_time_ratio.mean(),
            mem_util: self.mem_util.mean(),
            sigma_norm: self.sigma_norm.mean(),
            sigma_norm2: self.sigma_norm2.mean(),
            loss_mean: self.loss.mean(),
            iters: self.batch_acc.count(),
        };
        *self = WindowAggregator::default();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{profiles, ComputeOutcome};
    use crate::config::ClusterPreset;

    fn outcome(load: f64) -> ComputeOutcome {
        ComputeOutcome {
            compute_s: 0.1,
            load,
            effective_speed: 1.0 - load,
        }
    }

    #[test]
    fn stat_welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut s = Stat::default();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 5.0;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 16.0);
    }

    #[test]
    fn collector_ratio_drops_under_load() {
        let prof = &profiles(ClusterPreset::UniformA100, 1, 0)[0];
        let c = Collector::default();
        let idle = c.sample(prof, &outcome(0.0), 1_000_000, 128);
        let busy = c.sample(prof, &outcome(0.8), 1_000_000, 128);
        assert!(idle.cpu_time_ratio > 1.0, "multi-core ratio > 1 when idle");
        assert!(busy.cpu_time_ratio < idle.cpu_time_ratio);
    }

    #[test]
    fn collector_mem_grows_with_batch() {
        let prof = &profiles(ClusterPreset::UniformA100, 1, 0)[0];
        let c = Collector::default();
        let small = c.sample(prof, &outcome(0.1), 25_000, 32);
        let large = c.sample(prof, &outcome(0.1), 25_000, 1024);
        assert!(large.mem_util > small.mem_util);
        assert!(large.mem_util <= 1.0);
    }

    #[test]
    fn window_aggregates_and_resets() {
        let mut w = WindowAggregator::default();
        for i in 0..5 {
            w.push_iteration(
                0.5 + 0.05 * i as f64,
                2.0 - 0.1 * i as f64,
                0.1,
                5.0,
                10,
                SysSample {
                    cpu_time_ratio: 2.0,
                    mem_util: 0.3,
                },
                0.9,
                0.81,
            );
        }
        let s = w.finish();
        assert_eq!(s.iters, 5);
        assert!((s.acc_mean - 0.6).abs() < 1e-9);
        assert!((s.retransmissions - 50.0).abs() < 1e-9);
        assert!(s.acc_gain > 0.5, "rising accuracy must give positive gain");
        // reset happened
        let s2 = w.finish();
        assert_eq!(s2.iters, 0);
    }

    #[test]
    fn acc_gain_negative_when_accuracy_falls() {
        let mut w = WindowAggregator::default();
        for i in 0..6 {
            w.push_iteration(
                0.9 - 0.05 * i as f64,
                1.0,
                0.1,
                5.0,
                0,
                SysSample::default(),
                0.5,
                0.25,
            );
        }
        assert!(w.finish().acc_gain < -0.5);
    }

    #[test]
    fn acc_gain_zero_for_flat_or_short_series() {
        let mut w = WindowAggregator::default();
        w.push_iteration(0.5, 1.0, 0.1, 1.0, 0, SysSample::default(), 0.1, 0.01);
        assert_eq!(w.finish().acc_gain, 0.0);
        let mut w = WindowAggregator::default();
        for _ in 0..5 {
            w.push_iteration(0.7, 1.0, 0.1, 1.0, 0, SysSample::default(), 0.1, 0.01);
        }
        assert!(w.finish().acc_gain.abs() < 1e-9);
    }
}
