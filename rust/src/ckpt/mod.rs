//! Crash-consistent checkpoint/restore + event-sourced run journal.
//!
//! Durable elastic runs: the coordinator periodically captures **every**
//! piece of mutable run state — model/optimizer, cluster + fabric
//! processes (with their raw RNG streams), shard samplers, the remaining
//! scenario timeline, the RL agent (policy params + Adam moments +
//! exploration RNG), convergence detector, calibration refs and the
//! record-so-far — into one binary [`ResumeState`] image. All of that
//! state is flat buffers and scalars, so serialization is a straight
//! field walk over `comm::wire`'s [`Encoder`]/[`Decoder`]; nothing is
//! approximated, which is what makes a restored run continue the
//! original **bit-for-bit** (`tests/checkpoint_restore.rs` pins a
//! SIGKILL-mid-run → restore → bitwise-identical-record oracle).
//!
//! Crash consistency is temp-file + rename: a checkpoint is visible under
//! its final `ckpt-<step>.bin` name only after its bytes are durably
//! written, so a kill at ANY point leaves either the previous checkpoint
//! or a complete new one — never a torn file. Restore picks the
//! highest-step image in the directory.
//!
//! Every image opens with a fingerprint header ([`CkptHeader`]): the
//! gradient plane (`DYNAMIX_PLANE`), wire codec (`DYNAMIX_WIRE`), seed,
//! worker count and model. A restore under a different deployment is
//! rejected loudly, naming both values — resuming a zero-plane run on the
//! replica plane (or across wire codecs) would silently diverge instead
//! of resuming, exactly the mixed-deployment hazard the sharded
//! handshake already rejects.
//!
//! The run **journal** (`journal.jsonl`) is the event-sourced side: one
//! JSON line per decision cycle, per applied scenario/membership event
//! and per checkpoint, each stamped with the SIM clock (never wall time —
//! `dynamix-lint`'s wall-clock rule covers this module). The journal is
//! append-only and a reader tolerates a torn final line, so it survives
//! kill -9 too and lets a restore (or a human) re-trace how the timeline
//! was re-armed mid-run.

use crate::comm::wire::{Decoder, Encoder, WireMode};
use crate::metrics::{DetectorState, RunRecord, TracePoint};
use crate::rl::agent::AgentState;
use crate::runtime::OptState;
use crate::sim::engine::QueueState;
use crate::sim::process::ProcessState;
use crate::sim::scenario::ScenarioEvent;
use crate::trainer::TrainerState;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// File magic: identifies a DYNAMIX checkpoint image.
pub const MAGIC: &[u8; 8] = b"DYNXCKPT";
/// Bump on any layout change; old images are rejected loudly.
pub const CKPT_VERSION: u16 = 1;

/// Deployment fingerprint. A checkpoint taken under one deployment must
/// not silently resume under another: the restored trajectory would
/// diverge from the original instead of continuing it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CkptHeader {
    /// Gradient exchange plane (`DYNAMIX_PLANE`: `zero` | `replica`).
    pub plane: String,
    /// Gradient-slice wire codec (`DYNAMIX_WIRE`: `dense` | `topk` | `q8`).
    pub wire: String,
    pub seed: u64,
    pub n_workers: usize,
    pub model: String,
}

impl CkptHeader {
    fn encode(&self, e: &mut Encoder) {
        e.str(&self.plane);
        e.str(&self.wire);
        e.u64(self.seed);
        e.u64(self.n_workers as u64);
        e.str(&self.model);
    }

    fn decode(d: &mut Decoder) -> anyhow::Result<CkptHeader> {
        Ok(CkptHeader {
            plane: d.str()?,
            wire: d.str()?,
            seed: d.u64()?,
            n_workers: d.u64()? as usize,
            model: d.str()?,
        })
    }

    /// Reject a cross-deployment restore, naming both values.
    pub fn check(&self, expect: &CkptHeader) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.plane == expect.plane,
            "checkpoint was taken under DYNAMIX_PLANE={:?} but this run uses \
             DYNAMIX_PLANE={:?}; a cross-plane resume would diverge instead of \
             continuing — restart fresh or match the plane",
            self.plane,
            expect.plane
        );
        anyhow::ensure!(
            self.wire == expect.wire,
            "checkpoint was taken under DYNAMIX_WIRE={:?} but this run uses \
             DYNAMIX_WIRE={:?}; a cross-codec resume would diverge instead of \
             continuing — restart fresh or match the codec",
            self.wire,
            expect.wire
        );
        anyhow::ensure!(
            self.seed == expect.seed,
            "checkpoint seed {} != this run's seed {}",
            self.seed,
            expect.seed
        );
        anyhow::ensure!(
            self.n_workers == expect.n_workers,
            "checkpoint is for {} workers, this run has {}",
            self.n_workers,
            expect.n_workers
        );
        anyhow::ensure!(
            self.model == expect.model,
            "checkpoint model {:?} != this run's model {:?}",
            self.model,
            expect.model
        );
        Ok(())
    }
}

/// Plain-data image of the pending `CycleOutcome` (the window summary the
/// next action will be chosen from).
#[derive(Clone, Debug, PartialEq)]
pub struct CycleSnap {
    pub states: Vec<Vec<f32>>,
    pub rewards: Vec<f64>,
    pub active: Vec<bool>,
    pub sim_clock: f64,
    pub train_acc: f64,
    pub eval_acc: f64,
    pub loss: f64,
}

/// Everything a resumed inference run needs to continue bit-for-bit.
#[derive(Clone, Debug)]
pub struct ResumeState {
    /// Decision-cycle index to resume AT (the checkpoint was taken at the
    /// top of this cycle, before its trace point was recorded).
    pub step: usize,
    pub trainer: TrainerState,
    pub agent: AgentState,
    pub detector: DetectorState,
    pub eval_history: Vec<f64>,
    pub calibrated: bool,
    /// `StateBuilder::iter_time_ref` (first-window calibration).
    pub state_iter_time_ref: f64,
    /// `RewardParams::iter_time_ref`.
    pub reward_iter_time_ref: f64,
    /// The record as of this checkpoint (points for cycles `< step`).
    pub record: RunRecord,
    /// The pending cycle outcome the resumed loop acts on.
    pub cycle: CycleSnap,
}

// --- field-walk codecs ---

fn enc_opt(e: &mut Encoder, o: &OptState) {
    e.f32s(&o.params);
    e.f32s(&o.m);
    e.f32s(&o.v);
    e.f32(o.step);
}

fn dec_opt(d: &mut Decoder) -> anyhow::Result<OptState> {
    Ok(OptState {
        params: d.f32s()?,
        m: d.f32s()?,
        v: d.f32s()?,
        step: d.f32()?,
    })
}

fn enc_process(e: &mut Encoder, p: &ProcessState) {
    e.f64(p.level);
    e.f64(p.mean);
    e.f64(p.rate);
    e.f64(p.vol);
    e.f64(p.burst_rate);
    e.f64(p.burst_level);
    e.f64(p.lo);
    e.f64(p.hi);
    enc_rng(e, &p.rng);
}

fn dec_process(d: &mut Decoder) -> anyhow::Result<ProcessState> {
    Ok(ProcessState {
        level: d.f64()?,
        mean: d.f64()?,
        rate: d.f64()?,
        vol: d.f64()?,
        burst_rate: d.f64()?,
        burst_level: d.f64()?,
        lo: d.f64()?,
        hi: d.f64()?,
        rng: dec_rng(d)?,
    })
}

fn enc_rng(e: &mut Encoder, s: &[u64; 4]) {
    for &w in s {
        e.u64(w);
    }
}

fn dec_rng(d: &mut Decoder) -> anyhow::Result<[u64; 4]> {
    Ok([d.u64()?, d.u64()?, d.u64()?, d.u64()?])
}

fn enc_profile(e: &mut Encoder, p: &crate::cluster::WorkerProfile) {
    e.f64(p.speed);
    e.f64(p.mem_mib);
    e.f64(p.bandwidth_gbps);
    e.f64(p.latency_ms);
    e.f64(p.load_mean);
    e.f64(p.load_rate);
    e.f64(p.load_vol);
    e.f64(p.burst_rate);
    e.f64(p.burst_level);
}

fn dec_profile(d: &mut Decoder) -> anyhow::Result<crate::cluster::WorkerProfile> {
    Ok(crate::cluster::WorkerProfile {
        speed: d.f64()?,
        mem_mib: d.f64()?,
        bandwidth_gbps: d.f64()?,
        latency_ms: d.f64()?,
        load_mean: d.f64()?,
        load_rate: d.f64()?,
        load_vol: d.f64()?,
        burst_rate: d.f64()?,
        burst_level: d.f64()?,
    })
}

fn enc_option_f64(e: &mut Encoder, v: Option<f64>) {
    match v {
        Some(x) => {
            e.u8(1);
            e.f64(x);
        }
        None => e.u8(0),
    }
}

fn dec_option_f64(d: &mut Decoder) -> anyhow::Result<Option<f64>> {
    Ok(match d.u8()? {
        0 => None,
        _ => Some(d.f64()?),
    })
}

fn enc_trainer(e: &mut Encoder, t: &TrainerState) {
    enc_opt(e, &t.opt);
    e.f64(t.cluster.clock);
    e.f64(t.cluster.barrier_s);
    e.f64(t.cluster.cost.base_us_per_sample);
    e.f64(t.cluster.cost.fixed_us);
    e.u32(t.cluster.workers.len() as u32);
    for w in &t.cluster.workers {
        e.u8(w.active as u8);
        enc_profile(e, &w.profile);
        enc_profile(e, &w.base);
        enc_process(e, &w.load);
    }
    enc_rng(e, &t.net.rng);
    enc_process(e, &t.net.congestion);
    e.f64(t.net.base_mean);
    e.u8(t.net.noisy as u8);
    e.f64(t.net.retx_per_gib);
    e.u32(t.samplers.len() as u32);
    for s in &t.samplers {
        e.u64(s.worker as u64);
        e.u64(s.n_workers as u64);
        e.u64(s.train_size as u64);
        e.u64(s.seed);
        e.u64(s.epoch);
        e.u64(s.cursor as u64);
    }
    e.u32(t.batches.len() as u32);
    for &b in &t.batches {
        e.u64(b as u64);
    }
    e.u64(t.iter as u64);
    e.u32(t.scenario_queue.entries.len() as u32);
    for (time, seq, ev) in &t.scenario_queue.entries {
        e.f64(*time);
        e.u64(*seq);
        e.str(&ev.to_json().to_string());
    }
    e.u64(t.scenario_queue.seq);
    e.f64(t.scenario_queue.last_popped);
    e.u32(t.events_applied.len() as u32);
    for (at, desc) in &t.events_applied {
        e.f64(*at);
        e.str(desc);
    }
    e.u64(t.shard_seed);
    e.u64(t.membership_rev);
    e.u8(t.overlap_sync as u8);
    e.u64(t.bucket_bytes as u64);
    e.str(t.wire_sync.label());
}

fn dec_trainer(d: &mut Decoder) -> anyhow::Result<TrainerState> {
    let opt = dec_opt(d)?;
    let clock = d.f64()?;
    let barrier_s = d.f64()?;
    let cost = crate::cluster::ComputeCostModel {
        base_us_per_sample: d.f64()?,
        fixed_us: d.f64()?,
    };
    let nw = d.u32()? as usize;
    let mut workers = Vec::with_capacity(nw);
    for _ in 0..nw {
        workers.push(crate::cluster::WorkerSnap {
            active: d.u8()? != 0,
            profile: dec_profile(d)?,
            base: dec_profile(d)?,
            load: dec_process(d)?,
        });
    }
    let cluster = crate::cluster::ClusterState {
        clock,
        barrier_s,
        cost,
        workers,
    };
    let net = crate::netsim::NetSimState {
        rng: dec_rng(d)?,
        congestion: dec_process(d)?,
        base_mean: d.f64()?,
        noisy: d.u8()? != 0,
        retx_per_gib: d.f64()?,
    };
    let ns = d.u32()? as usize;
    let mut samplers = Vec::with_capacity(ns);
    for _ in 0..ns {
        samplers.push(crate::data::SamplerState {
            worker: d.u64()? as usize,
            n_workers: d.u64()? as usize,
            train_size: d.u64()? as usize,
            seed: d.u64()?,
            epoch: d.u64()?,
            cursor: d.u64()? as usize,
        });
    }
    let nb = d.u32()? as usize;
    let mut batches = Vec::with_capacity(nb);
    for _ in 0..nb {
        batches.push(d.u64()? as usize);
    }
    let iter = d.u64()? as usize;
    let nq = d.u32()? as usize;
    let mut entries = Vec::with_capacity(nq);
    for _ in 0..nq {
        let time = d.f64()?;
        let seq = d.u64()?;
        let ev = ScenarioEvent::from_json(&Json::parse(&d.str()?)?)?;
        entries.push((time, seq, ev));
    }
    let scenario_queue = QueueState {
        entries,
        seq: d.u64()?,
        last_popped: d.f64()?,
    };
    let ne = d.u32()? as usize;
    let mut events_applied = Vec::with_capacity(ne);
    for _ in 0..ne {
        events_applied.push((d.f64()?, d.str()?));
    }
    Ok(TrainerState {
        opt,
        cluster,
        net,
        samplers,
        batches,
        iter,
        scenario_queue,
        events_applied,
        shard_seed: d.u64()?,
        membership_rev: d.u64()?,
        overlap_sync: d.u8()? != 0,
        bucket_bytes: d.u64()? as usize,
        wire_sync: WireMode::parse(&d.str()?)?,
    })
}

fn enc_record(e: &mut Encoder, r: &RunRecord) {
    e.str(&r.name);
    e.u32(r.points.len() as u32);
    for p in &r.points {
        e.u64(p.iter as u64);
        e.f64(p.sim_time);
        e.f64(p.train_acc);
        e.f64(p.eval_acc);
        e.f64(p.loss);
        e.f64(p.batch_mean);
        e.f64(p.batch_std);
        e.u64(p.global_batch as u64);
    }
    e.f64(r.final_eval_acc);
    enc_option_f64(e, r.convergence_time);
    e.f64(r.total_sim_time);
    e.u64(r.total_iters as u64);
    e.str(&Json::Obj(r.extra.clone()).to_string());
}

fn dec_record(d: &mut Decoder) -> anyhow::Result<RunRecord> {
    let name = d.str()?;
    let np = d.u32()? as usize;
    let mut points = Vec::with_capacity(np);
    for _ in 0..np {
        points.push(TracePoint {
            iter: d.u64()? as usize,
            sim_time: d.f64()?,
            train_acc: d.f64()?,
            eval_acc: d.f64()?,
            loss: d.f64()?,
            batch_mean: d.f64()?,
            batch_std: d.f64()?,
            global_batch: d.u64()? as usize,
        });
    }
    let final_eval_acc = d.f64()?;
    let convergence_time = dec_option_f64(d)?;
    let total_sim_time = d.f64()?;
    let total_iters = d.u64()? as usize;
    let extra = match Json::parse(&d.str()?)? {
        Json::Obj(m) => m,
        other => anyhow::bail!("record extras must be a JSON object, got {other:?}"),
    };
    Ok(RunRecord {
        name,
        points,
        final_eval_acc,
        convergence_time,
        total_sim_time,
        total_iters,
        extra,
    })
}

fn enc_cycle(e: &mut Encoder, c: &CycleSnap) {
    e.u32(c.states.len() as u32);
    for s in &c.states {
        e.f32s(s);
    }
    e.u32(c.rewards.len() as u32);
    for &r in &c.rewards {
        e.f64(r);
    }
    e.u32(c.active.len() as u32);
    for &a in &c.active {
        e.u8(a as u8);
    }
    e.f64(c.sim_clock);
    e.f64(c.train_acc);
    e.f64(c.eval_acc);
    e.f64(c.loss);
}

fn dec_cycle(d: &mut Decoder) -> anyhow::Result<CycleSnap> {
    let ns = d.u32()? as usize;
    let mut states = Vec::with_capacity(ns);
    for _ in 0..ns {
        states.push(d.f32s()?);
    }
    let nr = d.u32()? as usize;
    let mut rewards = Vec::with_capacity(nr);
    for _ in 0..nr {
        rewards.push(d.f64()?);
    }
    let na = d.u32()? as usize;
    let mut active = Vec::with_capacity(na);
    for _ in 0..na {
        active.push(d.u8()? != 0);
    }
    Ok(CycleSnap {
        states,
        rewards,
        active,
        sim_clock: d.f64()?,
        train_acc: d.f64()?,
        eval_acc: d.f64()?,
        loss: d.f64()?,
    })
}

/// Serialize `(header, state)` into one image (magic + version + body).
pub fn encode(header: &CkptHeader, s: &ResumeState) -> Vec<u8> {
    let mut e = Encoder::new();
    e.u16(CKPT_VERSION);
    header.encode(&mut e);
    e.u64(s.step as u64);
    enc_trainer(&mut e, &s.trainer);
    enc_opt(&mut e, &s.agent.opt);
    enc_rng(&mut e, &s.agent.rng);
    e.f64(s.detector.target_acc);
    e.u64(s.detector.patience as u64);
    e.u64(s.detector.hits as u64);
    enc_option_f64(&mut e, s.detector.streak_start);
    e.u8(s.detector.latched as u8);
    e.u32(s.eval_history.len() as u32);
    for &v in &s.eval_history {
        e.f64(v);
    }
    e.u8(s.calibrated as u8);
    e.f64(s.state_iter_time_ref);
    e.f64(s.reward_iter_time_ref);
    enc_record(&mut e, &s.record);
    enc_cycle(&mut e, &s.cycle);
    let body = e.frame();
    let mut out = Vec::with_capacity(body.len() + MAGIC.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&body[4..]); // drop the frame length: file-sized
    out
}

/// Deserialize an image, validating magic/version and the deployment
/// fingerprint against `expect`.
pub fn decode(bytes: &[u8], expect: &CkptHeader) -> anyhow::Result<ResumeState> {
    anyhow::ensure!(
        bytes.len() > MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC,
        "not a DYNAMIX checkpoint (bad magic)"
    );
    let mut d = Decoder::new(&bytes[MAGIC.len()..]);
    let version = d.u16()?;
    anyhow::ensure!(
        version == CKPT_VERSION,
        "checkpoint version {version} unsupported (expected {CKPT_VERSION})"
    );
    let header = CkptHeader::decode(&mut d)?;
    header.check(expect)?;
    let step = d.u64()? as usize;
    let trainer = dec_trainer(&mut d)?;
    let agent = AgentState {
        opt: dec_opt(&mut d)?,
        rng: dec_rng(&mut d)?,
    };
    let detector = DetectorState {
        target_acc: d.f64()?,
        patience: d.u64()? as usize,
        hits: d.u64()? as usize,
        streak_start: dec_option_f64(&mut d)?,
        latched: d.u8()? != 0,
    };
    let nh = d.u32()? as usize;
    let mut eval_history = Vec::with_capacity(nh);
    for _ in 0..nh {
        eval_history.push(d.f64()?);
    }
    let calibrated = d.u8()? != 0;
    let state_iter_time_ref = d.f64()?;
    let reward_iter_time_ref = d.f64()?;
    let record = dec_record(&mut d)?;
    let cycle = dec_cycle(&mut d)?;
    d.finish()?;
    Ok(ResumeState {
        step,
        trainer,
        agent,
        detector,
        eval_history,
        calibrated,
        state_iter_time_ref,
        reward_iter_time_ref,
        record,
        cycle,
    })
}

/// Checkpoint filename for a decision-cycle step.
pub fn file_name(step: usize) -> String {
    format!("ckpt-{step}.bin")
}

/// Write `bytes` to `dir/name` atomically: they land in a dot-prefixed
/// temp file first and are `rename`d into place, so a crash at any
/// instant leaves either the previous image or a complete new one.
fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(".{name}.tmp"));
    let fin = dir.join(name);
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, &fin)?;
    Ok(fin)
}

/// Atomically write `ckpt-<step>.bin` under `dir` (see [`write_atomic`]).
pub fn save_atomic(dir: &Path, header: &CkptHeader, s: &ResumeState) -> anyhow::Result<PathBuf> {
    write_atomic(dir, &file_name(s.step), &encode(header, s))
}

/// Highest-step `ckpt-<step>.bin` under `dir`, if any. Temp files and
/// foreign names are ignored.
pub fn latest(dir: &Path) -> Option<(usize, PathBuf)> {
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in std::fs::read_dir(dir).ok()? {
        let entry = entry.ok()?;
        let name = entry.file_name();
        let name = name.to_str()?;
        let step = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".bin"))
            .and_then(|s| s.parse::<usize>().ok());
        if let Some(step) = step {
            if best.as_ref().map_or(true, |(b, _)| step > *b) {
                best = Some((step, entry.path()));
            }
        }
    }
    best
}

/// Retention GC over numbered checkpoint images: delete every
/// `<prefix><n>.bin` under `dir` except the `keep` highest-numbered ones.
/// Runs AFTER a successful atomic write, so the newest image is always in
/// the kept set; the journal and every non-matching file are untouched.
/// Best-effort by design — an unreadable directory or a failed unlink is
/// a warning on stderr, never an error: losing a prune is benign (the
/// next save retries), while failing a save over it would not be.
/// Returns the paths actually removed (the unit tests pin the set).
pub fn prune_numbered(dir: &Path, prefix: &str, keep: usize) -> Vec<PathBuf> {
    let mut found: Vec<(usize, PathBuf)> = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[ckpt] retention scan of {dir:?} failed: {e}");
            return Vec::new();
        }
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let num = name
            .strip_prefix(prefix)
            .and_then(|s| s.strip_suffix(".bin"))
            .and_then(|s| s.parse::<usize>().ok());
        if let Some(num) = num {
            found.push((num, entry.path()));
        }
    }
    if found.len() <= keep {
        return Vec::new();
    }
    // Newest first; everything past the first `keep` goes.
    found.sort_by(|a, b| b.0.cmp(&a.0));
    let mut removed = Vec::new();
    for (_, path) in found.drain(keep..) {
        match std::fs::remove_file(&path) {
            Ok(()) => removed.push(path),
            Err(e) => eprintln!("[ckpt] retention prune of {path:?} failed: {e}"),
        }
    }
    removed
}

/// Retention GC for the single-process coordinator's `ckpt-<step>.bin`
/// images (`DYNAMIX_CKPT_KEEP` / `--ckpt-keep`).
pub fn prune(dir: &Path, keep: usize) -> Vec<PathBuf> {
    prune_numbered(dir, "ckpt-", keep)
}

/// Load and validate the image at `path`.
pub fn load(path: &Path, expect: &CkptHeader) -> anyhow::Result<ResumeState> {
    let bytes =
        std::fs::read(path).map_err(|e| anyhow::anyhow!("checkpoint {path:?}: {e}"))?;
    decode(&bytes, expect).map_err(|e| anyhow::anyhow!("checkpoint {path:?}: {e}"))
}

/// File magic of a deployed-leader checkpoint image.
pub const LEADER_MAGIC: &[u8; 8] = b"DYNXLDRC";

/// Durable snapshot of the deployed TCP leader (`comm::leader::serve_n`):
/// the leader's mirror of the trained parameters (its own optimizer
/// replica on the replica plane; the all-gathered slices on the zero
/// plane, where the slice-local optimizer moments live worker-side and
/// are not captured), the per-worker batch assignment, and the cycle
/// index. This is the warm-start artifact of a deployed run — the
/// single-process Coordinator has the full bitwise [`ResumeState`]
/// restore; a distributed restore additionally re-registers the workers.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaderCkpt {
    pub header: CkptHeader,
    /// Decision cycles completed when the image was taken.
    pub cycle: usize,
    pub opt: OptState,
    /// Per-worker batch assignment at the checkpoint (registered-id order).
    pub batches: Vec<u64>,
}

impl LeaderCkpt {
    /// `leader-<cycle>.bin`.
    pub fn file_name(cycle: usize) -> String {
        format!("leader-{cycle}.bin")
    }

    /// Serialize into one image (magic + version + fingerprint + body).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        e.u16(CKPT_VERSION);
        self.header.encode(&mut e);
        e.u64(self.cycle as u64);
        enc_opt(&mut e, &self.opt);
        e.u32(self.batches.len() as u32);
        for &b in &self.batches {
            e.u64(b);
        }
        let body = e.frame();
        let mut out = Vec::with_capacity(body.len() + LEADER_MAGIC.len());
        out.extend_from_slice(LEADER_MAGIC);
        out.extend_from_slice(&body[4..]);
        out
    }

    /// Deserialize, validating magic/version and the deployment
    /// fingerprint against `expect`.
    pub fn decode(bytes: &[u8], expect: &CkptHeader) -> anyhow::Result<LeaderCkpt> {
        anyhow::ensure!(
            bytes.len() > LEADER_MAGIC.len() && &bytes[..LEADER_MAGIC.len()] == LEADER_MAGIC,
            "not a DYNAMIX leader checkpoint (bad magic)"
        );
        let mut d = Decoder::new(&bytes[LEADER_MAGIC.len()..]);
        let version = d.u16()?;
        anyhow::ensure!(
            version == CKPT_VERSION,
            "leader checkpoint version {version} unsupported (expected {CKPT_VERSION})"
        );
        let header = CkptHeader::decode(&mut d)?;
        header.check(expect)?;
        let cycle = d.u64()? as usize;
        let opt = dec_opt(&mut d)?;
        let nb = d.u32()? as usize;
        let mut batches = Vec::with_capacity(nb);
        for _ in 0..nb {
            batches.push(d.u64()?);
        }
        d.finish()?;
        Ok(LeaderCkpt { header, cycle, opt, batches })
    }

    /// Atomically write `leader-<cycle>.bin` under `dir`.
    pub fn save_atomic(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        write_atomic(dir, &Self::file_name(self.cycle), &self.encode())
    }

    /// Highest-cycle `leader-<cycle>.bin` under `dir`, if any.
    pub fn latest(dir: &Path) -> Option<(usize, PathBuf)> {
        let mut best: Option<(usize, PathBuf)> = None;
        for entry in std::fs::read_dir(dir).ok()? {
            let entry = entry.ok()?;
            let name = entry.file_name();
            let name = name.to_str()?;
            let cycle = name
                .strip_prefix("leader-")
                .and_then(|s| s.strip_suffix(".bin"))
                .and_then(|s| s.parse::<usize>().ok());
            if let Some(cycle) = cycle {
                if best.as_ref().map_or(true, |(b, _)| cycle > *b) {
                    best = Some((cycle, entry.path()));
                }
            }
        }
        best
    }

    /// Retention GC for `leader-<cycle>.bin` images — same
    /// keep-the-newest-k, warn-don't-fail contract as [`prune`].
    pub fn prune(dir: &Path, keep: usize) -> Vec<PathBuf> {
        prune_numbered(dir, "leader-", keep)
    }

    /// Load and validate the image at `path`.
    pub fn load(path: &Path, expect: &CkptHeader) -> anyhow::Result<LeaderCkpt> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("leader checkpoint {path:?}: {e}"))?;
        Self::decode(&bytes, expect)
            .map_err(|e| anyhow::anyhow!("leader checkpoint {path:?}: {e}"))
    }
}

/// Append-only run journal: one JSON line per applied scenario event,
/// membership change, decision cycle and checkpoint. Lines carry the sim
/// clock only — never wall time — so a journal is as replayable as the
/// run it describes.
pub struct Journal {
    path: PathBuf,
}

impl Journal {
    /// Open (creating the directory if needed) `journal.jsonl` under `dir`.
    pub fn open(dir: &Path) -> anyhow::Result<Journal> {
        std::fs::create_dir_all(dir)?;
        Ok(Journal {
            path: dir.join("journal.jsonl"),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one line. Each call opens/appends/closes so a crash between
    /// lines never holds a torn buffer — at worst the final line is torn,
    /// which [`Journal::read`] tolerates.
    pub fn append(&self, line: &Json) -> anyhow::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{line}")?;
        Ok(())
    }

    /// One decision cycle: `step` index, sim clock, iteration counter,
    /// global batch, eval accuracy.
    pub fn cycle(
        &self,
        step: usize,
        sim_time: f64,
        iter: usize,
        global_batch: usize,
        eval_acc: f64,
    ) -> anyhow::Result<()> {
        self.append(&crate::jobj! {
            "kind" => "cycle",
            "step" => step,
            "sim_time" => sim_time,
            "iter" => iter,
            "global_batch" => global_batch,
            "eval_acc" => eval_acc,
        })
    }

    /// One applied scenario/membership event (sim-time stamped).
    pub fn event(&self, at_s: f64, desc: &str) -> anyhow::Result<()> {
        self.append(&crate::jobj! {
            "kind" => "event",
            "at_s" => at_s,
            "event" => desc.to_string(),
        })
    }

    /// One checkpoint written at `step` / sim clock.
    pub fn checkpoint(&self, step: usize, sim_time: f64) -> anyhow::Result<()> {
        self.append(&crate::jobj! {
            "kind" => "ckpt",
            "step" => step,
            "sim_time" => sim_time,
        })
    }

    /// Read every parseable line under `dir`. A torn FINAL line (the kill
    /// -9 case) is skipped; corruption anywhere else is an error. Missing
    /// file reads as empty.
    pub fn read(dir: &Path) -> anyhow::Result<Vec<Json>> {
        let path = dir.join("journal.jsonl");
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(anyhow::anyhow!("journal {path:?}: {e}")),
        };
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        let mut out = Vec::with_capacity(lines.len());
        for (i, line) in lines.iter().enumerate() {
            match Json::parse(line) {
                Ok(v) => out.push(v),
                Err(_) if i + 1 == lines.len() => break, // torn tail
                Err(e) => {
                    anyhow::bail!("journal {path:?} line {}: {e}", i + 1)
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, ComputeCostModel, WorkerProfile, WorkerSnap};
    use crate::data::SamplerState;
    use crate::netsim::NetSimState;

    fn profile(x: f64) -> WorkerProfile {
        WorkerProfile {
            speed: x,
            mem_mib: 24_000.0,
            bandwidth_gbps: 25.0,
            latency_ms: 0.15,
            load_mean: 0.05,
            load_rate: 0.5,
            load_vol: 0.05,
            burst_rate: 0.005,
            burst_level: 0.3,
        }
    }

    fn process(l: f64) -> ProcessState {
        ProcessState {
            level: l,
            mean: 0.1,
            rate: 0.5,
            vol: 0.05,
            burst_rate: 0.01,
            burst_level: 0.3,
            lo: 0.0,
            hi: 0.95,
            rng: [1, 2, 3, 4],
        }
    }

    fn header() -> CkptHeader {
        CkptHeader {
            plane: "zero".into(),
            wire: "dense".into(),
            seed: 42,
            n_workers: 2,
            model: "vgg11_mini".into(),
        }
    }

    fn sample_state() -> ResumeState {
        let opt = OptState {
            params: vec![1.0, -2.5, 0.0],
            m: vec![0.1, 0.2, 0.3],
            v: vec![0.4],
            step: 7.0,
        };
        let trainer = TrainerState {
            opt: opt.clone(),
            cluster: ClusterState {
                clock: 1.25,
                barrier_s: 0.002,
                cost: ComputeCostModel {
                    base_us_per_sample: 12.0,
                    fixed_us: 8_000.0,
                },
                workers: vec![
                    WorkerSnap {
                        active: true,
                        profile: profile(1.0),
                        base: profile(1.0),
                        load: process(0.1),
                    },
                    WorkerSnap {
                        active: false,
                        profile: profile(0.5),
                        base: profile(1.0),
                        load: process(0.6),
                    },
                ],
            },
            net: NetSimState {
                rng: [9, 8, 7, 6],
                congestion: process(0.3),
                base_mean: 0.05,
                noisy: true,
                retx_per_gib: 900.0,
            },
            samplers: vec![
                SamplerState {
                    worker: 0,
                    n_workers: 2,
                    train_size: 50_000,
                    seed: 42,
                    epoch: 1,
                    cursor: 123,
                },
                SamplerState {
                    worker: 1,
                    n_workers: 2,
                    train_size: 50_000,
                    seed: 42,
                    epoch: 1,
                    cursor: 124,
                },
            ],
            batches: vec![64, 96],
            iter: 17,
            scenario_queue: QueueState {
                entries: vec![
                    (2.0, 3, ScenarioEvent::CongestionRelax),
                    (
                        5.0,
                        1,
                        ScenarioEvent::RejoinWorker { worker: 1 },
                    ),
                ],
                seq: 4,
                last_popped: 1.2,
            },
            events_applied: vec![(0.5, "preempt_worker w1".into())],
            shard_seed: 42,
            membership_rev: 1,
            overlap_sync: true,
            bucket_bytes: 32 << 10,
            wire_sync: WireMode::Dense,
        };
        let mut record = RunRecord::new("test-run");
        record.push(TracePoint {
            iter: 4,
            sim_time: 0.8,
            train_acc: 0.4,
            eval_acc: 0.35,
            loss: 1.7,
            batch_mean: 80.0,
            batch_std: 16.0,
            global_batch: 160,
        });
        record.extra.insert("scenario".into(), Json::Str("t".into()));
        ResumeState {
            step: 2,
            trainer,
            agent: AgentState {
                opt,
                rng: [11, 12, 13, 14],
            },
            detector: DetectorState {
                target_acc: 0.8,
                patience: 2,
                hits: 1,
                streak_start: Some(0.8),
                latched: false,
            },
            eval_history: vec![0.2, 0.35],
            calibrated: true,
            state_iter_time_ref: 0.09,
            reward_iter_time_ref: 0.09,
            record,
            cycle: CycleSnap {
                states: vec![vec![0.1; 16], vec![0.0; 16]],
                rewards: vec![1.5, 0.0],
                active: vec![true, false],
                sim_clock: 1.25,
                train_acc: 0.41,
                eval_acc: 0.35,
                loss: 1.68,
            },
        }
    }

    fn assert_state_eq(a: &ResumeState, b: &ResumeState) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.trainer.opt.params, b.trainer.opt.params);
        assert_eq!(a.trainer.opt.m, b.trainer.opt.m);
        assert_eq!(a.trainer.opt.v, b.trainer.opt.v);
        assert_eq!(a.trainer.opt.step, b.trainer.opt.step);
        assert_eq!(a.trainer.cluster.clock, b.trainer.cluster.clock);
        assert_eq!(
            a.trainer.cluster.workers.len(),
            b.trainer.cluster.workers.len()
        );
        for (x, y) in a
            .trainer
            .cluster
            .workers
            .iter()
            .zip(&b.trainer.cluster.workers)
        {
            assert_eq!(x.active, y.active);
            assert_eq!(x.profile.speed, y.profile.speed);
            assert_eq!(x.load, y.load);
        }
        assert_eq!(a.trainer.net, b.trainer.net);
        assert_eq!(a.trainer.samplers, b.trainer.samplers);
        assert_eq!(a.trainer.batches, b.trainer.batches);
        assert_eq!(a.trainer.iter, b.trainer.iter);
        assert_eq!(
            a.trainer.scenario_queue.entries,
            b.trainer.scenario_queue.entries
        );
        assert_eq!(a.trainer.scenario_queue.seq, b.trainer.scenario_queue.seq);
        assert_eq!(
            a.trainer.scenario_queue.last_popped,
            b.trainer.scenario_queue.last_popped
        );
        assert_eq!(a.trainer.events_applied, b.trainer.events_applied);
        assert_eq!(a.trainer.wire_sync, b.trainer.wire_sync);
        assert_eq!(a.agent.opt.params, b.agent.opt.params);
        assert_eq!(a.agent.rng, b.agent.rng);
        assert_eq!(a.detector, b.detector);
        assert_eq!(a.eval_history, b.eval_history);
        assert_eq!(a.calibrated, b.calibrated);
        assert_eq!(a.state_iter_time_ref, b.state_iter_time_ref);
        assert_eq!(a.record.points.len(), b.record.points.len());
        assert_eq!(a.record.name, b.record.name);
        assert_eq!(a.record.extra, b.record.extra);
        assert_eq!(a.cycle, b.cycle);
    }

    #[test]
    fn image_roundtrips_every_field() {
        let h = header();
        let s = sample_state();
        let bytes = encode(&h, &s);
        let back = decode(&bytes, &h).unwrap();
        assert_state_eq(&s, &back);
    }

    #[test]
    fn rejects_wrong_magic_version_and_truncation() {
        let h = header();
        let s = sample_state();
        let bytes = encode(&h, &s);
        // Magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(decode(&bad, &h).unwrap_err().to_string().contains("magic"));
        // Version.
        let mut bad = bytes.clone();
        bad[8] ^= 0xFF;
        assert!(decode(&bad, &h)
            .unwrap_err()
            .to_string()
            .contains("version"));
        // Truncation (a torn write that bypassed the atomic rename).
        assert!(decode(&bytes[..bytes.len() - 3], &h).is_err());
    }

    #[test]
    fn rejects_cross_deployment_restore_naming_both_values() {
        let h = header();
        let bytes = encode(&h, &sample_state());
        let mut other = header();
        other.plane = "replica".into();
        let err = decode(&bytes, &other).unwrap_err().to_string();
        assert!(err.contains("\"zero\"") && err.contains("\"replica\""), "{err}");
        assert!(err.contains("DYNAMIX_PLANE"), "{err}");
        let mut other = header();
        other.wire = "q8".into();
        let err = decode(&bytes, &other).unwrap_err().to_string();
        assert!(err.contains("\"dense\"") && err.contains("\"q8\""), "{err}");
        assert!(err.contains("DYNAMIX_WIRE"), "{err}");
        let mut other = header();
        other.seed = 7;
        assert!(decode(&bytes, &other).is_err());
    }

    #[test]
    fn save_atomic_and_latest_pick_highest_step() {
        let dir = std::env::temp_dir().join(format!("dynamix_ckpt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let h = header();
        let mut s = sample_state();
        for step in [0usize, 4, 2] {
            s.step = step;
            save_atomic(&dir, &h, &s).unwrap();
        }
        // A stray temp file and a foreign file must both be ignored.
        std::fs::write(dir.join(".ckpt-9.tmp"), b"junk").unwrap();
        std::fs::write(dir.join("notes.txt"), b"junk").unwrap();
        let (step, path) = latest(&dir).expect("checkpoints exist");
        assert_eq!(step, 4);
        let back = load(&path, &h).unwrap();
        assert_eq!(back.step, 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prune_keeps_newest_k_and_spares_everything_else() {
        let dir = std::env::temp_dir().join(format!("dynamix_prune_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let h = header();
        let mut s = sample_state();
        // Out-of-order writes: retention ranks by step, not mtime.
        for step in [3usize, 11, 1, 7, 5] {
            s.step = step;
            save_atomic(&dir, &h, &s).unwrap();
        }
        // The journal, temp files, foreign names, and leader images must
        // all survive a ckpt- prune.
        std::fs::write(dir.join("journal.jsonl"), b"{}\n").unwrap();
        std::fs::write(dir.join(".ckpt-99.tmp"), b"junk").unwrap();
        std::fs::write(dir.join("notes.txt"), b"junk").unwrap();
        std::fs::write(dir.join("leader-2.bin"), b"junk").unwrap();
        std::fs::write(dir.join("ckpt-x.bin"), b"junk").unwrap();

        let removed = prune(&dir, 2);
        let mut gone: Vec<String> = removed
            .iter()
            .map(|p| p.file_name().unwrap().to_str().unwrap().to_string())
            .collect();
        gone.sort();
        assert_eq!(gone, ["ckpt-1.bin", "ckpt-3.bin", "ckpt-5.bin"]);
        assert!(dir.join("ckpt-11.bin").exists());
        assert!(dir.join("ckpt-7.bin").exists());
        assert!(dir.join("journal.jsonl").exists(), "the journal is never pruned");
        assert!(dir.join(".ckpt-99.tmp").exists());
        assert!(dir.join("notes.txt").exists());
        assert!(dir.join("leader-2.bin").exists(), "ckpt- prune must not touch leader images");
        assert!(dir.join("ckpt-x.bin").exists(), "non-numeric names are foreign");
        // The survivors still restore, and latest() still resolves.
        let (step, path) = latest(&dir).expect("kept checkpoints exist");
        assert_eq!(step, 11);
        assert_eq!(load(&path, &h).unwrap().step, 11);

        // At or under the retention floor: a no-op, not an error.
        assert!(prune(&dir, 2).is_empty());
        assert!(prune(&dir, 10).is_empty());
        // A missing directory warns and removes nothing.
        assert!(prune(&dir.join("nope"), 1).is_empty());

        // Leader-image retention uses the same core on its own prefix.
        for cycle in [2usize, 9, 4] {
            std::fs::write(dir.join(LeaderCkpt::file_name(cycle)), b"junk").unwrap();
        }
        let removed = LeaderCkpt::prune(&dir, 1);
        let mut gone: Vec<String> = removed
            .iter()
            .map(|p| p.file_name().unwrap().to_str().unwrap().to_string())
            .collect();
        gone.sort();
        assert_eq!(gone, ["leader-2.bin", "leader-4.bin"]);
        assert!(dir.join("leader-9.bin").exists());
        assert!(dir.join("ckpt-11.bin").exists(), "leader prune must not touch ckpt images");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn leader_image_roundtrips_and_rejects_cross_deployment() {
        let dir =
            std::env::temp_dir().join(format!("dynamix_leaderckpt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let lc = LeaderCkpt {
            header: header(),
            cycle: 3,
            opt: OptState {
                params: vec![0.5, -1.5],
                m: vec![0.1, 0.2],
                v: vec![0.3],
                step: 9.0,
            },
            batches: vec![64, 96],
        };
        lc.save_atomic(&dir).unwrap();
        let mut later = lc.clone();
        later.cycle = 7;
        later.save_atomic(&dir).unwrap();
        let (cycle, path) = LeaderCkpt::latest(&dir).expect("leader images exist");
        assert_eq!(cycle, 7);
        let back = LeaderCkpt::load(&path, &header()).unwrap();
        assert_eq!(back, later);
        // The same fingerprint gate as the full image: cross-plane load
        // must fail naming both values.
        let mut other = header();
        other.plane = "replica".into();
        let err = LeaderCkpt::load(&path, &other).unwrap_err().to_string();
        assert!(err.contains("\"zero\"") && err.contains("\"replica\""), "{err}");
        // Bad magic: a full-image file is not a leader image.
        let full = encode(&header(), &sample_state());
        let err = LeaderCkpt::decode(&full, &header()).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn journal_appends_and_tolerates_torn_tail() {
        let dir = std::env::temp_dir().join(format!("dynamix_journal_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let j = Journal::open(&dir).unwrap();
        j.cycle(0, 0.5, 2, 256, 0.3).unwrap();
        j.event(0.4, "preempt_worker w3").unwrap();
        j.checkpoint(1, 0.5).unwrap();
        // Simulate a kill -9 mid-append: a torn final line.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(j.path())
                .unwrap();
            f.write_all(b"{\"kind\":\"cycle\",\"ste").unwrap();
        }
        let lines = Journal::read(&dir).unwrap();
        assert_eq!(lines.len(), 3, "torn tail skipped");
        assert_eq!(lines[0].get("kind").and_then(Json::as_str), Some("cycle"));
        assert_eq!(lines[1].get("kind").and_then(Json::as_str), Some("event"));
        assert_eq!(lines[2].get("kind").and_then(Json::as_str), Some("ckpt"));
        assert_eq!(Journal::read(&dir.join("missing")).unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
