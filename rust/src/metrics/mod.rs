//! Run recording + convergence measurement.
//!
//! Every experiment harness produces a [`RunRecord`]: the accuracy/loss
//! trajectory against *simulated* cluster time, batch-size traces, and the
//! convergence summary the paper's tables report (final accuracy,
//! time-to-convergence). Records serialize to JSON (plots) and CSV
//! (eyeballing) under `runs/`.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One sampled point of a training run.
#[derive(Clone, Debug)]
pub struct TracePoint {
    pub iter: usize,
    pub sim_time: f64,
    pub train_acc: f64,
    pub eval_acc: f64,
    pub loss: f64,
    /// Mean per-worker batch size at this point.
    pub batch_mean: f64,
    /// Std of per-worker batch sizes.
    pub batch_std: f64,
    pub global_batch: usize,
}

/// A full run: config echo + trajectory + summary.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub name: String,
    pub points: Vec<TracePoint>,
    pub final_eval_acc: f64,
    /// Simulated seconds to reach the convergence target (None = never).
    pub convergence_time: Option<f64>,
    pub total_sim_time: f64,
    pub total_iters: usize,
    /// Free-form extras (episode rewards, overhead stats, ...).
    pub extra: BTreeMap<String, Json>,
}

impl RunRecord {
    pub fn new(name: &str) -> Self {
        RunRecord {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn push(&mut self, p: TracePoint) {
        self.total_sim_time = p.sim_time;
        self.total_iters = p.iter;
        self.points.push(p);
    }

    /// Best eval accuracy seen (the paper reports final/converged acc).
    pub fn best_eval_acc(&self) -> f64 {
        self.points.iter().map(|p| p.eval_acc).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                crate::jobj! {
                    "iter" => p.iter,
                    "sim_time" => p.sim_time,
                    "train_acc" => p.train_acc,
                    "eval_acc" => p.eval_acc,
                    "loss" => p.loss,
                    "batch_mean" => p.batch_mean,
                    "batch_std" => p.batch_std,
                    "global_batch" => p.global_batch,
                }
            })
            .collect();
        let mut obj = crate::jobj! {
            "name" => self.name.clone(),
            "final_eval_acc" => self.final_eval_acc,
            "total_sim_time" => self.total_sim_time,
            "total_iters" => self.total_iters,
            "points" => Json::Arr(points),
        };
        if let Json::Obj(m) = &mut obj {
            m.insert(
                "convergence_time".into(),
                match self.convergence_time {
                    Some(t) => Json::Num(t),
                    None => Json::Null,
                },
            );
            for (k, v) in &self.extra {
                m.insert(k.clone(), v.clone());
            }
        }
        obj
    }

    pub fn save_json(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut s = String::from(
            "iter,sim_time,train_acc,eval_acc,loss,batch_mean,batch_std,global_batch\n",
        );
        for p in &self.points {
            s.push_str(&format!(
                "{},{:.3},{:.4},{:.4},{:.4},{:.1},{:.1},{}\n",
                p.iter,
                p.sim_time,
                p.train_acc,
                p.eval_acc,
                p.loss,
                p.batch_mean,
                p.batch_std,
                p.global_batch
            ));
        }
        std::fs::write(path, s)?;
        Ok(())
    }
}

/// Convergence detector: target accuracy sustained over `patience`
/// consecutive eval points (filters single-eval noise spikes).
#[derive(Clone, Debug)]
pub struct ConvergenceDetector {
    pub target_acc: f64,
    pub patience: usize,
    hits: usize,
    streak_start: Option<f64>,
    latched: bool,
}

impl ConvergenceDetector {
    pub fn new(target_acc: f64, patience: usize) -> Self {
        ConvergenceDetector {
            target_acc,
            patience: patience.max(1),
            hits: 0,
            streak_start: None,
            latched: false,
        }
    }

    /// Feed one eval point; returns Some(time) once converged (time =
    /// first eval of the sustained streak). Latches after convergence.
    pub fn observe(&mut self, eval_acc: f64, sim_time: f64) -> Option<f64> {
        if self.latched {
            return self.streak_start;
        }
        if eval_acc >= self.target_acc {
            if self.hits == 0 {
                self.streak_start = Some(sim_time);
            }
            self.hits += 1;
            if self.hits >= self.patience {
                self.latched = true;
                return self.streak_start;
            }
            None
        } else {
            self.hits = 0;
            self.streak_start = None;
            None
        }
    }

    pub fn converged(&self) -> bool {
        self.latched
    }

    pub fn time(&self) -> Option<f64> {
        if self.latched {
            self.streak_start
        } else {
            None
        }
    }

    /// Checkpoint image (the streak counters are mid-run state).
    pub fn snapshot(&self) -> DetectorState {
        DetectorState {
            target_acc: self.target_acc,
            patience: self.patience,
            hits: self.hits,
            streak_start: self.streak_start,
            latched: self.latched,
        }
    }

    /// Rebuild a detector mid-streak from a [`DetectorState`].
    pub fn from_snapshot(s: &DetectorState) -> Self {
        ConvergenceDetector {
            target_acc: s.target_acc,
            patience: s.patience,
            hits: s.hits,
            streak_start: s.streak_start,
            latched: s.latched,
        }
    }
}

/// Serializable checkpoint image of a [`ConvergenceDetector`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DetectorState {
    pub target_acc: f64,
    pub patience: usize,
    pub hits: usize,
    pub streak_start: Option<f64>,
    pub latched: bool,
}

/// Mean/std of a slice (population std).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Mean/std over usize slices (batch-size traces).
pub fn mean_std_usize(xs: &[usize]) -> (f64, f64) {
    let v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    mean_std(&v)
}

/// Median of a slice (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(iter: usize, t: f64, acc: f64) -> TracePoint {
        TracePoint {
            iter,
            sim_time: t,
            train_acc: acc,
            eval_acc: acc,
            loss: 1.0 - acc,
            batch_mean: 128.0,
            batch_std: 10.0,
            global_batch: 512,
        }
    }

    #[test]
    fn record_roundtrips_to_json() {
        let mut r = RunRecord::new("test");
        r.push(point(1, 0.5, 0.3));
        r.push(point(2, 1.0, 0.5));
        r.final_eval_acc = 0.5;
        r.convergence_time = Some(1.0);
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("test"));
        assert_eq!(j.get("points").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("convergence_time").unwrap().as_f64(), Some(1.0));
        assert!(Json::parse(&j.to_string()).is_ok());
        assert_eq!(r.best_eval_acc(), 0.5);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut r = RunRecord::new("csv");
        r.push(point(1, 0.5, 0.3));
        let path = std::env::temp_dir().join("dynamix_metrics_test.csv");
        r.save_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iter,sim_time"));
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn convergence_requires_sustained_target() {
        let mut d = ConvergenceDetector::new(0.8, 2);
        assert!(d.observe(0.85, 10.0).is_none(), "one hit not enough");
        assert_eq!(d.observe(0.82, 20.0), Some(10.0), "streak start time");
        assert!(d.converged());
        assert_eq!(d.observe(0.1, 30.0), Some(10.0), "latched");
    }

    #[test]
    fn convergence_resets_on_dip() {
        let mut d = ConvergenceDetector::new(0.8, 2);
        d.observe(0.85, 10.0);
        d.observe(0.5, 20.0);
        assert!(!d.converged());
        d.observe(0.9, 30.0);
        assert_eq!(d.observe(0.9, 40.0), Some(30.0));
    }

    #[test]
    fn detector_snapshot_preserves_mid_streak_state() {
        let mut d = ConvergenceDetector::new(0.8, 3);
        d.observe(0.85, 10.0);
        d.observe(0.82, 20.0); // 2 hits of 3 — mid-streak
        let mut r = ConvergenceDetector::from_snapshot(&d.snapshot());
        assert_eq!(r.observe(0.81, 30.0), Some(10.0), "third hit converges");
        assert_eq!(d.observe(0.81, 30.0), Some(10.0), "original agrees");
        // Latched state survives a roundtrip too.
        let l = ConvergenceDetector::from_snapshot(&r.snapshot());
        assert!(l.converged());
        assert_eq!(l.time(), Some(10.0));
    }

    #[test]
    fn never_converges_below_target() {
        let mut d = ConvergenceDetector::new(0.99, 1);
        for i in 0..10 {
            assert!(d.observe(0.5, i as f64).is_none());
        }
        assert_eq!(d.time(), None);
    }

    #[test]
    fn stats_helpers() {
        let (m, s) = mean_std(&[2.0, 4.0]);
        assert_eq!((m, s), (3.0, 1.0));
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        let (m, _) = mean_std_usize(&[32, 64, 96]);
        assert_eq!(m, 64.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }
}
