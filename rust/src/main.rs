//! `dynamix` CLI: the L3 leader entrypoint.
//!
//! Subcommands:
//!   info                         — manifest + model zoo summary
//!   train-rl   --preset P [...]  — Fig. 3 episodic PPO training
//!   infer      --preset P [...]  — Fig. 4/5 frozen-policy run
//!   baseline   --preset P --batch B — static-batch run
//!   exp        --which fig2|fig3|fig4|table1|fig6|byteps|overhead|dynamics|all
//!   serve      --bind ADDR       — distributed leader (TCP protocol)
//!   worker     --connect ADDR --id N — distributed worker
//!
//! Global flags: `--threads N` pins the native-backend kernel thread
//! count (sets DYNAMIX_THREADS before backend init); `--shards N` selects
//! the sharded loopback data plane (DYNAMIX_BACKEND=sharded +
//! DYNAMIX_SHARDS, bit-identical to native); `--scenario <path|name>`
//! runs train-rl/infer/baseline under a scripted dynamic-environment
//! timeline (JSON file or built-in name).
//!
//! Argument parsing is hand-rolled (offline build, no clap); see
//! `Args::parse`.

use dynamix::config::{presets, Scale};
use dynamix::harness;
use dynamix::runtime::{default_backend, Backend};
use dynamix::sim::scenario::ScenarioScript;
use std::collections::BTreeMap;

/// Minimal `--key value` argument parser.
struct Args {
    cmd: String,
    kv: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut argv = std::env::args().skip(1);
        let cmd = argv.next().unwrap_or_else(|| "help".to_string());
        let mut kv = BTreeMap::new();
        let rest: Vec<String> = argv.collect();
        let mut i = 0;
        while i < rest.len() {
            if let Some(key) = rest[i].strip_prefix("--") {
                let val = if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    i += 1;
                    rest[i].clone()
                } else {
                    "true".to_string()
                };
                kv.insert(key.to_string(), val);
            }
            i += 1;
        }
        Args { cmd, kv }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

const HELP: &str = "dynamix — RL-based adaptive batch size optimization (paper reproduction)

USAGE: dynamix <command> [--key value ...]

COMMANDS:
  info                      show manifest / model zoo / artifact summary
  train-rl  --preset P [--scale quick|full] [--scenario S]
  infer     --preset P [--scale quick|full] [--scenario S]
  baseline  --preset P --batch B [--scale quick|full] [--cycles N]
            [--scenario S]
  exp       --which fig2|fig3|fig4|table1|fig6|byteps|overhead|dynamics|all
            [--scale quick|full]
  serve     --bind 127.0.0.1:7077 --preset P   (distributed leader)
  worker    --connect 127.0.0.1:7077 --preset P --id N
  help

GLOBAL FLAGS:
  --threads N     pin native-backend kernel threads (DYNAMIX_THREADS)
  --kernel T      kernel tier: auto|scalar|blocked|simd (DYNAMIX_KERNEL;
                  simd = AVX2/FMA where the CPU supports it, else the
                  portable blocked fallback; scalar = reference loops)
  --shards N      run the sharded data plane: split every fused batch over
                  N loopback worker shards (sets DYNAMIX_BACKEND=sharded +
                  DYNAMIX_SHARDS; bit-identical to the native backend
                  under every kernel tier)
  --plane P       gradient exchange plane: zero|replica (DYNAMIX_PLANE;
                  zero = ZeRO-style reduce-scatter parameter sharding,
                  the default; replica = the full-replica parity ring)
  --wire M        zero-plane slice codec: dense|topk|q8 (DYNAMIX_WIRE;
                  topk/q8 compress the gradient wire deterministically,
                  trading bit parity with the fused step for bytes)
  --scenario S    scripted dynamic-environment timeline: a JSON file path
                  or a built-in name (preempt_rejoin bandwidth_collapse
                  congestion_storm load_shift spot_chaos)
  --ckpt-dir D    durable runs: write crash-consistent checkpoints + an
                  append-only run journal under D (DYNAMIX_CKPT_DIR;
                  dedicate a directory per run)
  --ckpt-every N  decision cycles between checkpoints (DYNAMIX_CKPT_EVERY,
                  default 1 = every cycle)
  --ckpt-keep K   retention: after each save, prune all but the newest K
                  checkpoint images (DYNAMIX_CKPT_KEEP; default keeps
                  everything; the journal is never pruned)
  --resume        resume from the latest checkpoint under --ckpt-dir
                  (DYNAMIX_RESUME; the deployment fingerprint —
                  plane/wire/seed/workers/model — must match, and the run
                  must use the same cycle horizon as the original)

SERVE FLAGS:
  --workers N --cycles C   demo/smoke sizes for the TCP leader (defaults:
                           the preset's worker count / steps_per_episode)

PRESETS: vgg11-sgd vgg11-adam resnet34-sgd scal-{8,16,32}
         transfer-{vgg16-src,vgg19-dst,resnet34-src,resnet50-dst}
         byteps-hetero ablate-*

BACKEND: DYNAMIX_BACKEND=native|sharded|xla|auto (default auto: xla when
         built with the backend-xla feature and `make artifacts` ran, else
         native; sharded honors DYNAMIX_SHARDS, default 2)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Resolve `--scenario <path|name>` into a script (None when absent).
fn scenario_arg(args: &Args) -> anyhow::Result<Option<ScenarioScript>> {
    match args.get("scenario") {
        None => Ok(None),
        Some(s) => Ok(Some(ScenarioScript::resolve(s)?)),
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse();
    // --threads N must land in the environment BEFORE any backend is
    // constructed (the native kernel pool reads DYNAMIX_THREADS once).
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads expects a positive integer, got {t:?}"))?;
        anyhow::ensure!(n >= 1, "--threads must be >= 1");
        std::env::set_var("DYNAMIX_THREADS", t);
    }
    // --kernel T picks the linalg tier; like --threads it must land in the
    // environment before the first backend is constructed (the process
    // pool reads DYNAMIX_KERNEL exactly once).
    if let Some(k) = args.get("kernel") {
        dynamix::runtime::KernelTier::parse(k)?; // validate loudly
        std::env::set_var("DYNAMIX_KERNEL", k);
    }
    // --shards N selects the sharded loopback data plane, overriding any
    // DYNAMIX_BACKEND already in the environment (explicit flag wins).
    if let Some(s) = args.get("shards") {
        let n: usize = s
            .parse()
            .map_err(|_| anyhow::anyhow!("--shards expects a positive integer, got {s:?}"))?;
        anyhow::ensure!((1..=64).contains(&n), "--shards must be in [1,64]");
        std::env::set_var("DYNAMIX_BACKEND", "sharded");
        std::env::set_var("DYNAMIX_SHARDS", s);
    }
    // --plane / --wire pick the gradient exchange plane and its slice
    // codec; like --kernel they must land in the environment before the
    // backend (or TCP leader/worker) is constructed.
    if let Some(p) = args.get("plane") {
        let p = p.trim().to_ascii_lowercase();
        anyhow::ensure!(
            matches!(p.as_str(), "zero" | "replica"),
            "--plane expects zero|replica, got {p:?}"
        );
        std::env::set_var("DYNAMIX_PLANE", p);
    }
    if let Some(w) = args.get("wire") {
        dynamix::comm::wire::WireMode::parse(w)?; // validate loudly
        std::env::set_var("DYNAMIX_WIRE", w);
    }
    // --ckpt-dir / --ckpt-every / --ckpt-keep / --resume configure durable
    // runs; the
    // coordinator reads these at construction, so they must land in the
    // environment first like every other global flag.
    if let Some(d) = args.get("ckpt-dir") {
        anyhow::ensure!(!d.is_empty(), "--ckpt-dir expects a directory path");
        std::env::set_var("DYNAMIX_CKPT_DIR", d);
    }
    if let Some(n) = args.get("ckpt-every") {
        let every: usize = n
            .parse()
            .map_err(|_| anyhow::anyhow!("--ckpt-every expects a positive integer, got {n:?}"))?;
        anyhow::ensure!(every >= 1, "--ckpt-every must be >= 1");
        std::env::set_var("DYNAMIX_CKPT_EVERY", n);
    }
    if let Some(k) = args.get("ckpt-keep") {
        let keep: usize = k
            .parse()
            .map_err(|_| anyhow::anyhow!("--ckpt-keep expects a positive integer, got {k:?}"))?;
        anyhow::ensure!(keep >= 1, "--ckpt-keep must be >= 1 (the newest image always survives)");
        std::env::set_var("DYNAMIX_CKPT_KEEP", k);
    }
    if args.get("resume").is_some() {
        anyhow::ensure!(
            args.get("ckpt-dir").is_some() || dynamix::config::env::ckpt_dir().is_some(),
            "--resume needs --ckpt-dir (or DYNAMIX_CKPT_DIR) pointing at an \
             existing run's checkpoint directory"
        );
        std::env::set_var("DYNAMIX_RESUME", "1");
    }
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "info" => info(),
        "train-rl" => {
            let store = default_backend()?;
            let preset = args.get_or("preset", "vgg11-sgd");
            let scale = Scale::parse(&args.get_or("scale", "quick"))?;
            harness::fig3_rl_training(store, &preset, scale, scenario_arg(&args)?)?;
            Ok(())
        }
        "infer" => {
            let store = default_backend()?;
            let preset = args.get_or("preset", "vgg11-sgd");
            let scale = Scale::parse(&args.get_or("scale", "quick"))?;
            harness::fig4_fig5_inference(store, &preset, scale, scenario_arg(&args)?)?;
            Ok(())
        }
        "baseline" => {
            let preset = args.get_or("preset", "vgg11-sgd");
            let scale = Scale::parse(&args.get_or("scale", "quick"))?;
            let batch: usize = args.get_or("batch", "64").parse()?;
            let mut cfg = presets::scaled(presets::by_name(&preset)?, scale);
            cfg.batch.initial = batch;
            cfg.scenario = scenario_arg(&args)?;
            cfg.validate()?;
            // The config's shard/kernel/wire requests apply when the
            // environment didn't pick them (see runtime::backend_for /
            // apply_kernel_request / apply_wire_request).
            dynamix::runtime::apply_kernel_request(cfg.kernel.as_deref());
            dynamix::runtime::apply_wire_request(cfg.wire.as_deref());
            let store = dynamix::runtime::backend_for(cfg.shards)?;
            let cycles: usize = args
                .get_or("cycles", &format!("{}", cfg.steps_per_episode))
                .parse()?;
            let mut record =
                dynamix::metrics::RunRecord::new(&format!("{preset}-static{batch}"));
            let mut policy = dynamix::baselines::StaticPolicy(batch);
            let s =
                dynamix::baselines::run_baseline(&cfg, store, &mut policy, cycles, &mut record)?;
            println!(
                "{}: final_acc={:.3} conv_time={:?} sim_time={:.0}s iters={}",
                s.policy, s.final_eval_acc, s.convergence_time, s.total_sim_time, s.total_iters
            );
            Ok(())
        }
        "exp" => {
            let store = default_backend()?;
            let which = args.get_or("which", "all");
            let scale = Scale::parse(&args.get_or("scale", "quick"))?;
            run_experiments(store, &which, scale)
        }
        "serve" => {
            let bind = args.get_or("bind", "127.0.0.1:7077");
            let preset = args.get_or("preset", "vgg11-sgd");
            let scale = Scale::parse(&args.get_or("scale", "quick"))?;
            match (args.get("workers"), args.get("cycles")) {
                (None, None) => dynamix::comm::leader::serve(&bind, &preset, scale),
                (w, c) => {
                    let cfg = presets::scaled(presets::by_name(&preset)?, scale);
                    let workers: usize = match w {
                        Some(v) => v.parse()?,
                        None => cfg.cluster.n_workers,
                    };
                    let cycles: usize = match c {
                        Some(v) => v.parse()?,
                        None => cfg.steps_per_episode,
                    };
                    dynamix::comm::leader::serve_n(&bind, &preset, scale, workers, cycles)
                }
            }
        }
        "worker" => {
            let addr = args.get_or("connect", "127.0.0.1:7077");
            let preset = args.get_or("preset", "vgg11-sgd");
            let scale = Scale::parse(&args.get_or("scale", "quick"))?;
            let id: u32 = args.get_or("id", "0").parse()?;
            dynamix::comm::leader::worker(&addr, &preset, scale, id)
        }
        other => anyhow::bail!("unknown command {other:?}; try `dynamix help`"),
    }
}

fn info() -> anyhow::Result<()> {
    let backend = default_backend()?;
    let m = backend.schema();
    println!("DYNAMIX compute backend: {}", backend.name());
    {
        let pool = dynamix::runtime::native::exec::Pool::global();
        println!(
            "  kernel tier: {} (DYNAMIX_KERNEL; simd supported: {})  threads: {}",
            pool.tier().as_str(),
            dynamix::runtime::native::exec::simd_supported(),
            pool.threads()
        );
    }
    println!(
        "  state_dim={} n_actions={} max_workers={} ppo_minibatch={}",
        m.state_dim, m.n_actions, m.max_workers, m.ppo_minibatch
    );
    println!("  buckets: {:?}", m.buckets);
    println!("  policy params: {}", m.policy_param_count);
    println!("  models:");
    for (name, info) in &m.models {
        println!(
            "    {name:16} family={:8} depth={:2} params={:7} dataset={}",
            info.family, info.depth, info.param_count, info.dataset
        );
    }
    println!("  (select with DYNAMIX_BACKEND=native|sharded|xla|auto; sharded honors DYNAMIX_SHARDS)");
    Ok(())
}

fn run_experiments(store: Backend, which: &str, scale: Scale) -> anyhow::Result<()> {
    let all = which == "all";
    if all || which == "fig2" {
        harness::fig2_baselines(store.clone(), scale)?;
    }
    if all || which == "fig3" {
        for preset in ["vgg11-sgd", "vgg11-adam", "resnet34-sgd"] {
            harness::fig3_rl_training(store.clone(), preset, scale, None)?;
        }
    }
    if all || which == "fig4" || which == "fig5" {
        for preset in ["vgg11-sgd", "vgg11-adam", "resnet34-sgd"] {
            harness::fig4_fig5_inference(store.clone(), preset, scale, None)?;
        }
    }
    if all || which == "table1" {
        harness::table1_scalability(store.clone(), scale)?;
    }
    if all || which == "fig6" {
        harness::fig6_transfer(store.clone(), "transfer-vgg16-src", "transfer-vgg19-dst", scale)?;
        harness::fig6_transfer(
            store.clone(),
            "transfer-resnet34-src",
            "transfer-resnet50-dst",
            scale,
        )?;
    }
    if all || which == "byteps" {
        harness::byteps_integration(store.clone(), scale)?;
    }
    if all || which == "overhead" {
        harness::overhead_analysis(store.clone(), 10)?;
    }
    if all || which == "dynamics" {
        harness::fig7_dynamics(store, scale)?;
    }
    Ok(())
}
