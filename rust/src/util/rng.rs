//! Deterministic PRNG: xoshiro256++ with splitmix64 seeding.
//!
//! Every stochastic component in the simulator (data generation, cluster
//! load processes, network cross-traffic, PPO exploration) draws from its
//! own [`Rng`] stream derived via [`Rng::split`], so experiment runs are
//! exactly reproducible from a single root seed and components never
//! perturb each other's streams when the code evolves.

/// xoshiro256++ PRNG (public-domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 (expanded through splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Export the raw xoshiro256++ state word-for-word (checkpointing).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a stream from a [`Rng::state`] export: the restored stream
    /// continues the original bit-for-bit.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent child stream tagged by `label`.
    ///
    /// Streams derived with distinct labels from the same parent are
    /// decorrelated; the parent is not advanced.
    pub fn split(&self, label: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform usize in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — the simulators draw thousands, not billions).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large —
    /// the retransmission model never needs exact tails above ~1e3).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 256.0 {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from a discrete probability distribution.
    /// `probs` must sum to ~1; falls back to the last index on rounding.
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let u = self.uniform();
        let mut acc = 0.0;
        for (i, p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return i;
            }
        }
        probs.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_decorrelated_and_stable() {
        let root = Rng::new(7);
        let mut a1 = root.split(1);
        let mut a2 = root.split(1);
        let mut b = root.split(2);
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }

    #[test]
    fn state_roundtrip_resumes_bitwise() {
        let mut a = Rng::new(9);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s, mut ss) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            ss += x * x;
        }
        let mean = s / n as f64;
        let var = ss / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::new(4);
        for &lam in &[0.5, 4.0, 40.0, 400.0] {
            let n = 5000;
            let total: u64 = (0..n).map(|_| r.poisson(lam)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lam).abs() < lam.max(1.0) * 0.15,
                "lambda={lam} mean={mean}"
            );
        }
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let total: f64 = (0..20_000).map(|_| r.exponential(2.0)).sum();
        let mean = total / 20_000.0;
        assert!((mean - 0.5).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let probs = [0.1, 0.7, 0.2];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&probs)] += 1;
        }
        assert!((counts[1] as f64 / 20_000.0 - 0.7).abs() < 0.03);
    }
}
