//! Self-contained utility substrate.
//!
//! The build environment is offline (only the `xla` crate and its
//! dependency closure are vendored), so the usual ecosystem crates
//! (serde/rand/etc.) are unavailable — these small, well-tested
//! replacements keep the rest of the system dependency-free:
//!
//! * [`rng`]  — splitmix64-seeded xoshiro256++ PRNG with the exact
//!   distributions the simulators need (uniform, normal, exponential,
//!   poisson) and deterministic stream splitting.
//! * [`json`] — a strict recursive-descent JSON parser + serializer used
//!   for `artifacts/manifest.json`, experiment configs and run records.

//! * [`lint`] — `dynamix-lint`, the repo-native invariant checker
//!   (SAFETY/env-read/wall-clock/fold-order rule catalogue) backing the
//!   `dynamix-lint` binary and the blocking CI leg.

pub mod bench;
pub mod json;
pub mod lint;
pub mod rng;
