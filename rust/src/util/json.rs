//! Strict JSON parser + serializer (RFC 8259 subset sufficient for
//! `manifest.json`, experiment configs, and run records).
//!
//! Hand-rolled because the build environment vendors only the `xla` crate
//! closure. Numbers are f64 (the manifest's integers are all < 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --- typed accessors (return None on type mismatch) ---

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `v.at(&["artifacts", "train_x", "bucket"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(c) = self.peek() {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: manifest never emits them, but
                            // handle the basic-plane case correctly.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Collect the raw utf8 byte run.
                    let start = self.pos - 1;
                    while let Some(c2) = self.peek() {
                        if c2 == b'"' || c2 == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builders for run-record serialization.
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a [`Json::Obj`] from key/value pairs.
#[macro_export]
macro_rules! jobj {
    ($($k:expr => $v:expr),* $(,)?) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($k.to_string(), $crate::util::json::Json::from($v)); )*
        $crate::util::json::Json::Obj(m)
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"b":false,"s":"he\"llo","z":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(32.0).to_string(), "32");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn jobj_macro() {
        let v = jobj! { "x" => 1.0, "name" => "run", "ok" => true };
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("name").unwrap().as_str(), Some("run"));
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let man = Json::parse(&text).unwrap();
            assert!(man.get("artifacts").unwrap().as_obj().unwrap().len() > 5);
            assert_eq!(man.get("state_dim").unwrap().as_usize(), Some(16));
        }
    }
}
