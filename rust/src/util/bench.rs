//! Minimal criterion-style benchmark harness (offline build: no criterion).
//!
//! Used by the `rust/benches/*.rs` targets (`harness = false`). Prints a
//! stable greppable line per benchmark (the machine-readable sinks below
//! additionally carry p10/p90):
//!
//! ```text
//! bench <name>: mean 12.345 ms ± 0.678 (min 11.9, p50 12.2, n=20)
//! ```
//!
//! Two machine-readable sinks:
//!
//! * `DYNAMIX_BENCH_JSON` — emit one JSON line per benchmark on stdout
//!   (legacy; EXPERIMENTS.md table regeneration).
//! * [`BenchSession`] — collect results and append one run record (git
//!   rev, thread count, note, per-bench p10/p50/p90 + samples/s) to
//!   `BENCH_native.json` at the repo root (override with
//!   `DYNAMIX_BENCH_OUT`). This is the repo's recorded perf trajectory:
//!   every perf PR lands a before/after pair of runs.
//!
//! `DYNAMIX_BENCH_QUICK=1` shrinks warmup/iteration counts (see [`iters`])
//! so a CI smoke leg can exercise every bench — and still upload a
//! `BENCH_native.json` artifact — in seconds.

use crate::util::json::Json;
use std::time::Instant;

/// Timing summary of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p10_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub n: usize,
}

/// Warmup/measured iteration counts, shrunk under `DYNAMIX_BENCH_QUICK=1`
/// (CI smoke: correctness of the bench path, not statistical power).
/// Empty, `0` and `false` values leave the full counts in place so a
/// stale `DYNAMIX_BENCH_QUICK=0` export can't silently degrade recorded
/// numbers.
pub fn iters(warmup: usize, n: usize) -> (usize, usize) {
    let quick = std::env::var("DYNAMIX_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    if quick {
        (warmup.min(1), n.clamp(1, 3))
    } else {
        (warmup, n)
    }
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted: &[f64], p: usize) -> f64 {
    let n = sorted.len();
    sorted[(p * (n - 1) + 50) / 100]
}

/// Run `f` `n` times (after `warmup` untimed runs) and report statistics.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, n: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / n as f64;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / n as f64;
    let mut sorted = times.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: sorted[0],
        p10_s: percentile(&sorted, 10),
        p50_s: percentile(&sorted, 50),
        p90_s: percentile(&sorted, 90),
        n,
    };
    report(&result);
    result
}

fn unit(mean_s: f64) -> (f64, &'static str) {
    if mean_s >= 1.0 {
        (1.0, "s")
    } else if mean_s >= 1e-3 {
        (1e3, "ms")
    } else {
        (1e6, "us")
    }
}

fn report(r: &BenchResult) {
    let (scale, u) = unit(r.mean_s);
    println!(
        "bench {}: mean {:.3} {u} ± {:.3} (min {:.3}, p50 {:.3}, n={})",
        r.name,
        r.mean_s * scale,
        r.std_s * scale,
        r.min_s * scale,
        r.p50_s * scale,
        r.n
    );
    if std::env::var("DYNAMIX_BENCH_JSON").is_ok() {
        println!(
            "{}",
            crate::jobj! {
                "bench" => r.name.clone(),
                "mean_s" => r.mean_s,
                "std_s" => r.std_s,
                "min_s" => r.min_s,
                "p10_s" => r.p10_s,
                "p50_s" => r.p50_s,
                "p90_s" => r.p90_s,
                "n" => r.n,
            }
        );
    }
}

/// Throughput helper: items/sec at the measured mean.
pub fn throughput(r: &BenchResult, items: usize) -> f64 {
    items as f64 / r.mean_s
}

/// One bench binary's recording session: buffers results plus run metadata
/// and appends a run record to `BENCH_native.json` on [`BenchSession::flush`].
pub struct BenchSession {
    suite: String,
    note: Option<String>,
    results: Vec<Json>,
}

impl BenchSession {
    pub fn new(suite: &str) -> Self {
        BenchSession {
            suite: suite.to_string(),
            note: None,
            results: Vec::new(),
        }
    }

    /// Attach a computed note to this session (e.g. a measured pool-vs-
    /// spawn delta). Joined with any `DYNAMIX_BENCH_NOTE` label at flush.
    pub fn set_note(&mut self, note: &str) {
        self.note = Some(note.to_string());
    }

    /// Record a result with no item count (wall-time only).
    pub fn push(&mut self, r: &BenchResult) {
        self.push_items(r, 0);
    }

    /// Record a result; `items > 0` (e.g. the bucket size) also records
    /// `items_per_s` — the samples/s figure perf PRs are judged on.
    pub fn push_items(&mut self, r: &BenchResult, items: usize) {
        self.results.push(crate::jobj! {
            "bench" => r.name.clone(),
            "mean_s" => r.mean_s,
            "std_s" => r.std_s,
            "min_s" => r.min_s,
            "p10_s" => r.p10_s,
            "p50_s" => r.p50_s,
            "p90_s" => r.p90_s,
            "n" => r.n,
            "items" => items,
            "items_per_s" => if items > 0 { throughput(r, items) } else { 0.0 },
        });
    }

    /// Append this session as one run record and return the file path.
    /// Existing records are preserved (unparseable/missing files start a
    /// fresh `{"runs": []}`); the write is atomic (tmp + rename).
    pub fn flush(&self) -> std::io::Result<std::path::PathBuf> {
        self.flush_to(out_path())
    }

    /// [`Self::flush`] to an explicit path (tests; avoids env mutation).
    /// Top-level keys other than `"runs"` are preserved; a file whose
    /// `"runs"` is not an array is an error (never silently reset — the
    /// file is the repo's accrued perf trajectory).
    pub fn flush_to(&self, path: std::path::PathBuf) -> std::io::Result<std::path::PathBuf> {
        let mut root = match std::fs::read_to_string(&path) {
            Ok(text) => Json::parse(&text)
                .map_err(|e| {
                    std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("{}: not valid JSON ({e}); refusing to overwrite", path.display()),
                    )
                })?
                .as_obj()
                .cloned()
                .ok_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("{}: top level is not an object", path.display()),
                    )
                })?,
            Err(_) => std::collections::BTreeMap::new(), // fresh file
        };
        let mut runs = match root.remove("runs") {
            None => Vec::new(),
            Some(Json::Arr(a)) => a,
            Some(_) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{}: \"runs\" is not an array", path.display()),
                ))
            }
        };
        // The run's execution config comes from the process-global pool
        // (DYNAMIX_THREADS / DYNAMIX_KERNEL read once at backend init) —
        // the same pool every backend in this process actually used.
        let pool = crate::runtime::native::exec::Pool::global();
        let note = [
            std::env::var("DYNAMIX_BENCH_NOTE").unwrap_or_default(),
            self.note.clone().unwrap_or_default(),
        ]
        .into_iter()
        .filter(|s| !s.is_empty())
        .collect::<Vec<_>>()
        .join("; ");
        runs.push(crate::jobj! {
            "suite" => self.suite.clone(),
            "note" => note,
            "git_rev" => git_rev(),
            "threads" => pool.threads(),
            "kernel" => pool.tier().as_str(),
            "unix_time" => unix_time(),
            "results" => self.results.clone(),
        });
        root.insert("runs".to_string(), Json::Arr(runs));
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, format!("{}\n", Json::Obj(root)))?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }
}

/// `DYNAMIX_BENCH_OUT`, defaulting to `<repo root>/BENCH_native.json`.
/// Public so `bench_compare` resolves the record file identically.
pub fn out_path() -> std::path::PathBuf {
    match std::env::var("DYNAMIX_BENCH_OUT") {
        Ok(p) if !p.is_empty() => std::path::PathBuf::from(p),
        _ => std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_native.json"),
    }
}

fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn unix_time() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let r = bench("test-sleep", 0, 3, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(r.mean_s >= 0.002);
        assert!(r.min_s <= r.p50_s);
        assert!(r.p10_s <= r.p50_s && r.p50_s <= r.p90_s);
        assert_eq!(r.n, 3);
    }

    #[test]
    fn throughput_inverts_mean() {
        let r = BenchResult {
            name: "x".into(),
            mean_s: 0.5,
            std_s: 0.0,
            min_s: 0.5,
            p10_s: 0.5,
            p50_s: 0.5,
            p90_s: 0.5,
            n: 1,
        };
        assert_eq!(throughput(&r, 100), 200.0);
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let s: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&s, 0), 1.0);
        assert_eq!(percentile(&s, 50), 6.0); // (50*9+50)/100 = 5 -> s[5]
        assert_eq!(percentile(&s, 100), 10.0);
        assert_eq!(percentile(&[3.0], 90), 3.0);
    }

    #[test]
    fn session_appends_runs_to_json() {
        let dir = std::env::temp_dir().join(format!("dynamix-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let r = BenchResult {
            name: "train_step/b4096".into(),
            mean_s: 0.25,
            std_s: 0.0,
            min_s: 0.25,
            p10_s: 0.25,
            p50_s: 0.25,
            p90_s: 0.25,
            n: 4,
        };
        let mut s = BenchSession::new("train_step");
        s.push_items(&r, 4096);
        let written = s.flush_to(path.clone()).unwrap();
        let mut s2 = BenchSession::new("train_step");
        s2.push(&r);
        s2.flush_to(path).unwrap();

        let root = Json::parse(&std::fs::read_to_string(&written).unwrap()).unwrap();
        let runs = root.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        let first = &runs[0].get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("bench").unwrap().as_str(), Some("train_step/b4096"));
        assert_eq!(first.get("items").unwrap().as_usize(), Some(4096));
        assert!((first.get("items_per_s").unwrap().as_f64().unwrap() - 16384.0).abs() < 1e-6);
        assert!(runs[0].get("threads").unwrap().as_usize().unwrap() >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
