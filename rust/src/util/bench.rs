//! Minimal criterion-style benchmark harness (offline build: no criterion).
//!
//! Used by the `rust/benches/*.rs` targets (`harness = false`). Reports
//! mean ± std, min, and p50 over timed iterations after warmup, in a
//! stable greppable format:
//!
//! ```text
//! bench <name>: mean 12.345 ms ± 0.678 (min 11.9, p50 12.2, n=20)
//! ```
//!
//! Also emits a JSON line per benchmark when `DYNAMIX_BENCH_JSON` is set,
//! so EXPERIMENTS.md tables can be regenerated mechanically.

use std::time::Instant;

/// Timing summary of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub n: usize,
}

/// Run `f` `n` times (after `warmup` untimed runs) and report statistics.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, n: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / n as f64;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / n as f64;
    let mut sorted = times.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: sorted[0],
        p50_s: sorted[n / 2],
        n,
    };
    report(&result);
    result
}

fn unit(mean_s: f64) -> (f64, &'static str) {
    if mean_s >= 1.0 {
        (1.0, "s")
    } else if mean_s >= 1e-3 {
        (1e3, "ms")
    } else {
        (1e6, "us")
    }
}

fn report(r: &BenchResult) {
    let (scale, u) = unit(r.mean_s);
    println!(
        "bench {}: mean {:.3} {u} ± {:.3} (min {:.3}, p50 {:.3}, n={})",
        r.name,
        r.mean_s * scale,
        r.std_s * scale,
        r.min_s * scale,
        r.p50_s * scale,
        r.n
    );
    if std::env::var("DYNAMIX_BENCH_JSON").is_ok() {
        println!(
            "{}",
            crate::jobj! {
                "bench" => r.name.clone(),
                "mean_s" => r.mean_s,
                "std_s" => r.std_s,
                "min_s" => r.min_s,
                "p50_s" => r.p50_s,
                "n" => r.n,
            }
        );
    }
}

/// Throughput helper: items/sec at the measured mean.
pub fn throughput(r: &BenchResult, items: usize) -> f64 {
    items as f64 / r.mean_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let r = bench("test-sleep", 0, 3, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(r.mean_s >= 0.002);
        assert!(r.min_s <= r.p50_s);
        assert_eq!(r.n, 3);
    }

    #[test]
    fn throughput_inverts_mean() {
        let r = BenchResult {
            name: "x".into(),
            mean_s: 0.5,
            std_s: 0.0,
            min_s: 0.5,
            p50_s: 0.5,
            n: 1,
        };
        assert_eq!(throughput(&r, 100), 200.0);
    }
}
