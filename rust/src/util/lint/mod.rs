//! `dynamix-lint` — the repo-native invariant checker.
//!
//! DYNAMIX's load-bearing guarantees are invariants, not features:
//! bit-identical gradient reduction across shard counts and kernel
//! tiers, deterministic scenario replay, and env-config read exactly
//! once per process. This module codifies them as a source-level rule
//! catalogue over `rust/{src,tests,benches}`:
//!
//! | rule id             | invariant |
//! |---------------------|-----------|
//! | `safety-comment`    | every `unsafe` is immediately preceded by a `// SAFETY:` proof |
//! | `env-read`          | `std::env::var` only in the config/exec/bench allowlist (read-once) |
//! | `wall-clock`        | no `Instant::now`/`SystemTime` in determinism-critical modules |
//! | `nondet-collection` | no `HashMap`/`HashSet` in reduce/wire/record-emitting modules |
//! | `fold-order`        | float reductions, top-k partial selects, and FMA intrinsics in parity-critical paths carry a `// PARITY:` marker |
//! | `feature-detect`    | raw `is_x86_feature_detected!` only inside `exec.rs`; `#[target_feature]` lanes only in exec.rs / linalg.rs / comm/wire.rs |
//! | `suppression`       | every `lint:allow` names a known rule and justifies itself |
//!
//! A finding is suppressed by attaching `lint:allow(env-read): reason`
//! (with the offending rule's id and a non-empty justification after the
//! colon) to the flagged line — either trailing on the line itself or in
//! the comment block directly above it. An allow with an unknown rule id
//! or a missing justification does **not** suppress anything and is
//! itself flagged under the `suppression` rule.
//!
//! The checker is deliberately a line/token pass over the
//! [`scan`]-split source (no parser, no registry deps — consistent with
//! the vendored-`anyhow` policy). Rules attach context by walking
//! *upward* from a flagged line through comment-only lines, attribute
//! lines, and statement-continuation heads (a code line ending in `=`,
//! `(`, `,`, …), so a `SAFETY:` block above `#[target_feature]`
//! attributes or above a multi-line `let … =` binding still counts.
//!
//! [`fixtures`] embeds one known-bad/known-good source pair per rule;
//! [`self_test`] runs them so the linter's own regressions fail CI.

pub mod fixtures;
pub mod scan;

use scan::{count_tokens, split_lines, SourceLine};
use std::path::Path;

/// Rule ids with one-line summaries (order = report order).
pub const RULES: &[(&str, &str)] = &[
    ("safety-comment", "`unsafe` without an attached `SAFETY:` comment"),
    ("env-read", "`std::env::var` outside the config/exec/bench allowlist"),
    ("wall-clock", "wall-clock read in a determinism-critical module"),
    ("nondet-collection", "iteration-order-nondeterministic collection in a reduce/wire/record module"),
    ("fold-order", "float reduction / partial select / FMA in a parity-critical path without a `PARITY:` marker"),
    ("feature-detect", "CPU feature probe outside `exec.rs`, or a `#[target_feature]` lane outside the SIMD module allowlist"),
    ("suppression", "`lint:allow` with an unknown rule id or no justification"),
];

/// One finding. `line` is 1-based.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Violation {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

fn violation(rule: &'static str, file: &str, line: usize, msg: String) -> Violation {
    Violation { rule, file: file.to_string(), line, msg }
}

/// Is `id` a rule that `lint:allow` may name? (`suppression` itself is
/// the meta-rule and cannot be allowed away.)
fn allowable_rule(id: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == id && *r != "suppression")
}

/// Parse every `lint:allow` occurrence in one comment, returning
/// `(id, justified)` pairs. `justified` means a `:` followed by
/// non-empty text came right after the closing paren.
fn parse_allows(comment: &str) -> Vec<(String, bool)> {
    const NEEDLE: &str = "lint:allow(";
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(NEEDLE) {
        let after = &rest[pos + NEEDLE.len()..];
        let Some(close) = after.find(')') else {
            out.push((after.trim().to_string(), false));
            break;
        };
        let id = after[..close].trim().to_string();
        let tail = after[close + 1..].trim_start();
        let justified = tail.starts_with(':') && !tail[1..].trim().is_empty();
        out.push((id, justified));
        rest = &after[close + 1..];
    }
    out
}

/// Maximum upward steps when attaching comment context to a line (a
/// backstop — the blank-line / unrelated-statement stops are the real
/// boundary; sized to cover the longest SAFETY proof sketch in the tree).
const WALK_CAP: usize = 16;

/// Statement-continuation suffixes: a code line ending in one of these is
/// the head of the statement the *next* line continues, so a comment
/// above it still attaches (e.g. `let job: Box<…> =` / `unsafe { … }`).
const CONTINUATION: &[&str] = &["=", "(", ",", "=>", "+", "&&", "||"];

/// Indices of the lines whose comments attach to line `idx`: the line
/// itself, then upward through comment-only lines, `#[…]` attribute
/// lines, and continuation heads; stops at a blank line, plain code, or
/// after [`WALK_CAP`] steps.
fn attached_lines(lines: &[SourceLine], idx: usize) -> Vec<usize> {
    let mut out = vec![idx];
    let mut i = idx;
    for _ in 0..WALK_CAP {
        if i == 0 {
            break;
        }
        i -= 1;
        let code = lines[i].code.trim();
        let comment = lines[i].comment.trim();
        if code.is_empty() && comment.is_empty() {
            break; // blank line ends the attachment block
        }
        if code.is_empty() || code.starts_with("#[") || code.starts_with("#!") {
            out.push(i);
            continue;
        }
        if CONTINUATION.iter().any(|s| code.ends_with(s)) {
            out.push(i);
            continue;
        }
        break; // an unrelated statement
    }
    out
}

fn has_marker(lines: &[SourceLine], attached: &[usize], marker: &str) -> bool {
    attached.iter().any(|&i| lines[i].comment.contains(marker))
}

fn is_allowed(lines: &[SourceLine], attached: &[usize], rule: &str) -> bool {
    attached.iter().any(|&i| {
        parse_allows(&lines[i].comment)
            .iter()
            .any(|(id, justified)| *justified && id == rule && allowable_rule(id))
    })
}

// --- per-rule path scoping (paths are crate-relative, '/'-separated) ---

/// L2: modules allowed to read the environment directly. Everything else
/// must go through `config::env` (or carry a justified allow).
fn env_read_allowlisted(rel: &str) -> bool {
    rel.starts_with("src/config/")
        || rel == "src/runtime/native/exec.rs"
        || rel == "src/util/bench.rs"
}

/// L3: determinism-critical modules where wall-clock reads would break
/// replay / parity.
fn wall_clock_scoped(rel: &str) -> bool {
    rel.starts_with("src/sim/")
        || rel.starts_with("src/runtime/sharded/")
        || rel.starts_with("src/ckpt/")
        || rel == "src/runtime/native/linalg.rs"
        || rel == "src/comm/wire.rs"
}

/// L4: reduce-sensitive / wire / record-emitting modules where iteration
/// order reaches observable output.
fn collection_scoped(rel: &str) -> bool {
    rel.starts_with("src/runtime/")
        || rel.starts_with("src/comm/")
        || rel.starts_with("src/sim/")
        || rel.starts_with("src/metrics/")
}

/// L5: parity-critical fold paths (the bit-identical reduction contract).
fn fold_scoped(rel: &str) -> bool {
    rel.starts_with("src/runtime/native/")
        || rel.starts_with("src/runtime/sharded/")
        || rel == "src/comm/wire.rs"
}

/// L6: the only module allowed to probe CPU features directly.
fn feature_detect_allowlisted(rel: &str) -> bool {
    rel == "src/runtime/native/exec.rs"
}

/// L6 (second token): modules allowed to declare `#[target_feature]`
/// lanes. SIMD implementations live next to their scalar references so
/// the tier dispatch (and its SAFETY obligations) stays auditable in one
/// place per subsystem: tier resolution in `exec.rs`, compute kernels in
/// `linalg.rs`, wire codecs in `comm/wire.rs`.
fn target_feature_allowlisted(rel: &str) -> bool {
    rel == "src/runtime/native/exec.rs"
        || rel == "src/runtime/native/linalg.rs"
        || rel == "src/comm/wire.rs"
}

/// Run the full rule catalogue over one file's source. `rel` is the
/// crate-relative path (forward slashes) used for rule scoping.
pub fn scan_source(rel: &str, src: &str) -> Vec<Violation> {
    let lines = split_lines(src);
    let mut out = Vec::new();

    // Meta-pass: every `lint:allow` must name a known rule and justify
    // itself; invalid allows are flagged here and ignored everywhere else.
    for (i, l) in lines.iter().enumerate() {
        for (id, justified) in parse_allows(&l.comment) {
            if !allowable_rule(&id) {
                out.push(violation(
                    "suppression",
                    rel,
                    i + 1,
                    format!("lint:allow names unknown rule `{id}`"),
                ));
            } else if !justified {
                out.push(violation(
                    "suppression",
                    rel,
                    i + 1,
                    format!("lint:allow({id}) needs a `: <why>` justification suffix"),
                ));
            }
        }
    }

    for (i, l) in lines.iter().enumerate() {
        let code = l.code.as_str();
        if code.trim().is_empty() {
            continue;
        }

        // L1 — safety-comment.
        if count_tokens(code, "unsafe", false) > 0 {
            let ctx = attached_lines(&lines, i);
            if !has_marker(&lines, &ctx, "SAFETY:") && !is_allowed(&lines, &ctx, "safety-comment") {
                out.push(violation(
                    "safety-comment",
                    rel,
                    i + 1,
                    "`unsafe` without an attached `// SAFETY:` comment".to_string(),
                ));
            }
        }

        // L2 — env-read (prefix match also catches `env::var_os`/`env::vars`).
        if !env_read_allowlisted(rel) && count_tokens(code, "env::var", true) > 0 {
            let ctx = attached_lines(&lines, i);
            if !is_allowed(&lines, &ctx, "env-read") {
                out.push(violation(
                    "env-read",
                    rel,
                    i + 1,
                    "direct env read outside the config/exec/bench allowlist; route through `config::env`".to_string(),
                ));
            }
        }

        // L3 — wall-clock.
        if wall_clock_scoped(rel) {
            for pat in ["Instant::now", "SystemTime"] {
                if count_tokens(code, pat, false) > 0 {
                    let ctx = attached_lines(&lines, i);
                    if !is_allowed(&lines, &ctx, "wall-clock") {
                        out.push(violation(
                            "wall-clock",
                            rel,
                            i + 1,
                            format!("`{pat}` in a determinism-critical module"),
                        ));
                    }
                    break;
                }
            }
        }

        // L4 — nondet-collection.
        if collection_scoped(rel) {
            for pat in ["HashMap", "HashSet"] {
                if count_tokens(code, pat, false) > 0 {
                    let ctx = attached_lines(&lines, i);
                    if !is_allowed(&lines, &ctx, "nondet-collection") {
                        out.push(violation(
                            "nondet-collection",
                            rel,
                            i + 1,
                            format!("`{pat}` iteration order is nondeterministic; use `BTreeMap`/`BTreeSet`"),
                        ));
                    }
                    break;
                }
            }
        }

        // L5 — fold-order. Beyond literal folds, two SIMD-era patterns
        // carry the same ordering burden: a quickselect partition feeding
        // the top-k wire (its selected set must match the sort reference
        // bit-for-bit) and an FMA intrinsic (contracted rounding — only
        // legal on the 1e-5 forward/input-grad paths, never in a
        // bitwise-parity kernel). `select_nth_unstable` matches with
        // prefix_ok so `_by`/`_by_key` variants are caught too.
        if fold_scoped(rel) {
            for (pat, prefix_ok) in [
                ("sum::<f32>", false),
                ("sum::<f64>", false),
                (".fold(", false),
                ("select_nth_unstable", true),
                ("_mm256_fmadd_ps", false),
            ] {
                if count_tokens(code, pat, prefix_ok) > 0 {
                    let ctx = attached_lines(&lines, i);
                    if !has_marker(&lines, &ctx, "PARITY:")
                        && !is_allowed(&lines, &ctx, "fold-order")
                    {
                        out.push(violation(
                            "fold-order",
                            rel,
                            i + 1,
                            format!("`{pat}` in a parity-critical path without a `// PARITY:` marker"),
                        ));
                    }
                    break;
                }
            }
        }

        // L6 — feature-detect.
        if !feature_detect_allowlisted(rel) && count_tokens(code, "is_x86_feature_detected", false) > 0
        {
            let ctx = attached_lines(&lines, i);
            if !is_allowed(&lines, &ctx, "feature-detect") {
                out.push(violation(
                    "feature-detect",
                    rel,
                    i + 1,
                    "raw feature detection outside `exec.rs`; dispatch through `KernelTier::resolved`".to_string(),
                ));
            }
        }
        // L6 (second token) — a `#[target_feature]` lane outside the SIMD
        // module allowlist: new lanes must live beside their scalar
        // reference and reach callers through the tier dispatch, never as
        // free-floating feature-gated functions.
        if !target_feature_allowlisted(rel) && count_tokens(code, "target_feature", false) > 0 {
            let ctx = attached_lines(&lines, i);
            if !is_allowed(&lines, &ctx, "feature-detect") {
                out.push(violation(
                    "feature-detect",
                    rel,
                    i + 1,
                    "`#[target_feature]` outside the SIMD module allowlist (exec.rs, linalg.rs, comm/wire.rs)".to_string(),
                ));
            }
        }
    }
    out
}

/// Recursively collect `.rs` files under `dir`, pushing crate-relative
/// '/'-joined paths onto `out`. A missing `dir` is skipped.
fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> std::io::Result<()> {
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e),
    };
    let mut entries: Vec<_> = rd.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(root, &p, out)?;
        } else if p.extension().and_then(|x| x.to_str()) == Some("rs") {
            let rel = p
                .strip_prefix(root)
                .expect("walk stays under root")
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Scan `crate_root/{src,tests,benches}` with the full catalogue.
/// Returns the findings (file-sorted) and the number of files scanned.
pub fn scan_tree(crate_root: &Path) -> std::io::Result<(Vec<Violation>, usize)> {
    let mut files = Vec::new();
    for top in ["src", "tests", "benches"] {
        collect_rs(crate_root, &crate_root.join(top), &mut files)?;
    }
    files.sort();
    let mut out = Vec::new();
    for rel in &files {
        let src = std::fs::read_to_string(crate_root.join(rel))?;
        out.extend(scan_source(rel, &src));
    }
    Ok((out, files.len()))
}

/// Run every rule against its embedded known-bad/known-good fixture.
/// Returns human-readable failure descriptions (empty = all rules live).
pub fn self_test() -> Vec<String> {
    let mut fails = Vec::new();
    for f in fixtures::all() {
        let bad = scan_source(f.path, f.bad);
        let hits = bad.iter().filter(|v| v.rule == f.rule).count();
        if hits != 1 {
            fails.push(format!(
                "rule `{}`: expected exactly 1 finding on the bad fixture, got {hits}",
                f.rule
            ));
        }
        // The suppression fixture legitimately also trips the rule the
        // invalid allow failed to suppress; every other bad fixture must
        // trip only its own rule.
        if f.rule != "suppression" && bad.len() != hits {
            fails.push(format!(
                "rule `{}`: bad fixture tripped unrelated rules: {:?}",
                f.rule,
                bad.iter().map(|v| v.rule).collect::<Vec<_>>()
            ));
        }
        let good = scan_source(f.path, f.good);
        if !good.is_empty() {
            fails.push(format!(
                "rule `{}`: good fixture should be clean, got {:?}",
                f.rule,
                good.iter().map(Violation::render).collect::<Vec<_>>()
            ));
        }
    }
    fails
}

/// Machine-readable report for CI annotation (`--format json`).
pub fn report_json(violations: &[Violation], files_scanned: usize) -> String {
    let items: Vec<crate::util::json::Json> = violations
        .iter()
        .map(|v| {
            crate::jobj!(
                "rule" => v.rule,
                "file" => v.file.clone(),
                "line" => v.line,
                "msg" => v.msg.clone()
            )
        })
        .collect();
    crate::jobj!(
        "files_scanned" => files_scanned,
        "violations" => items,
        "ok" => violations.is_empty()
    )
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn safety_attaches_through_attributes_and_continuations() {
        // Comment above attribute lines (a target_feature-allowlisted
        // path — the attribute itself is legal only there).
        let src = "// SAFETY: unsafe solely for target_feature; no pointer preconditions.\n#[inline]\n#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n";
        assert!(scan_source("src/runtime/native/linalg.rs", src).is_empty());
        // Comment above a multi-line `let … =` head.
        let src = "// SAFETY: the latch below outlives every borrow.\nlet job: Box<F> =\n    unsafe { transmute(j) };\n";
        assert!(scan_source("src/runtime/native/x.rs", src).is_empty());
        // A blank line breaks attachment.
        let src = "// SAFETY: stale, detached.\n\nunsafe fn f() {}\n";
        assert_eq!(scan_source("src/runtime/native/x.rs", src).len(), 1);
    }

    #[test]
    fn env_read_scoping_and_suppression() {
        let hit = "let v = std::env::var(\"X\").ok();\n";
        assert_eq!(scan_source("src/trainer/mod.rs", hit).len(), 1);
        // Allowlisted paths pass without annotation.
        assert!(scan_source("src/runtime/native/exec.rs", hit).is_empty());
        assert!(scan_source("src/config/env.rs", hit).is_empty());
        // A justified trailing allow suppresses.
        let ok = "let v = std::env::var(\"X\").ok(); // lint:allow(env-read): test save/restore of the raw env.\n";
        assert!(scan_source("src/trainer/mod.rs", ok).is_empty());
        // Unjustified: the allow is flagged AND the read still fires.
        let bad = "let v = std::env::var(\"X\").ok(); // lint:allow(env-read)\n";
        let vs = scan_source("src/trainer/mod.rs", bad);
        let rules: Vec<_> = vs.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&"suppression") && rules.contains(&"env-read"), "{rules:?}");
        // Unknown rule id never suppresses.
        let bogus = "let v = std::env::var(\"X\").ok(); // lint:allow(no-such-rule): because.\n";
        let vs = scan_source("src/trainer/mod.rs", bogus);
        let rules: Vec<_> = vs.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&"suppression") && rules.contains(&"env-read"), "{rules:?}");
    }

    #[test]
    fn fold_order_needs_parity_marker_only_in_scope() {
        let bare = "let d: f32 = mask.iter().sum::<f32>().max(1.0);\n";
        assert_eq!(scan_source("src/runtime/native/model.rs", bare).len(), 1);
        let marked = "// PARITY: sequential left-to-right fold, shared with the sharded path.\nlet d: f32 = mask.iter().sum::<f32>().max(1.0);\n";
        assert!(scan_source("src/runtime/native/model.rs", marked).is_empty());
        // Out of scope: no marker needed.
        assert!(scan_source("src/metrics/mod.rs", bare).is_empty());
    }

    #[test]
    fn fold_order_covers_partial_select_and_fma() {
        // The `_by_key` suffix must not hide the partition from the rule.
        let bare = "order.select_nth_unstable_by_key(k - 1, key);\n";
        assert_eq!(scan_source("src/comm/wire.rs", bare).len(), 1);
        let marked = "// PARITY: duplicate-free key — prefix equals the sort reference.\norder.select_nth_unstable_by_key(k - 1, key);\n";
        assert!(scan_source("src/comm/wire.rs", marked).is_empty());
        // An FMA intrinsic in a parity path needs the same marker.
        let fma = "let acc = unsafe { _mm256_fmadd_ps(a, b, acc) }; // SAFETY: avx2 checked by tier.\n";
        assert_eq!(scan_source("src/runtime/native/linalg.rs", fma).len(), 1);
        let fma_ok = "// PARITY: fwd/input-grad path — contracted rounding under the 1e-5 contract.\n// SAFETY: avx2 checked by tier.\nlet acc = unsafe { _mm256_fmadd_ps(a, b, acc) };\n";
        assert!(scan_source("src/runtime/native/linalg.rs", fma_ok).is_empty());
        // Out of the fold scope neither token fires.
        assert!(scan_source("src/metrics/mod.rs", bare).is_empty());
    }

    #[test]
    fn target_feature_is_confined_to_the_simd_module_allowlist() {
        let lane = "// SAFETY: callers hold the avx2 witness from the tier dispatch.\n#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n";
        // Outside the allowlist: flagged even with a SAFETY proof.
        let vs = scan_source("src/runtime/sharded/worker.rs", lane);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "feature-detect");
        // The three SIMD homes pass.
        for rel in ["src/runtime/native/exec.rs", "src/runtime/native/linalg.rs", "src/comm/wire.rs"]
        {
            assert!(scan_source(rel, lane).is_empty(), "{rel} should allow lanes");
        }
        // A justified allow still works for one-off exceptions.
        let allowed = "// lint:allow(feature-detect): scalar-only test shim, never dispatched.\n// SAFETY: avx2 proven by the caller.\n#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n";
        assert!(scan_source("src/runtime/sharded/worker.rs", allowed).is_empty());
    }

    #[test]
    fn patterns_in_strings_and_comments_do_not_fire() {
        let src = "// This comment mentions unsafe and Instant::now freely.\nlet s = \"std::env::var HashMap unsafe\";\n";
        assert!(scan_source("src/sim/engine.rs", src).is_empty());
    }

    #[test]
    fn self_test_is_green() {
        let fails = self_test();
        assert!(fails.is_empty(), "{fails:#?}");
    }
}
