//! Line/token scanner for `dynamix-lint`: splits Rust source into
//! per-line (code, comment) channels so the rules in [`super`] can match
//! tokens without being fooled by string literals or comments.
//!
//! This is deliberately NOT a parser. The rules only need to know, per
//! line, (a) which characters are live code and (b) what the attached
//! comment text says — so a small state machine over the raw characters
//! is enough, and it stays zero-dependency (the vendored-`anyhow` policy
//! rules out syn/proc-macro2). Handled: line comments, nested block
//! comments, string literals (incl. escapes and `\`-newline
//! continuations), raw strings `r"…"` / `r#"…"#` (any hash count, and
//! therefore `br…` byte raw strings, whose `b` is just a code char),
//! char literals vs lifetimes (`'x'` and `'\n'` vs `'scope`).
//!
//! String literal *contents* are dropped (the delimiting quotes are kept
//! as anchors); comment text is preserved verbatim so the `SAFETY:` /
//! `PARITY:` / suppression markers can be read back out.

/// One source line, split into its live-code and comment channels.
#[derive(Debug, Default, Clone)]
pub struct SourceLine {
    /// The line with comments removed and string/char literal contents
    /// blanked (delimiters kept).
    pub code: String,
    /// The concatenated comment text of the line (without `//`).
    pub comment: String,
}

#[derive(Clone, Copy)]
enum St {
    Code,
    /// `// …` to end of line.
    Line,
    /// `/* … */`, tracking nesting depth.
    Block(usize),
    /// `"…"` with escapes.
    Str,
    /// `r##"…"##` with the given hash count.
    RawStr(usize),
}

/// Split `src` into per-line (code, comment) channels.
pub fn split_lines(src: &str) -> Vec<SourceLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = SourceLine::default();
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            if matches!(st, St::Line) {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::Line;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == 'r' && matches!(next, Some('"') | Some('#')) {
                    // Candidate raw string: `r"` or `r#…#"`; `r#ident`
                    // (raw identifier) falls through to plain code.
                    let mut h = 0;
                    let mut j = i + 1;
                    while chars.get(j) == Some(&'#') {
                        h += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        cur.code.push('"');
                        st = St::RawStr(h);
                        i = j + 1;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime.
                    if next == Some('\\') {
                        // `'\…'`: skip past the escape to the closing quote.
                        let mut j = i + 3; // first char after the backslash's escapee
                        while j < chars.len() && chars[j] != '\'' {
                            j += 1;
                        }
                        cur.code.push_str("'_'");
                        i = j + 1;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        // `'x'`
                        cur.code.push_str("'_'");
                        i += 3;
                    } else {
                        // lifetime (`'scope`) — keep the tick as code.
                        cur.code.push(c);
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::Line => {
                cur.comment.push(c);
                i += 1;
            }
            St::Block(d) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(d + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    if chars.get(i + 1) == Some(&'\n') {
                        // Line-continuation escape: let the newline be
                        // processed normally so line numbers stay right.
                        i += 1;
                    } else {
                        i += 2; // skip the escaped char (content is dropped)
                    }
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && (0..h).all(|t| chars.get(i + 1 + t) == Some(&'#')) {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Count identifier-boundary-respecting occurrences of `pat` in `code`.
/// The char before a match must not be an identifier char (when `pat`
/// starts with one); same for the char after, unless `prefix_ok` — used
/// for patterns like `env::var` that should also catch `env::var_os`.
pub fn count_tokens(code: &str, pat: &str, prefix_ok: bool) -> usize {
    let first_ident = pat.chars().next().map(is_ident).unwrap_or(false);
    let last_ident = pat.chars().last().map(is_ident).unwrap_or(false);
    code.match_indices(pat)
        .filter(|&(pos, _)| {
            if first_ident {
                if let Some(prev) = code[..pos].chars().last() {
                    if is_ident(prev) {
                        return false;
                    }
                }
            }
            if last_ident && !prefix_ok {
                if let Some(next) = code[pos + pat.len()..].chars().next() {
                    if is_ident(next) {
                        return false;
                    }
                }
            }
            true
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_split_from_code() {
        let ls = split_lines("let a = 1; // trailing note\n/* block */ let b = 2;\n");
        assert_eq!(ls.len(), 2);
        assert_eq!(ls[0].code.trim(), "let a = 1;");
        assert_eq!(ls[0].comment.trim(), "trailing note");
        assert_eq!(ls[1].code.trim(), "let b = 2;");
        assert_eq!(ls[1].comment.trim(), "block");
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let ls = split_lines("a /* one /* two */ still */ b\n/* open\nmid\nclose */ c\n");
        assert_eq!(ls[0].code.replace(' ', ""), "ab");
        assert_eq!(ls[1].code, "");
        assert_eq!(ls[2].code, "");
        assert_eq!(ls[2].comment, "mid");
        assert_eq!(ls[3].code.trim(), "c");
    }

    #[test]
    fn string_contents_are_blanked() {
        let ls = split_lines("call(\"std::env::var inside // not a comment\");\n");
        assert_eq!(ls[0].code, "call(\"\");");
        assert_eq!(ls[0].comment, "");
        // Escaped quote doesn't terminate the literal.
        let ls = split_lines("x(\"a\\\"b\", y)\n");
        assert_eq!(ls[0].code, "x(\"\", y)");
    }

    #[test]
    fn raw_strings_are_blanked() {
        let ls = split_lines("let f = r#\"fn bad() { }\n// SAFETY: fake\n\"#; done();\n");
        assert_eq!(ls.len(), 3);
        assert_eq!(ls[0].code, "let f = \"");
        assert_eq!(ls[1].comment, "");
        assert_eq!(ls[2].code, "\"; done();");
        // Hash counts must match to close.
        let ls = split_lines("r##\"content \"# still\"## after\n");
        assert_eq!(ls[0].code, "\"\" after");
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let ls = split_lines("let q = '\"'; let n = '\\n'; fn f<'a>(x: &'a str) {}\n");
        assert_eq!(ls[0].code, "let q = '_'; let n = '_'; fn f<'a>(x: &'a str) {}");
        // A double-quote char literal must not open string mode.
        assert!(ls[0].code.contains("fn f"));
    }

    #[test]
    fn token_boundaries() {
        assert_eq!(count_tokens("let x = foo(); unsafe { }", "unsafe", false), 1);
        assert_eq!(count_tokens("let unsafety = 1;", "unsafe", false), 0);
        assert_eq!(count_tokens("std::env::var(\"X\")", "env::var", true), 1);
        assert_eq!(count_tokens("std::env::var_os(\"X\")", "env::var", true), 1);
        assert_eq!(count_tokens("my_env::variant()", "env::var", true), 0);
        assert_eq!(count_tokens("std::time::SystemTime::now()", "SystemTime", false), 1);
        assert_eq!(count_tokens("MySystemTimeWrapper::now()", "SystemTime", false), 0);
    }
}
