//! Embedded known-bad/known-good fixtures for `dynamix-lint --self-test`.
//!
//! Each rule ships with a minimal source pair: `bad` must trip its rule
//! exactly once, `good` must scan completely clean. The linter's own
//! regressions (a rule silently going blind after a scanner change) are
//! caught by running these on every `--self-test` and in
//! `tests/lint_self.rs`. The sources live in raw strings, so when the
//! linter scans *this* file their contents are blanked out of the code
//! channel and none of the deliberately-bad patterns fire on the real
//! tree.

/// One rule's self-test pair. `path` is the synthetic in-scope location
/// the sources pretend to live at (scoping is path-based).
pub struct Fixture {
    pub rule: &'static str,
    pub path: &'static str,
    pub bad: &'static str,
    pub good: &'static str,
}

/// All self-test fixtures, one per rule.
pub fn all() -> Vec<Fixture> {
    vec![
        Fixture {
            rule: "safety-comment",
            path: "src/runtime/native/lintfix.rs",
            bad: r#"
pub fn read_first(p: *const f32) -> f32 {
    unsafe { *p }
}
"#,
            good: r#"
pub fn read_first(p: *const f32) -> f32 {
    // SAFETY: caller guarantees `p` points to at least one valid f32.
    unsafe { *p }
}
"#,
        },
        Fixture {
            rule: "env-read",
            path: "src/trainer/lintfix.rs",
            bad: r#"
pub fn knob() -> Option<String> {
    std::env::var("DYNAMIX_KNOB").ok()
}
"#,
            good: r#"
pub fn knob() -> Option<String> {
    crate::config::env::raw("DYNAMIX_KNOB")
}
"#,
        },
        Fixture {
            rule: "wall-clock",
            path: "src/sim/lintfix.rs",
            bad: r#"
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
"#,
            good: r#"
pub fn stamp(virtual_clock_us: u64) -> u64 {
    virtual_clock_us + 1
}
"#,
        },
        Fixture {
            rule: "nondet-collection",
            path: "src/runtime/lintfix.rs",
            bad: r#"
pub type Slots = std::collections::HashMap<String, usize>;
"#,
            good: r#"
pub type Slots = std::collections::BTreeMap<String, usize>;
"#,
        },
        Fixture {
            rule: "fold-order",
            path: "src/runtime/native/lintfix2.rs",
            bad: r#"
pub fn denom(mask: &[f32]) -> f32 {
    mask.iter().sum::<f32>().max(1.0)
}
"#,
            good: r#"
pub fn denom(mask: &[f32]) -> f32 {
    // PARITY: left-to-right fold — must stay bit-identical to the
    // sharded denominator fold in runtime/sharded.
    mask.iter().sum::<f32>().max(1.0)
}
"#,
        },
        Fixture {
            // The fold-order rule also covers the overlapped ring's bucket
            // fold sites in `runtime/sharded/`: seeding a window and then
            // folding rows into it is exactly the reduction whose order
            // the parity oracle depends on.
            rule: "fold-order",
            path: "src/runtime/sharded/lintfix_bucket.rs",
            bad: r#"
pub fn fold_bucket(seed: f32, rows: &[f32]) -> f32 {
    rows.iter().fold(seed, |acc, r| acc + r)
}
"#,
            good: r#"
pub fn fold_bucket(seed: f32, rows: &[f32]) -> f32 {
    // PARITY: the seed enters BEFORE the row fold and rows fold in
    // order — bucket k at ring position j must replay the fused sum.
    rows.iter().fold(seed, |acc, r| acc + r)
}
"#,
        },
        Fixture {
            // The wall-clock rule also scopes `src/ckpt/`: checkpoint
            // images and journal lines must stamp the SIM clock — a wall
            // time in either would make a restored run unreplayable.
            rule: "wall-clock",
            path: "src/ckpt/lintfix.rs",
            bad: r#"
pub fn journal_stamp_is_fresh() -> bool {
    std::time::SystemTime::now().elapsed().is_ok()
}
"#,
            good: r#"
pub fn journal_stamp(sim_clock_s: f64) -> f64 {
    sim_clock_s
}
"#,
        },
        Fixture {
            // The fold-order rule also covers the wire codec's quickselect
            // partition: the selected prefix must equal the full-sort
            // reference bit-for-bit, which only holds when the key is a
            // duplicate-free total order — a property the marker forces
            // the author to state.
            rule: "fold-order",
            path: "src/runtime/native/lintfix_select.rs",
            bad: r#"
pub fn cut(order: &mut [u32], k: usize) {
    order.select_nth_unstable(k - 1);
}
"#,
            good: r#"
pub fn cut(order: &mut [u32], k: usize) {
    // PARITY: indices are distinct, so the selected prefix is exactly
    // the full-sort prefix — ties cannot reach the unstable partition.
    order.select_nth_unstable(k - 1);
}
"#,
        },
        Fixture {
            // The feature-detect rule's second token: `#[target_feature]`
            // lanes may only live in the SIMD module allowlist (exec.rs,
            // linalg.rs, comm/wire.rs) where the tier dispatch and its
            // SAFETY obligations stay in one auditable place.
            rule: "feature-detect",
            path: "src/runtime/sharded/lintfix_simd.rs",
            bad: r#"
// SAFETY: callers prove avx2 before taking this lane.
#[target_feature(enable = "avx2")]
pub unsafe fn bump_lane(x: &mut [f32]) {
    x[0] += 1.0;
}
"#,
            good: r#"
pub fn bump(pool: &crate::runtime::native::exec::Pool, x: &mut [f32]) {
    crate::runtime::native::linalg::relu(pool, x);
}
"#,
        },
        Fixture {
            rule: "feature-detect",
            path: "src/runtime/native/lintfix3.rs",
            bad: r#"
pub fn has_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}
"#,
            good: r#"
pub fn has_avx2() -> bool {
    crate::runtime::native::exec::KernelTier::resolved().is_simd()
}
"#,
        },
        Fixture {
            rule: "suppression",
            path: "src/trainer/lintfix2.rs",
            // An allow without a justification suffix is itself a
            // violation AND does not suppress the underlying rule.
            bad: r#"
pub fn knob() -> Option<String> {
    std::env::var("DYNAMIX_KNOB").ok() // lint:allow(env-read)
}
"#,
            good: r#"
pub fn knob() -> Option<String> {
    std::env::var("DYNAMIX_KNOB").ok() // lint:allow(env-read): read once at startup; value is mirrored into the config layer.
}
"#,
        },
    ]
}
