//! Worker <-> arbitrator communication layer.
//!
//! The paper uses gRPC (§V); this build is offline, so the wire layer is a
//! hand-rolled, versioned, length-prefixed binary protocol with the same
//! message schema and the same state-up / action-down cycle. Two
//! transports implement the common [`Transport`] trait:
//!
//! * [`TcpTransport`] — real sockets, used by the distributed
//!   leader/worker example (`examples/distributed.rs`) and the §VI-H
//!   overhead measurement;
//! * [`ChannelTransport`] — in-process `mpsc`, used by the simulator and
//!   tests (zero-copy of the same encode/decode path so framing bugs
//!   cannot hide in sim mode).
//!
//! Encoding: little-endian, `u32` frame length, then `u16` proto version,
//! `u8` message tag, payload. All floats are f64 bit patterns.

pub mod leader;
pub mod wire;

use crate::rl::state::StateVector;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use wire::{Decoder, Encoder};

/// Bumped to 2 when the shard-gradient data-plane frames landed
/// (`ShardStep`/`ShardFwd`/`ShardGradSeed`/`ShardGradOut`/`ShardGradFin`);
/// to 3 for the pipelined bucket frames
/// (`ShardGradBucket`/`ShardBucketFin`); to 4 for the ZeRO
/// reduce-scatter / compressed-wire frames
/// (`ShardGradSlice`/`ShardGradTopK`/`ShardGradQ8`/`ShardParamSlice`); to
/// 5 when `ShardGradFin` grew the per-step gradient-moment triple
/// (`sigma_norm`/`sigma_norm2`/`grad_l2`), fixing the zero-plane
/// sigma-stat blackout (an empty-gradient fin left worker RL features at
/// 0.0). A peer speaking an older codec is rejected at decode with a
/// version-mismatch error naming both versions.
pub const PROTO_VERSION: u16 = 5;

/// Hard ceiling on one frame's body. Sized for the largest legitimate
/// payload — a shard row slab at the top bucket (32768 x 128 features x
/// 4 B = 16 MiB) — while still rejecting forged giant length prefixes.
pub const MAX_FRAME: usize = 32 << 20;

/// One shard's row slice of a fused batch: `x` is `[mask.len(),
/// feature_dim]` row-major, `y`/`mask` per-row. Model-tagged so a shard
/// server needs no out-of-band schema agreement.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardRows {
    pub model: String,
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub mask: Vec<f32>,
}

/// Protocol messages: the paper Fig. 1 control plane (state up, action
/// down, lifecycle) plus the shard-gradient data plane (fused-batch rows
/// out, chained gradient reduction around the shards, reduced gradient
/// broadcast back).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker announces itself and its capabilities.
    Register { worker: u32, max_batch: u32 },
    /// Arbitrator acknowledges registration. `n_workers`/`cycles` are the
    /// LEADER's deployment sizes (they override whatever the worker's
    /// preset says — demo/smoke runs shrink both), so data sharding and
    /// progress accounting agree across the cluster.
    Welcome { worker: u32, k: u32, initial_batch: u32, n_workers: u32, cycles: u32 },
    /// Worker's k-iteration window state report (§III-C cycle).
    StateReport {
        worker: u32,
        cycle: u32,
        state: StateVector,
        reward: f64,
        sim_clock: f64,
    },
    /// Arbitrator's batch-size adjustment for one worker (§IV-C).
    Action { worker: u32, cycle: u32, delta: i32, new_batch: u32 },
    /// BSP barrier marker (used by the distributed example).
    Barrier { cycle: u32 },
    /// Graceful shutdown broadcast (Algorithm 1 line 33).
    Shutdown,
    /// Data plane: begin one fused iteration on a shard. `denom` is the
    /// global fused-batch mask sum (per-row loss gradients scale by it).
    /// `rows`/`params` are None for shards that own their data and hold a
    /// parameter replica (the TCP leader/worker deployment).
    ShardStep {
        seq: u64,
        denom: f32,
        train: bool,
        rows: Option<ShardRows>,
        params: Option<Vec<f32>>,
    },
    /// Data plane: a shard's per-row loss pieces (forward half done).
    ShardFwd { seq: u64, loss_terms: Vec<f32>, correct: Vec<f32> },
    /// Data plane: the traveling gradient accumulator arrives at a shard
    /// (one hop of the chained deterministic reduction).
    ShardGradSeed { seq: u64, grad: Vec<f32> },
    /// Data plane: the accumulator after folding this shard's rows in.
    ShardGradOut { seq: u64, grad: Vec<f32> },
    /// Data plane: fully-reduced gradient broadcast. Replica-holding
    /// shards apply the same optimizer update, staying bit-identical.
    /// `sigma_norm`/`sigma_norm2`/`grad_l2` (v5) carry the step's
    /// normalized gradient moments, computed by the leader from the full
    /// reduced gradient: the zero plane's fin has an EMPTY `grad` (the
    /// slices already traveled), so without the triple a worker's
    /// sigma-stat RL features would silently read 0.0 — the zero-plane
    /// blackout this field fixes.
    ShardGradFin {
        seq: u64,
        loss: f32,
        acc: f32,
        sigma_norm: f32,
        sigma_norm2: f32,
        grad_l2: f32,
        grad: Vec<f32>,
    },
    /// Data plane: a shard failed to process step `seq` (bad inputs,
    /// protocol abuse). The shard stays alive and serviceable; the leader
    /// surfaces the message as the step's error.
    ShardErr { seq: u64, msg: String },
    /// Data plane: one traveling gradient **bucket** — the window
    /// `[offset, offset + grad.len())` of the flat gradient, hop `bucket`
    /// of the step's deterministic plan. Used in both ring directions.
    ShardGradBucket { seq: u64, bucket: u32, offset: u64, grad: Vec<f32> },
    /// Data plane: a shard's bucketed backward completed after exactly
    /// `buckets` buckets (plan-agreement acknowledgement).
    ShardBucketFin { seq: u64, buckets: u32 },
    /// Data plane (v4, ZeRO plane): one dense traveling gradient slice —
    /// the window `[offset, offset + grad.len())` of the flat gradient,
    /// hop `slice` of the step's partition-aligned plan. Same schedule as
    /// `ShardGradBucket`; a distinct tag so a replica/ZeRO plane mismatch
    /// fails loudly instead of folding the wrong protocol.
    ShardGradSlice { seq: u64, slice: u32, offset: u64, grad: Vec<f32> },
    /// Data plane (v4): a traveling slice under `DYNAMIX_WIRE=topk` —
    /// `len` is the dense window length, `idx`/`val` the kept elements in
    /// strictly increasing index order (`wire::topk_encode`). The decoder
    /// validates `len`, counts and monotonicity BEFORE any dense
    /// allocation.
    ShardGradTopK { seq: u64, slice: u32, offset: u64, len: u64, idx: Vec<u32>, val: Vec<f32> },
    /// Data plane (v4): a traveling slice under `DYNAMIX_WIRE=q8` —
    /// symmetric int8 with a per-window power-of-two f32 `scale`
    /// (`wire::q8_encode`); the dense length is `q.len()`.
    ShardGradQ8 { seq: u64, slice: u32, offset: u64, scale: f32, q: Vec<i8> },
    /// Data plane (v4): an owner's updated parameter slice, the
    /// all-gather leg of the reduce-scatter plane.
    ShardParamSlice { seq: u64, slice: u32, offset: u64, params: Vec<f32> },
}

const TAG_REGISTER: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_STATE: u8 = 3;
const TAG_ACTION: u8 = 4;
const TAG_BARRIER: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;
const TAG_SHARD_STEP: u8 = 7;
const TAG_SHARD_FWD: u8 = 8;
const TAG_SHARD_GRAD_SEED: u8 = 9;
const TAG_SHARD_GRAD_OUT: u8 = 10;
const TAG_SHARD_GRAD_FIN: u8 = 11;
const TAG_SHARD_ERR: u8 = 12;
const TAG_SHARD_GRAD_BUCKET: u8 = 13;
const TAG_SHARD_BUCKET_FIN: u8 = 14;
const TAG_SHARD_GRAD_SLICE: u8 = 15;
const TAG_SHARD_GRAD_TOPK: u8 = 16;
const TAG_SHARD_GRAD_Q8: u8 = 17;
const TAG_SHARD_PARAM_SLICE: u8 = 18;

impl Msg {
    /// Encode to a length-prefixed frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        e.u16(PROTO_VERSION);
        match self {
            Msg::Register { worker, max_batch } => {
                e.u8(TAG_REGISTER);
                e.u32(*worker);
                e.u32(*max_batch);
            }
            Msg::Welcome { worker, k, initial_batch, n_workers, cycles } => {
                e.u8(TAG_WELCOME);
                e.u32(*worker);
                e.u32(*k);
                e.u32(*initial_batch);
                e.u32(*n_workers);
                e.u32(*cycles);
            }
            Msg::StateReport { worker, cycle, state, reward, sim_clock } => {
                e.u8(TAG_STATE);
                e.u32(*worker);
                e.u32(*cycle);
                e.u8(state.0.len() as u8);
                for &f in &state.0 {
                    e.f64(f as f64);
                }
                e.f64(*reward);
                e.f64(*sim_clock);
            }
            Msg::Action { worker, cycle, delta, new_batch } => {
                e.u8(TAG_ACTION);
                e.u32(*worker);
                e.u32(*cycle);
                e.i32(*delta);
                e.u32(*new_batch);
            }
            Msg::Barrier { cycle } => {
                e.u8(TAG_BARRIER);
                e.u32(*cycle);
            }
            Msg::Shutdown => {
                e.u8(TAG_SHUTDOWN);
            }
            Msg::ShardStep { seq, denom, train, rows, params } => {
                e.u8(TAG_SHARD_STEP);
                e.u64(*seq);
                e.f32(*denom);
                e.u8(u8::from(*train));
                match rows {
                    Some(r) => {
                        e.u8(1);
                        e.str(&r.model);
                        e.f32s(&r.x);
                        e.i32s(&r.y);
                        e.f32s(&r.mask);
                    }
                    None => e.u8(0),
                }
                match params {
                    Some(p) => {
                        e.u8(1);
                        e.f32s(p);
                    }
                    None => e.u8(0),
                }
            }
            Msg::ShardFwd { seq, loss_terms, correct } => {
                e.u8(TAG_SHARD_FWD);
                e.u64(*seq);
                e.f32s(loss_terms);
                e.f32s(correct);
            }
            Msg::ShardGradSeed { seq, grad } => {
                e.u8(TAG_SHARD_GRAD_SEED);
                e.u64(*seq);
                e.f32s(grad);
            }
            Msg::ShardGradOut { seq, grad } => {
                e.u8(TAG_SHARD_GRAD_OUT);
                e.u64(*seq);
                e.f32s(grad);
            }
            Msg::ShardGradFin { seq, loss, acc, sigma_norm, sigma_norm2, grad_l2, grad } => {
                e.u8(TAG_SHARD_GRAD_FIN);
                e.u64(*seq);
                e.f32(*loss);
                e.f32(*acc);
                e.f32(*sigma_norm);
                e.f32(*sigma_norm2);
                e.f32(*grad_l2);
                e.f32s(grad);
            }
            Msg::ShardErr { seq, msg } => {
                e.u8(TAG_SHARD_ERR);
                e.u64(*seq);
                e.str(msg);
            }
            Msg::ShardGradBucket { seq, bucket, offset, grad } => {
                e.u8(TAG_SHARD_GRAD_BUCKET);
                e.u64(*seq);
                e.u32(*bucket);
                e.u64(*offset);
                e.f32s(grad);
            }
            Msg::ShardBucketFin { seq, buckets } => {
                e.u8(TAG_SHARD_BUCKET_FIN);
                e.u64(*seq);
                e.u32(*buckets);
            }
            Msg::ShardGradSlice { seq, slice, offset, grad } => {
                e.u8(TAG_SHARD_GRAD_SLICE);
                e.u64(*seq);
                e.u32(*slice);
                e.u64(*offset);
                e.f32s(grad);
            }
            Msg::ShardGradTopK { seq, slice, offset, len, idx, val } => {
                e.u8(TAG_SHARD_GRAD_TOPK);
                e.u64(*seq);
                e.u32(*slice);
                e.u64(*offset);
                e.u64(*len);
                e.u32s(idx);
                e.f32s(val);
            }
            Msg::ShardGradQ8 { seq, slice, offset, scale, q } => {
                e.u8(TAG_SHARD_GRAD_Q8);
                e.u64(*seq);
                e.u32(*slice);
                e.u64(*offset);
                e.f32(*scale);
                let raw: Vec<u8> = q.iter().map(|&v| v as u8).collect();
                e.bytes(&raw);
            }
            Msg::ShardParamSlice { seq, slice, offset, params } => {
                e.u8(TAG_SHARD_PARAM_SLICE);
                e.u64(*seq);
                e.u32(*slice);
                e.u64(*offset);
                e.f32s(params);
            }
        }
        e.frame()
    }

    /// Decode one frame body (without the length prefix).
    pub fn decode(body: &[u8]) -> anyhow::Result<Msg> {
        let mut d = Decoder::new(body);
        let ver = d.u16()?;
        anyhow::ensure!(ver == PROTO_VERSION, "protocol version {ver} != {PROTO_VERSION}");
        let tag = d.u8()?;
        let msg = match tag {
            TAG_REGISTER => Msg::Register { worker: d.u32()?, max_batch: d.u32()? },
            TAG_WELCOME => Msg::Welcome {
                worker: d.u32()?,
                k: d.u32()?,
                initial_batch: d.u32()?,
                n_workers: d.u32()?,
                cycles: d.u32()?,
            },
            TAG_STATE => {
                let worker = d.u32()?;
                let cycle = d.u32()?;
                let n = d.u8()? as usize;
                let mut state = Vec::with_capacity(n);
                for _ in 0..n {
                    state.push(d.f64()? as f32);
                }
                Msg::StateReport {
                    worker,
                    cycle,
                    state: StateVector(state),
                    reward: d.f64()?,
                    sim_clock: d.f64()?,
                }
            }
            TAG_ACTION => Msg::Action {
                worker: d.u32()?,
                cycle: d.u32()?,
                delta: d.i32()?,
                new_batch: d.u32()?,
            },
            TAG_BARRIER => Msg::Barrier { cycle: d.u32()? },
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_SHARD_STEP => {
                let seq = d.u64()?;
                let denom = d.f32()?;
                let train = d.u8()? != 0;
                let rows = if d.u8()? != 0 {
                    Some(ShardRows {
                        model: d.str()?,
                        x: d.f32s()?,
                        y: d.i32s()?,
                        mask: d.f32s()?,
                    })
                } else {
                    None
                };
                let params = if d.u8()? != 0 { Some(d.f32s()?) } else { None };
                Msg::ShardStep { seq, denom, train, rows, params }
            }
            TAG_SHARD_FWD => Msg::ShardFwd {
                seq: d.u64()?,
                loss_terms: d.f32s()?,
                correct: d.f32s()?,
            },
            TAG_SHARD_GRAD_SEED => Msg::ShardGradSeed { seq: d.u64()?, grad: d.f32s()? },
            TAG_SHARD_GRAD_OUT => Msg::ShardGradOut { seq: d.u64()?, grad: d.f32s()? },
            TAG_SHARD_GRAD_FIN => Msg::ShardGradFin {
                seq: d.u64()?,
                loss: d.f32()?,
                acc: d.f32()?,
                sigma_norm: d.f32()?,
                sigma_norm2: d.f32()?,
                grad_l2: d.f32()?,
                grad: d.f32s()?,
            },
            TAG_SHARD_ERR => Msg::ShardErr { seq: d.u64()?, msg: d.str()? },
            TAG_SHARD_GRAD_BUCKET => Msg::ShardGradBucket {
                seq: d.u64()?,
                bucket: d.u32()?,
                offset: d.u64()?,
                grad: d.f32s()?,
            },
            TAG_SHARD_BUCKET_FIN => Msg::ShardBucketFin { seq: d.u64()?, buckets: d.u32()? },
            TAG_SHARD_GRAD_SLICE => Msg::ShardGradSlice {
                seq: d.u64()?,
                slice: d.u32()?,
                offset: d.u64()?,
                grad: d.f32s()?,
            },
            TAG_SHARD_GRAD_TOPK => {
                let (seq, slice, offset) = (d.u64()?, d.u32()?, d.u64()?);
                let len = d.u64()?;
                let idx = d.u32s()?;
                let val = d.f32s()?;
                // Validate the DECLARED dense length (and the index/count
                // invariants) at the protocol boundary, before any decoder
                // downstream allocates a dense window from it. The frame's
                // own arrays are already bounds-checked against the body.
                let dense: usize = usize::try_from(len)
                    .map_err(|_| anyhow::anyhow!("topk dense length {len} overflows"))?;
                wire::topk_validate(dense, &idx, &val)?;
                Msg::ShardGradTopK { seq, slice, offset, len, idx, val }
            }
            TAG_SHARD_GRAD_Q8 => {
                let (seq, slice, offset) = (d.u64()?, d.u32()?, d.u64()?);
                let scale = d.f32()?;
                anyhow::ensure!(
                    scale.is_finite() && scale >= 0.0,
                    "q8 scale must be finite and non-negative"
                );
                let q: Vec<i8> = d.bytes()?.iter().map(|&b| b as i8).collect();
                Msg::ShardGradQ8 { seq, slice, offset, scale, q }
            }
            TAG_SHARD_PARAM_SLICE => Msg::ShardParamSlice {
                seq: d.u64()?,
                slice: d.u32()?,
                offset: d.u64()?,
                params: d.f32s()?,
            },
            t => anyhow::bail!("unknown message tag {t}"),
        };
        d.finish()?;
        Ok(msg)
    }
}

/// Bidirectional message transport.
pub trait Transport: Send {
    fn send(&mut self, msg: &Msg) -> anyhow::Result<()>;
    fn recv(&mut self) -> anyhow::Result<Msg>;

    /// A detached write half over the same connection, when the carrier
    /// can clone its OS handle (TCP can; the default cannot). Lets one
    /// thread block in `recv` while another sends. Framing stays intact
    /// because each `send` issues a single `write_all`.
    fn clone_writer(&self) -> Option<Box<dyn Transport + Send>> {
        None
    }
}

/// Framed TCP transport.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> anyhow::Result<Self> {
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Msg) -> anyhow::Result<()> {
        let frame = msg.encode();
        self.stream.write_all(&frame)?;
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<Msg> {
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        anyhow::ensure!(len <= MAX_FRAME, "frame too large: {len}");
        let mut body = vec![0u8; len];
        self.stream.read_exact(&mut body)?;
        Msg::decode(&body)
    }

    fn clone_writer(&self) -> Option<Box<dyn Transport + Send>> {
        self.stream
            .try_clone()
            .ok()
            .map(|stream| Box::new(TcpTransport { stream }) as Box<dyn Transport + Send>)
    }
}

/// In-process transport over std mpsc, running the same encode/decode.
pub struct ChannelTransport {
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

/// Create a connected pair of in-process transports.
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (tx_a, rx_b) = mpsc::channel();
    let (tx_b, rx_a) = mpsc::channel();
    (
        ChannelTransport { tx: tx_a, rx: rx_a },
        ChannelTransport { tx: tx_b, rx: rx_b },
    )
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: &Msg) -> anyhow::Result<()> {
        // Same serialized bytes as TCP so the codec is always exercised.
        self.tx
            .send(msg.encode())
            .map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv(&mut self) -> anyhow::Result<Msg> {
        let frame = self.rx.recv().map_err(|_| anyhow::anyhow!("peer closed"))?;
        anyhow::ensure!(frame.len() >= 4, "short frame");
        let len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
        // Same ceiling as TCP: in-process peers get no oversize privilege,
        // so a frame that would be rejected on sockets never hides here.
        anyhow::ensure!(len <= MAX_FRAME, "frame too large: {len}");
        anyhow::ensure!(frame.len() == len + 4, "frame length mismatch");
        Msg::decode(&frame[4..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_msgs() -> Vec<Msg> {
        vec![
            Msg::Register { worker: 3, max_batch: 1024 },
            Msg::Welcome { worker: 3, k: 5, initial_batch: 128, n_workers: 4, cycles: 10 },
            Msg::StateReport {
                worker: 3,
                cycle: 17,
                state: StateVector(vec![0.5; 16]),
                reward: -1.25,
                sim_clock: 99.5,
            },
            Msg::Action { worker: 3, cycle: 17, delta: -25, new_batch: 103 },
            Msg::Barrier { cycle: 42 },
            Msg::ShardStep {
                seq: 9,
                denom: 512.0,
                train: true,
                rows: Some(ShardRows {
                    model: "vgg11_mini".into(),
                    x: vec![0.5; 2 * 4],
                    y: vec![1, 3],
                    mask: vec![1.0, 0.0],
                }),
                params: Some(vec![-0.25; 6]),
            },
            Msg::ShardStep { seq: 10, denom: 64.0, train: false, rows: None, params: None },
            Msg::ShardFwd { seq: 9, loss_terms: vec![2.3, 0.0], correct: vec![1.0, 0.0] },
            Msg::ShardGradSeed { seq: 9, grad: vec![0.0; 5] },
            Msg::ShardGradOut { seq: 9, grad: vec![0.125; 5] },
            Msg::ShardGradFin {
                seq: 9,
                loss: 2.3,
                acc: 0.5,
                sigma_norm: 0.75,
                sigma_norm2: 0.5625,
                grad_l2: 1.25,
                grad: vec![0.125; 5],
            },
            // The zero-plane shape: empty grad, stats carried in the triple.
            Msg::ShardGradFin {
                seq: 10,
                loss: 1.9,
                acc: 0.625,
                sigma_norm: 0.25,
                sigma_norm2: 0.0625,
                grad_l2: 0.5,
                grad: vec![],
            },
            Msg::ShardErr { seq: 9, msg: "label 37 outside [0, 10)".into() },
            Msg::ShardGradBucket { seq: 9, bucket: 2, offset: 650, grad: vec![0.125; 4] },
            Msg::ShardGradBucket { seq: 9, bucket: 0, offset: 0, grad: vec![] },
            Msg::ShardBucketFin { seq: 9, buckets: 3 },
            Msg::ShardGradSlice { seq: 11, slice: 1, offset: 640, grad: vec![-0.5; 6] },
            Msg::ShardGradTopK {
                seq: 11,
                slice: 2,
                offset: 64,
                len: 8,
                idx: vec![1, 5],
                val: vec![0.75, -1.5],
            },
            Msg::ShardGradQ8 {
                seq: 11,
                slice: 3,
                offset: 0,
                scale: 0.015625,
                q: vec![-127, 0, 64, 127],
            },
            Msg::ShardParamSlice { seq: 11, slice: 0, offset: 0, params: vec![0.25; 5] },
            // Shutdown stays LAST: the TCP roundtrip test's echo server
            // exits on it.
            Msg::Shutdown,
        ]
    }

    #[test]
    fn roundtrip_all_messages() {
        for msg in sample_msgs() {
            let frame = msg.encode();
            let len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
            assert_eq!(len + 4, frame.len());
            let decoded = Msg::decode(&frame[4..]).unwrap();
            assert_eq!(decoded, msg);
        }
    }

    #[test]
    fn rejects_bad_version_and_tag() {
        let mut frame = Msg::Shutdown.encode();
        frame[4] = 99; // version low byte
        assert!(Msg::decode(&frame[4..]).is_err());
        let mut frame = Msg::Shutdown.encode();
        frame[6] = 200; // tag
        assert!(Msg::decode(&frame[4..]).is_err());
    }

    #[test]
    fn rejects_trailing_bytes() {
        let mut frame = Msg::Barrier { cycle: 1 }.encode();
        frame.push(0);
        assert!(Msg::decode(&frame[4..]).is_err());
    }

    #[test]
    fn topk_frame_with_forged_dense_length_rejected_before_alloc() {
        // The compressed frame is tiny, but its DECLARED dense length
        // claims gigabytes: decode must reject at the protocol boundary,
        // never letting a downstream dense-window allocation see it.
        for forged in [u64::MAX, (MAX_FRAME as u64 / 4) + 1, u64::from(u32::MAX)] {
            let mut e = Encoder::new();
            e.u16(PROTO_VERSION);
            e.u8(TAG_SHARD_GRAD_TOPK);
            e.u64(9); // seq
            e.u32(0); // slice
            e.u64(0); // offset
            e.u64(forged);
            e.u32s(&[1, 5]);
            e.f32s(&[0.5, -0.5]);
            let frame = e.frame();
            let err = Msg::decode(&frame[4..]).unwrap_err().to_string();
            assert!(
                err.contains("frame ceiling") || err.contains("overflows"),
                "forged len {forged} escaped: {err}"
            );
        }
        // Count and monotonicity forgeries die at the same boundary.
        let good = Msg::ShardGradTopK {
            seq: 9,
            slice: 0,
            offset: 0,
            len: 8,
            idx: vec![1, 5],
            val: vec![0.5, -0.5],
        };
        assert!(Msg::decode(&good.encode()[4..]).is_ok());
        for (idx, val) in [
            (vec![5u32, 1], vec![0.5f32, -0.5]), // not increasing
            (vec![1, 9], vec![0.5, -0.5]),       // out of range
            (vec![1], vec![0.5]),                // wrong k for len 8
        ] {
            let bad = Msg::ShardGradTopK { seq: 9, slice: 0, offset: 0, len: 8, idx, val };
            assert!(Msg::decode(&bad.encode()[4..]).is_err());
        }
    }

    #[test]
    fn q8_frame_with_hostile_scale_rejected() {
        for scale in [f32::NAN, f32::INFINITY, -0.25] {
            let bad = Msg::ShardGradQ8 { seq: 9, slice: 0, offset: 0, scale, q: vec![1, -1] };
            assert!(Msg::decode(&bad.encode()[4..]).is_err(), "scale {scale} accepted");
        }
    }

    #[test]
    fn channel_transport_enforces_the_frame_ceiling() {
        // A forged giant length prefix on the in-process transport errors
        // exactly like TCP — before any body processing.
        let (a, mut b) = channel_pair();
        let mut raw = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        raw.extend_from_slice(&[0u8; 8]);
        a.tx.send(raw).unwrap();
        let err = b.recv().unwrap_err().to_string();
        assert!(err.contains("frame too large"), "{err}");
    }

    #[test]
    fn channel_transport_roundtrip() {
        let (mut a, mut b) = channel_pair();
        for msg in sample_msgs() {
            a.send(&msg).unwrap();
            assert_eq!(b.recv().unwrap(), msg);
            b.send(&msg).unwrap();
            assert_eq!(a.recv().unwrap(), msg);
        }
    }

    #[test]
    fn tcp_clone_writer_shares_the_connection() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            loop {
                let m = t.recv().unwrap();
                let done = m == Msg::Shutdown;
                t.send(&m).unwrap(); // echo
                if done {
                    break;
                }
            }
        });
        let mut c = TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        let mut w = c.clone_writer().expect("tcp supports a write half");
        // Sends go through the detached half while the original blocks in
        // recv — the comm-lane usage pattern.
        let sender = std::thread::spawn(move || {
            for cycle in 0..4 {
                w.send(&Msg::Barrier { cycle }).unwrap();
            }
            w.send(&Msg::Shutdown).unwrap();
        });
        for cycle in 0..4 {
            assert_eq!(c.recv().unwrap(), Msg::Barrier { cycle });
        }
        assert_eq!(c.recv().unwrap(), Msg::Shutdown);
        sender.join().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn tcp_transport_roundtrip() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            loop {
                let m = t.recv().unwrap();
                if m == Msg::Shutdown {
                    t.send(&m).unwrap();
                    break;
                }
                t.send(&m).unwrap(); // echo
            }
        });
        let mut c = TcpTransport::new(TcpStream::connect(addr).unwrap()).unwrap();
        for msg in sample_msgs() {
            c.send(&msg).unwrap();
            assert_eq!(c.recv().unwrap(), msg);
        }
        h.join().unwrap();
    }
}
