//! Distributed leader/worker deployment over the TCP protocol.
//!
//! This is the paper's Fig. 1 deployed across real processes, with a REAL
//! synchronous data-parallel data plane (since PR 4; the old demo mode ran
//! local SGD on independent replicas). Each iteration:
//!
//! 1. the leader broadcasts `ShardStep { denom }` (the global batch's mask
//!    sum); every worker draws its own shard rows at its current batch
//!    size and runs the forward half, reporting per-row loss pieces;
//! 2. the gradient accumulator rings through the workers in id order —
//!    the same chained deterministic reduction the loopback
//!    `ShardedBackend` uses, relayed by the leader. Under the default
//!    **zero plane** it travels window-by-window as v4 slice frames
//!    (compressible via `DYNAMIX_WIRE=dense|topk|q8`); under
//!    `DYNAMIX_PLANE=replica` it travels whole
//!    (`ShardGradSeed`/`ShardGradOut`);
//! 3. **replica plane**: the leader broadcasts the reduced gradient
//!    (`ShardGradFin`) and every worker applies the identical optimizer
//!    update to its full parameter replica. **Zero plane**: each worker
//!    owns one contiguous bucket-aligned parameter slice
//!    (`param_partition`) and holds optimizer state for ONLY that slice —
//!    `O(P/N)` resident floats — so the leader scatters each owner its
//!    reduced slice, the owner applies `apply_*_slice` locally and
//!    returns the updated params (`ShardParamSlice`), and the leader
//!    all-gathers the slices back out; an empty-gradient `ShardGradFin`
//!    then carries loss/acc as the step barrier. On BOTH planes the fin
//!    carries the step's normalized gradient moments (v5), computed
//!    leader-side from the full reduced gradient, so the zero plane's
//!    empty-gradient barrier no longer blacks out the workers' sigma-stat
//!    RL features.
//!
//! The control plane is unchanged: every `k` iterations workers report
//! their window state, the leader's PPO arbitrator scores all workers in
//! one forward pass and pushes batch-size actions back (Algorithm 1's
//! register -> welcome -> state/action cycles -> shutdown lifecycle).
//! Worker-measured wall times are real, preserving the §VI-H overhead
//! story. The leader writes a `RunRecord` under `runs/distributed/`.
//!
//! **Durable runs** (opt-in via `--ckpt-dir` / `DYNAMIX_CKPT_DIR`): the
//! leader appends a run journal (registrations, decision cycles,
//! checkpoints) and writes an atomic [`LeaderCkpt`] image every
//! `DYNAMIX_CKPT_EVERY` cycles — its parameter mirror (maintained at zero
//! extra traffic: the replica plane's reduced gradient / the zero plane's
//! all-gathered slices pass through the leader anyway), the per-worker
//! batch assignment and the cycle index, fingerprinted against
//! cross-deployment restores like the coordinator's full image.

use crate::ckpt::{CkptHeader, Journal, LeaderCkpt};
use crate::comm::wire::{self, WireMode};
use crate::comm::{Msg, TcpTransport, Transport};
use crate::config::{presets, Optimizer, Scale};
use crate::metrics::{mean_std_usize, RunRecord, TracePoint};
use crate::rl::action::BatchRule;
use crate::rl::agent::PpoAgent;
use crate::rl::reward::RewardParams;
use crate::rl::state::{GlobalState, StateBuilder};
use crate::runtime::default_backend;
use crate::runtime::native::model::{
    apply_adam, apply_adam_slice, apply_sgd, apply_sgd_slice, fold_masked_ce_partial,
    normalized_grad_stats,
};
use crate::runtime::native::exec::Pool;
use crate::runtime::native::{NativeBackend, ShardCtx};
use crate::runtime::OptState;
use crate::sysmetrics::{SysSample, WindowAggregator};
use crate::util::json::Json;
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

/// Bucket target shared by the deployed reduce-scatter's travel plan and
/// its ownership partition. Leader and workers derive both independently
/// from the model layout — pure arithmetic, never transmitted — so the
/// target must be one compile-time constant on both sides.
const ZERO_BUCKET_BYTES: usize = 32 << 10;

/// `DYNAMIX_PLANE` for the deployed data plane: zero (reduce-scatter)
/// unless `replica` is requested. Read once at leader/worker start.
fn zero_plane() -> bool {
    crate::config::env::plane().as_deref() != Some("replica")
}

/// Wrap one traveling gradient window in the configured slice frame.
fn encode_slice_msg(mode: WireMode, seq: u64, slice: u32, offset: usize, win: Vec<f32>) -> Msg {
    match mode {
        WireMode::Dense => Msg::ShardGradSlice { seq, slice, offset: offset as u64, grad: win },
        WireMode::TopK => {
            let len = win.len() as u64;
            let (idx, val) = wire::topk_encode(&win);
            Msg::ShardGradTopK { seq, slice, offset: offset as u64, len, idx, val }
        }
        WireMode::Q8 => {
            let (scale, q) = wire::q8_encode(&win);
            Msg::ShardGradQ8 { seq, slice, offset: offset as u64, scale, q }
        }
    }
}

/// Unpack any slice frame to `(seq, slice, offset, dense window)`.
fn decode_slice_msg(msg: Msg) -> anyhow::Result<(u64, u32, usize, Vec<f32>)> {
    match msg {
        Msg::ShardGradSlice { seq, slice, offset, grad } => {
            Ok((seq, slice, offset as usize, grad))
        }
        Msg::ShardGradTopK { seq, slice, offset, len, idx, val } => {
            let dense = usize::try_from(len)
                .map_err(|_| anyhow::anyhow!("topk dense length {len} overflows"))?;
            Ok((seq, slice, offset as usize, wire::topk_decode(dense, &idx, &val)?))
        }
        Msg::ShardGradQ8 { seq, slice, offset, scale, q } => {
            Ok((seq, slice, offset as usize, wire::q8_decode(scale, &q)?))
        }
        other => anyhow::bail!("expected a gradient slice frame, got {other:?}"),
    }
}

/// Leader-side receive of worker `w`'s reply for ring hop `slice` of step
/// `seq`: the frame kind must match the configured wire mode (a worker
/// answering dense to a q8 hop is a protocol error, not a fallback).
fn recv_slice_frame(
    t: &mut TcpTransport,
    w: usize,
    seq: u64,
    slice: u32,
    mode: WireMode,
) -> anyhow::Result<Msg> {
    let frame = t.recv()?;
    let (kind, rs, rb) = match &frame {
        Msg::ShardGradSlice { seq, slice, .. } => (WireMode::Dense, *seq, *slice),
        Msg::ShardGradTopK { seq, slice, .. } => (WireMode::TopK, *seq, *slice),
        Msg::ShardGradQ8 { seq, slice, .. } => (WireMode::Q8, *seq, *slice),
        other => anyhow::bail!("worker {w}: expected slice {slice} of seq {seq}, got {other:?}"),
    };
    anyhow::ensure!(
        kind == mode,
        "worker {w}: slice {slice} of seq {seq} replied in wire mode {} != configured {}",
        kind.label(),
        mode.label()
    );
    anyhow::ensure!(
        rs == seq && rb == slice,
        "worker {w}: slice reply (seq {rs}, slice {rb}) != expected (seq {seq}, slice {slice})"
    );
    Ok(frame)
}

/// Run the leader: accept the preset's worker count, drive
/// `steps_per_episode` decision cycles, broadcast shutdown.
pub fn serve(bind: &str, preset: &str, scale: Scale) -> anyhow::Result<()> {
    let cfg = presets::scaled(presets::by_name(preset)?, scale);
    let (n, cycles) = (cfg.cluster.n_workers, cfg.steps_per_episode);
    serve_n(bind, preset, scale, n, cycles)
}

/// [`serve`] with explicit worker count + cycle budget (demo/test sizes).
pub fn serve_n(
    bind: &str,
    preset: &str,
    scale: Scale,
    n_workers: usize,
    cycles: usize,
) -> anyhow::Result<()> {
    let mut cfg = presets::scaled(presets::by_name(preset)?, scale);
    cfg.cluster.n_workers = n_workers;
    cfg.steps_per_episode = cycles;
    let backend = default_backend()?;
    let pc = backend.schema().model(&cfg.train.model)?.param_count;
    // Exchange plane + slice codec for the deployed data plane, read once
    // at startup (leader and workers must agree via the same env).
    let zero = zero_plane();
    let wire_mode = crate::config::env::wire_mode().unwrap_or(WireMode::Dense);
    // Layout oracle for the reduce-scatter travel plan and ownership
    // partition: pure arithmetic on the model definition, derived
    // identically worker-side and never transmitted.
    let layout = NativeBackend::with_threads(1);
    let plan = layout.bucket_plan(&cfg.train.model, ZERO_BUCKET_BYTES)?;
    let part =
        layout.param_partition(&cfg.train.model, &vec![true; n_workers], ZERO_BUCKET_BYTES)?;
    let mut agent = PpoAgent::new(backend, cfg.rl.clone(), cfg.train.seed)?;
    let rule = BatchRule {
        min: cfg.batch.min,
        max: cfg.batch.max,
    };

    let listener = TcpListener::bind(bind)?;
    println!("[leader] listening on {bind}; waiting for {} workers", cfg.cluster.n_workers);
    // Accept in arrival order, then sort by REGISTERED worker id: the
    // gradient ring and the loss/acc folds walk this vector, so the
    // reduction order must not depend on TCP connect races.
    let mut regs: Vec<(u32, TcpTransport, usize)> = Vec::new();
    while regs.len() < cfg.cluster.n_workers {
        let (stream, peer) = listener.accept()?;
        let mut t = TcpTransport::new(stream)?;
        match t.recv()? {
            Msg::Register { worker, max_batch } => {
                println!("[leader] worker {worker} registered from {peer} (max_batch={max_batch})");
                anyhow::ensure!(
                    !regs.iter().any(|(w, _, _)| *w == worker),
                    "duplicate worker id {worker}"
                );
                // Ids must BE data-shard ranks: congruent ids (2 and 6 mod
                // 4) would silently sample identical row streams.
                anyhow::ensure!(
                    (worker as usize) < cfg.cluster.n_workers,
                    "worker id {worker} outside 0..{} (ids are shard ranks)",
                    cfg.cluster.n_workers
                );
                // The CLAMPED batch goes in the Welcome: leader's denom and
                // the worker's row count must agree to the sample.
                let initial = cfg.batch.initial.min(max_batch as usize);
                t.send(&Msg::Welcome {
                    worker,
                    k: cfg.rl.k as u32,
                    initial_batch: initial as u32,
                    n_workers: cfg.cluster.n_workers as u32,
                    cycles: cfg.steps_per_episode as u32,
                })?;
                regs.push((worker, t, initial));
            }
            other => anyhow::bail!("expected Register, got {other:?}"),
        }
    }
    regs.sort_by_key(|(w, _, _)| *w);
    let worker_ids: Vec<u32> = regs.iter().map(|(w, _, _)| *w).collect();
    let mut batches: Vec<usize> = regs.iter().map(|(_, _, b)| *b).collect();
    let mut transports: Vec<TcpTransport> = regs.into_iter().map(|(_, t, _)| t).collect();

    // Durable-run hooks, armed only when a checkpoint directory is
    // configured. The leader mirrors the trained parameters so an image
    // can be cut without asking any worker: on the replica plane it
    // applies the same reduced update every worker applies; on the zero
    // plane the all-gathered slices it relays ARE the updated params
    // (the slice-local optimizer moments live worker-side and are not
    // captured there).
    let ckpt_dir = crate::config::env::ckpt_dir();
    let ckpt_every = crate::config::env::ckpt_every().unwrap_or(1);
    let ckpt_keep = crate::config::env::ckpt_keep();
    let journal = match &ckpt_dir {
        Some(dir) => Some(Journal::open(dir)?),
        None => None,
    };
    let ckpt_header = CkptHeader {
        plane: (if zero { "zero" } else { "replica" }).to_string(),
        wire: wire_mode.label().to_string(),
        seed: cfg.train.seed,
        n_workers: cfg.cluster.n_workers,
        model: cfg.train.model.clone(),
    };
    if let Some(j) = &journal {
        for (w, b) in worker_ids.iter().zip(&batches) {
            j.event(0.0, &format!("register worker {w} batch={b}"))?;
        }
    }
    let mut mirror: Option<OptState> = match &ckpt_dir {
        Some(_) => {
            let init = layout.init_params(&cfg.train.model, cfg.train.seed)?;
            Some(if zero {
                OptState { params: init, m: Vec::new(), v: Vec::new(), step: 0.0 }
            } else {
                OptState::new(init, cfg.train.optimizer)
            })
        }
        None => None,
    };

    let mut record = RunRecord::new(&format!("{preset}-distributed"));
    let mut seq = 0u64;
    let (mut last_loss, mut last_acc) = (0.0f64, 0.0f64);
    for cycle in 0..cfg.steps_per_episode as u32 {
        let denom: f32 = batches.iter().sum::<usize>() as f32;
        // --- data plane: k fused iterations, chained all-reduce ---
        for _ in 0..cfg.rl.k {
            seq += 1;
            let step = Msg::ShardStep { seq, denom, train: true, rows: None, params: None };
            for t in transports.iter_mut() {
                t.send(&step)?;
            }
            // Per-row loss pieces fold in worker-id order (= the reduction
            // order, so loss/acc are deterministic too) — the same fold
            // the loopback data plane and the fused loss use.
            let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
            for (w, t) in transports.iter_mut().enumerate() {
                match t.recv()? {
                    Msg::ShardFwd { seq: rs, loss_terms, correct } => {
                        anyhow::ensure!(rs == seq, "worker {w}: ShardFwd seq {rs} != {seq}");
                        fold_masked_ce_partial(&loss_terms, &correct, &mut loss_sum, &mut acc_sum);
                    }
                    other => anyhow::bail!("worker {w}: expected ShardFwd, got {other:?}"),
                }
            }
            let loss = (loss_sum / denom as f64) as f32;
            let acc = (acc_sum / denom as f64) as f32;
            (last_loss, last_acc) = (loss as f64, acc as f64);
            if zero {
                // Reduce-scatter: each travel-plan window rings through
                // the workers in id order as a slice frame, compressed
                // replies relayed verbatim; only the final hop decodes.
                let mut grad = vec![0.0f32; pc];
                for (b, win) in plan.iter().enumerate() {
                    let mut frame = encode_slice_msg(
                        wire_mode,
                        seq,
                        b as u32,
                        win.offset,
                        vec![0.0f32; win.len],
                    );
                    for (w, t) in transports.iter_mut().enumerate() {
                        t.send(&frame)?;
                        frame = recv_slice_frame(t, w, seq, b as u32, wire_mode)?;
                    }
                    let (_, _, off, dense) = decode_slice_msg(frame)?;
                    anyhow::ensure!(
                        off == win.offset && dense.len() == win.len,
                        "slice {b} of seq {seq} window [{off}, {}) != planned [{}, {})",
                        off + dense.len(),
                        win.offset,
                        win.offset + win.len
                    );
                    grad[off..off + dense.len()].copy_from_slice(&dense);
                }
                // Scatter each owner its reduced slice (param legs travel
                // dense: compression is a gradient-wire trade only).
                for (w, t) in transports.iter_mut().enumerate() {
                    let r = part[w].clone();
                    t.send(&Msg::ShardGradSlice {
                        seq,
                        slice: w as u32,
                        offset: r.start as u64,
                        grad: grad[r].to_vec(),
                    })?;
                }
                // Gather every owner's updated params...
                let mut slices: Vec<Vec<f32>> = vec![Vec::new(); transports.len()];
                for (w, t) in transports.iter_mut().enumerate() {
                    match t.recv()? {
                        Msg::ShardParamSlice { seq: rs, slice, offset, params } => {
                            anyhow::ensure!(
                                rs == seq
                                    && slice as usize == w
                                    && offset as usize == part[w].start
                                    && params.len() == part[w].len(),
                                "worker {w}: param slice (seq {rs}, slice {slice}, \
                                 [{offset}, +{})) != owned [{}, {})",
                                params.len(),
                                part[w].start,
                                part[w].end
                            );
                            slices[w] = params;
                        }
                        other => {
                            anyhow::bail!("worker {w}: expected ShardParamSlice, got {other:?}")
                        }
                    }
                }
                if let Some(mir) = mirror.as_mut() {
                    // The gathered slices ARE the post-update parameters.
                    for (u, s) in slices.iter().enumerate() {
                        if !s.is_empty() {
                            mir.params[part[u].clone()].copy_from_slice(s);
                        }
                    }
                    mir.step += 1.0;
                }
                // ...and all-gather them back out (each worker already has
                // its own slice).
                for (w, t) in transports.iter_mut().enumerate() {
                    for (u, s) in slices.iter().enumerate() {
                        if u != w && !s.is_empty() {
                            t.send(&Msg::ShardParamSlice {
                                seq,
                                slice: u as u32,
                                offset: part[u].start as u64,
                                params: s.clone(),
                            })?;
                        }
                    }
                }
                // Step barrier + metrics; the empty gradient tells workers
                // the update already applied slice-wise. The moment triple
                // carries the sigma stats the workers can no longer derive
                // (they never see the assembled gradient on this plane).
                let (sigma_norm, sigma_norm2, grad_l2) = normalized_grad_stats(&grad);
                let fin = Msg::ShardGradFin {
                    seq,
                    loss,
                    acc,
                    sigma_norm,
                    sigma_norm2,
                    grad_l2,
                    grad: Vec::new(),
                };
                for t in transports.iter_mut() {
                    t.send(&fin)?;
                }
            } else {
                // Replica ring: the whole accumulator visits workers in id
                // order, then the reduced gradient broadcasts for the
                // full-replica optimizer apply.
                let mut grad = vec![0.0f32; pc];
                for (w, t) in transports.iter_mut().enumerate() {
                    t.send(&Msg::ShardGradSeed { seq, grad })?;
                    grad = match t.recv()? {
                        Msg::ShardGradOut { seq: rs, grad } => {
                            anyhow::ensure!(rs == seq, "worker {w}: GradOut seq {rs} != {seq}");
                            grad
                        }
                        other => anyhow::bail!("worker {w}: expected ShardGradOut, got {other:?}"),
                    };
                }
                let (sigma_norm, sigma_norm2, grad_l2) = normalized_grad_stats(&grad);
                if let Some(mir) = mirror.as_mut() {
                    // The identical update every full replica applies.
                    match cfg.train.optimizer {
                        Optimizer::Sgd => apply_sgd(&Pool::sequential(), mir, &grad, cfg.train.lr),
                        Optimizer::Adam => apply_adam(&Pool::sequential(), mir, &grad, cfg.train.lr),
                    }
                }
                let fin = Msg::ShardGradFin {
                    seq,
                    loss,
                    acc,
                    sigma_norm,
                    sigma_norm2,
                    grad_l2,
                    grad,
                };
                for t in transports.iter_mut() {
                    t.send(&fin)?;
                }
            }
        }

        // --- control plane: states up, actions down (BSP barrier) ---
        let mut states = Vec::with_capacity(transports.len());
        let mut rewards = Vec::with_capacity(transports.len());
        let mut clock = 0.0f64;
        for t in transports.iter_mut() {
            match t.recv()? {
                Msg::StateReport { state, reward, sim_clock, .. } => {
                    states.push(state);
                    rewards.push(reward);
                    clock = clock.max(sim_clock);
                }
                other => anyhow::bail!("expected StateReport, got {other:?}"),
            }
        }
        let samples = agent.act(&states, false)?;
        for (w, t) in transports.iter_mut().enumerate() {
            let new_batch = rule.apply(batches[w], samples[w].action, None);
            let delta = new_batch as i32 - batches[w] as i32;
            batches[w] = new_batch;
            t.send(&Msg::Action {
                worker: worker_ids[w],
                cycle,
                delta,
                new_batch: new_batch as u32,
            })?;
        }
        let mean_r: f64 = rewards.iter().sum::<f64>() / rewards.len().max(1) as f64;
        let (bm, bs) = mean_std_usize(&batches);
        record.push(TracePoint {
            iter: (cycle as usize + 1) * cfg.rl.k,
            sim_time: clock,
            train_acc: last_acc,
            eval_acc: 0.0, // no held-out eval in the deployed demo
            loss: last_loss,
            batch_mean: bm,
            batch_std: bs,
            global_batch: batches.iter().sum(),
        });
        println!(
            "[leader] cycle {cycle}: loss={last_loss:.3} acc={last_acc:.3} \
             mean_reward={mean_r:+.3} batches={batches:?}"
        );
        if let Some(j) = &journal {
            j.cycle(
                cycle as usize,
                clock,
                (cycle as usize + 1) * cfg.rl.k,
                batches.iter().sum(),
                0.0, // no held-out eval in the deployed demo
            )?;
        }
        if let (Some(dir), Some(mir)) = (&ckpt_dir, &mirror) {
            if (cycle as usize + 1) % ckpt_every == 0 {
                let image = LeaderCkpt {
                    header: ckpt_header.clone(),
                    cycle: cycle as usize + 1,
                    opt: mir.clone(),
                    batches: batches.iter().map(|&b| b as u64).collect(),
                };
                let path = image.save_atomic(dir)?;
                // Retention GC after the successful write: the newest
                // image always survives; failures warn and never abort
                // the serving loop.
                if let Some(keep) = ckpt_keep {
                    LeaderCkpt::prune(dir, keep);
                }
                if let Some(j) = &journal {
                    j.checkpoint(cycle as usize + 1, clock)?;
                }
                println!("[leader] checkpoint -> {}", path.display());
            }
        }
    }
    // Workers idle at the next ShardStep recv; Shutdown lands there
    // (Algorithm 1 line 33).
    for t in transports.iter_mut() {
        t.send(&Msg::Shutdown)?;
    }
    record.extra.insert(
        "data_plane".into(),
        crate::jobj! {
            "mode" => "tcp",
            "shard_count" => n_workers,
            "reduction" => if zero { "reduce-scatter" } else { "chained-ring" },
            "wire" => wire_mode.label(),
            "proto_version" => crate::comm::PROTO_VERSION as usize,
        },
    );
    record.extra.insert("final_train_acc".into(), Json::Num(last_acc));
    let path = crate::harness::runs_dir()
        .join("distributed")
        .join(format!("{}.json", record.name));
    record.save_json(&path)?;
    println!("[leader] done; run record -> {}", path.display());
    Ok(())
}

/// Run one worker: connect, register, serve the shard data plane (sample
/// rows, forward, fold the traveling gradient, apply the reduced update to
/// the local replica), report window state every k iterations, apply
/// actions, exit on Shutdown.
pub fn worker(addr: &str, preset: &str, scale: Scale, worker_id: u32) -> anyhow::Result<()> {
    let cfg = presets::scaled(presets::by_name(preset)?, scale);
    let native = NativeBackend::new();
    let info = native.schema().model(&cfg.train.model)?.clone();
    let fd = info.feature_dim;
    let dataset = crate::data::by_name(&info.dataset, fd, cfg.train.seed)?;
    let zero = zero_plane();
    let wire_mode = crate::config::env::wire_mode().unwrap_or(WireMode::Dense);
    // Parameter replica: the same seeded init on every worker. Replica
    // plane: identical ShardGradFin updates keep replicas bit-identical,
    // with full-vector optimizer state. Zero plane: this worker holds
    // optimizer state for ONLY its owned slice (allocated after Welcome,
    // O(P/N) floats) and replicas stay identical through the
    // scatter/all-gather of updated parameter slices.
    let init = native.init_params(&cfg.train.model, cfg.train.seed)?;
    let mut state = if zero {
        OptState { params: init, m: Vec::new(), v: Vec::new(), step: 0.0 }
    } else {
        OptState::new(init, cfg.train.optimizer)
    };
    let lr = cfg.train.lr;

    let mut t = TcpTransport::new(TcpStream::connect(addr)?)?;
    t.send(&Msg::Register {
        worker: worker_id,
        max_batch: cfg.batch.max as u32,
    })?;
    // The LEADER's deployment sizes win over the local preset (demo/smoke
    // runs shrink both): data shards over the real worker count, progress
    // over the real cycle budget.
    let (k, mut batch, n_workers, cycles) = match t.recv()? {
        Msg::Welcome { k, initial_batch, n_workers, cycles, .. } => (
            k as usize,
            initial_batch as usize,
            (n_workers as usize).max(1),
            (cycles as usize).max(1),
        ),
        other => anyhow::bail!("expected Welcome, got {other:?}"),
    };
    let mut sampler = crate::data::ShardSampler::new(
        worker_id as usize % n_workers,
        n_workers,
        dataset.train_size,
        cfg.train.seed,
    );
    // Zero plane: the owned parameter slice (same layout arithmetic as
    // the leader — `param_partition` is derived, never transmitted) and
    // its slice-local optimizer state.
    let rank = worker_id as usize % n_workers;
    let my = if zero {
        native.param_partition(&cfg.train.model, &vec![true; n_workers], ZERO_BUCKET_BYTES)?[rank]
            .clone()
    } else {
        0..0
    };
    let mut slice_m = vec![0.0f32; my.len()];
    let mut slice_v = vec![
        0.0f32;
        match cfg.train.optimizer {
            Optimizer::Adam => my.len(),
            Optimizer::Sgd => 0,
        }
    ];
    let mut slice_step = 0.0f32;

    let builder = StateBuilder::default();
    let reward = RewardParams::default();
    let mut window = WindowAggregator::default();
    let mut idx = Vec::new();
    let mut held: Option<(u64, ShardCtx)> = None;
    let (mut my_rows, mut my_correct) = (0usize, 0.0f64);
    let mut iters_in_cycle = 0usize;
    let mut cycle = 0u32;
    let mut t_step = Instant::now();
    let t_start = Instant::now();
    'outer: loop {
        match t.recv()? {
            Msg::ShardStep { seq, denom, .. } => {
                t_step = Instant::now();
                sampler.next_indices(batch, &mut idx);
                let mut xs = vec![0.0f32; batch * fd];
                let mut ys = vec![0i32; batch];
                for (r, &i) in idx.iter().enumerate() {
                    ys[r] = dataset.sample_into(i, &mut xs[r * fd..(r + 1) * fd]);
                }
                let mask = vec![1.0f32; batch];
                let (ctx, fwd) =
                    native.shard_forward(&cfg.train.model, &state.params, xs, &ys, &mask, denom)?;
                my_rows = batch;
                my_correct = fwd.correct.iter().map(|&c| c as f64).sum();
                held = Some((seq, ctx));
                t.send(&Msg::ShardFwd {
                    seq,
                    loss_terms: fwd.loss_terms,
                    correct: fwd.correct,
                })?;
            }
            Msg::ShardGradSeed { seq, mut grad } => {
                let (held_seq, ctx) = held
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("GradSeed without an in-flight step"))?;
                anyhow::ensure!(held_seq == seq, "GradSeed seq {seq} != {held_seq}");
                native.shard_backward_acc(&state.params, ctx, &mut grad)?;
                t.send(&Msg::ShardGradOut { seq, grad })?;
            }
            // Zero-plane ring leg: a traveling gradient window lands while
            // a step is in flight — decode, fold this shard's rows in at
            // the cursor, re-encode the reply in the SAME wire mode.
            m @ (Msg::ShardGradSlice { .. }
            | Msg::ShardGradTopK { .. }
            | Msg::ShardGradQ8 { .. })
                if held.is_some() =>
            {
                let (seq, slice, offset, dense) = decode_slice_msg(m)?;
                let (held_seq, ctx) = held.as_mut().expect("guarded by held.is_some()");
                anyhow::ensure!(*held_seq == seq, "slice {slice} seq {seq} != {held_seq}");
                let mut out = Vec::with_capacity(dense.len());
                native.shard_backward_bucket(&state.params, ctx, offset, &dense, &mut out)?;
                t.send(&encode_slice_msg(wire_mode, seq, slice, offset, out))?;
                if native.shard_backward_done(&held.as_ref().expect("still held").1)? {
                    let (_, ctx) = held.take().expect("checked above");
                    native.shard_finish(ctx)?;
                }
            }
            // Zero-plane scatter leg (no step in flight): the reduced
            // OWNED slice — apply the optimizer with the slice-local
            // state and hand the updated params back for the all-gather.
            Msg::ShardGradSlice { seq, slice, offset, grad } => {
                anyhow::ensure!(
                    zero
                        && slice as usize == rank
                        && offset as usize == my.start
                        && grad.len() == my.len(),
                    "unexpected reduced slice (slice {slice}, [{offset}, +{})) — own \
                     [{}, {}) on the {} plane",
                    grad.len(),
                    my.start,
                    my.end,
                    if zero { "zero" } else { "replica" }
                );
                slice_step += 1.0;
                match cfg.train.optimizer {
                    Optimizer::Sgd => apply_sgd_slice(
                        native.pool(),
                        &mut state.params[my.clone()],
                        &mut slice_m,
                        &grad,
                        lr,
                    ),
                    Optimizer::Adam => {
                        // PARITY: one bias correction per step, computed
                        // from the slice-local counter every owner bumps
                        // exactly once per iteration.
                        let step_t = slice_step as f64;
                        apply_adam_slice(
                            native.pool(),
                            &mut state.params[my.clone()],
                            &mut slice_m,
                            &mut slice_v,
                            &grad,
                            lr,
                            step_t,
                        );
                    }
                }
                t.send(&Msg::ShardParamSlice {
                    seq,
                    slice,
                    offset,
                    params: state.params[my.clone()].to_vec(),
                })?;
            }
            // Zero-plane all-gather leg: another owner's updated slice
            // lands in this replica.
            Msg::ShardParamSlice { offset, params, .. } => {
                let off = offset as usize;
                anyhow::ensure!(
                    off + params.len() <= state.params.len(),
                    "param slice [{off}, +{}) overruns the replica ({} params)",
                    params.len(),
                    state.params.len()
                );
                state.params[off..off + params.len()].copy_from_slice(&params);
            }
            Msg::ShardGradFin { loss, sigma_norm, sigma_norm2, grad, .. } => {
                // An empty gradient is the zero plane's step barrier: the
                // update already applied slice-wise. Either way the
                // leader-computed moment triple (v5) feeds the sigma-stat
                // RL features — workers never derive them locally, so the
                // zero plane's features match the replica plane's for the
                // same reduced gradient (the blackout fix).
                if !grad.is_empty() {
                    anyhow::ensure!(
                        !zero,
                        "full-gradient ShardGradFin on the zero plane — leader and worker \
                         disagree on DYNAMIX_PLANE"
                    );
                    match cfg.train.optimizer {
                        Optimizer::Sgd => apply_sgd(native.pool(), &mut state, &grad, lr),
                        Optimizer::Adam => apply_adam(native.pool(), &mut state, &grad, lr),
                    }
                }
                window.push_iteration(
                    my_correct / my_rows.max(1) as f64,
                    loss as f64,
                    t_step.elapsed().as_secs_f64(),
                    0.0, // single-host demo: no fabric measurement
                    0,
                    SysSample { cpu_time_ratio: 1.0, mem_util: 0.2 },
                    sigma_norm as f64,
                    sigma_norm2 as f64,
                );
                iters_in_cycle += 1;
                if iters_in_cycle == k {
                    iters_in_cycle = 0;
                    let summary = window.finish();
                    let global = GlobalState {
                        loss: summary.loss_mean,
                        eval_acc: summary.acc_mean,
                        eval_trend: 0.0,
                        progress: cycle as f64 / cycles as f64,
                        n_workers,
                    };
                    let sv = builder.build(&summary, batch, &global);
                    let r = reward.compute(&summary, batch);
                    t.send(&Msg::StateReport {
                        worker: worker_id,
                        cycle,
                        state: sv,
                        reward: r,
                        sim_clock: t_start.elapsed().as_secs_f64(),
                    })?;
                    match t.recv()? {
                        Msg::Action { new_batch, .. } => {
                            batch = new_batch as usize;
                        }
                        Msg::Shutdown => break 'outer,
                        other => anyhow::bail!("expected Action/Shutdown, got {other:?}"),
                    }
                    cycle += 1;
                }
            }
            Msg::Shutdown => break 'outer,
            other => anyhow::bail!("worker: unexpected {other:?}"),
        }
    }
    println!("[worker {worker_id}] shut down cleanly after {cycle} cycles");
    Ok(())
}
