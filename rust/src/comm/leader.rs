//! Distributed leader/worker deployment over the TCP protocol.
//!
//! This is the paper's Fig. 1 deployed across real processes: each worker
//! process owns a PJRT runtime and trains a model replica on its shard for
//! `k` iterations per cycle, measures its own (real) iteration times and
//! training statistics, and reports its state vector to the leader; the
//! leader runs the PPO arbitrator and pushes batch-size actions back.
//! Algorithm 1's lifecycle (register -> welcome -> state/action cycles ->
//! shutdown) maps 1:1 onto `comm::Msg`.
//!
//! Demo-mode caveat (documented in DESIGN.md): workers run *local* SGD on
//! their own replicas — the gradient all-reduce data plane is exercised by
//! the simulator path (`trainer::BspTrainer`), which is mathematically
//! exact; this mode exercises the coordination plane (real sockets, real
//! per-process PJRT compute, real latencies for the §VI-H overhead story).

use crate::comm::{Msg, TcpTransport, Transport};
use crate::config::{presets, Scale};
use crate::rl::action::BatchRule;
use crate::rl::agent::PpoAgent;
use crate::rl::reward::RewardParams;
use crate::rl::state::{GlobalState, StateBuilder};
use crate::runtime::default_backend;
use crate::sysmetrics::{SysSample, WindowAggregator};
use crate::trainer::ModelRuntime;
use std::net::{TcpListener, TcpStream};

/// Run the leader: accept the preset's worker count, drive
/// `steps_per_episode` decision cycles, broadcast shutdown.
pub fn serve(bind: &str, preset: &str, scale: Scale) -> anyhow::Result<()> {
    let cfg = presets::scaled(presets::by_name(preset)?, scale);
    let (n, cycles) = (cfg.cluster.n_workers, cfg.steps_per_episode);
    serve_n(bind, preset, scale, n, cycles)
}

/// [`serve`] with explicit worker count + cycle budget (demo/test sizes).
pub fn serve_n(
    bind: &str,
    preset: &str,
    scale: Scale,
    n_workers: usize,
    cycles: usize,
) -> anyhow::Result<()> {
    let mut cfg = presets::scaled(presets::by_name(preset)?, scale);
    cfg.cluster.n_workers = n_workers;
    cfg.steps_per_episode = cycles;
    let backend = default_backend()?;
    let mut agent = PpoAgent::new(backend, cfg.rl.clone(), cfg.train.seed)?;
    let rule = BatchRule {
        min: cfg.batch.min,
        max: cfg.batch.max,
    };

    let listener = TcpListener::bind(bind)?;
    println!("[leader] listening on {bind}; waiting for {} workers", cfg.cluster.n_workers);
    let mut transports: Vec<TcpTransport> = Vec::new();
    let mut batches: Vec<usize> = Vec::new();
    while transports.len() < cfg.cluster.n_workers {
        let (stream, peer) = listener.accept()?;
        let mut t = TcpTransport::new(stream)?;
        match t.recv()? {
            Msg::Register { worker, max_batch } => {
                println!("[leader] worker {worker} registered from {peer} (max_batch={max_batch})");
                t.send(&Msg::Welcome {
                    worker,
                    k: cfg.rl.k as u32,
                    initial_batch: cfg.batch.initial as u32,
                })?;
                transports.push(t);
                batches.push(cfg.batch.initial.min(max_batch as usize));
            }
            other => anyhow::bail!("expected Register, got {other:?}"),
        }
    }

    for cycle in 0..cfg.steps_per_episode as u32 {
        // Collect one StateReport per worker (BSP-style barrier).
        let mut states = Vec::with_capacity(transports.len());
        let mut rewards = Vec::with_capacity(transports.len());
        for t in transports.iter_mut() {
            match t.recv()? {
                Msg::StateReport { state, reward, .. } => {
                    states.push(state);
                    rewards.push(reward);
                }
                other => anyhow::bail!("expected StateReport, got {other:?}"),
            }
        }
        let samples = agent.act(&states, false)?;
        for (w, t) in transports.iter_mut().enumerate() {
            let new_batch = rule.apply(batches[w], samples[w].action, None);
            let delta = new_batch as i32 - batches[w] as i32;
            batches[w] = new_batch;
            t.send(&Msg::Action {
                worker: w as u32,
                cycle,
                delta,
                new_batch: new_batch as u32,
            })?;
        }
        let mean_r: f64 = rewards.iter().sum::<f64>() / rewards.len().max(1) as f64;
        println!(
            "[leader] cycle {cycle}: mean_reward={mean_r:+.3} batches={batches:?}"
        );
    }
    // Drain the final pipelined report from each worker, then shut down —
    // avoids a send-after-close race on the worker side (Algorithm 1 l.33).
    for t in transports.iter_mut() {
        let _ = t.recv()?;
        t.send(&Msg::Shutdown)?;
    }
    println!("[leader] done");
    Ok(())
}

/// Run one worker: connect, register, train k real iterations per cycle on
/// a local replica, report state, apply actions, exit on Shutdown.
pub fn worker(addr: &str, preset: &str, scale: Scale, worker_id: u32) -> anyhow::Result<()> {
    let cfg = presets::scaled(presets::by_name(preset)?, scale);
    let backend = default_backend()?;
    let info = backend.schema().model(&cfg.train.model)?.clone();
    let dataset = crate::data::by_name(&info.dataset, info.feature_dim, cfg.train.seed)?;
    let mut sampler = crate::data::ShardSampler::new(
        worker_id as usize % cfg.cluster.n_workers,
        cfg.cluster.n_workers,
        dataset.train_size,
        cfg.train.seed,
    );
    let mut runtime = ModelRuntime::new(
        backend.clone(),
        &cfg.train.model,
        cfg.train.optimizer,
        cfg.train.lr,
        cfg.train.seed,
    )?;

    let mut t = TcpTransport::new(TcpStream::connect(addr)?)?;
    t.send(&Msg::Register {
        worker: worker_id,
        max_batch: cfg.batch.max as u32,
    })?;
    let (k, mut batch) = match t.recv()? {
        Msg::Welcome { k, initial_batch, .. } => (k as usize, initial_batch as usize),
        other => anyhow::bail!("expected Welcome, got {other:?}"),
    };

    let builder = StateBuilder::default();
    let reward = RewardParams::default();
    let mut window = WindowAggregator::default();
    let mut idx = Vec::new();
    let mut cycle = 0u32;
    let t_start = std::time::Instant::now();
    loop {
        // k real local training iterations at the current batch size.
        for _ in 0..k {
            let bucket = backend.schema().bucket_for(batch)?;
            let mut xs = vec![0.0f32; bucket * info.feature_dim];
            let mut ys = vec![0i32; bucket];
            sampler.next_indices(batch, &mut idx);
            for (r, &i) in idx.iter().enumerate() {
                ys[r] = dataset
                    .sample_into(i, &mut xs[r * info.feature_dim..(r + 1) * info.feature_dim]);
            }
            let m = runtime.train_step(&xs, &ys, batch, bucket)?;
            window.push_iteration(
                m.acc,
                m.loss,
                m.exec_seconds,
                0.0, // no fabric in single-host demo mode
                0,
                SysSample { cpu_time_ratio: 1.0, mem_util: 0.2 },
                m.sigma_norm,
                m.sigma_norm2,
            );
        }
        let summary = window.finish();
        let global = GlobalState {
            loss: summary.loss_mean,
            eval_acc: summary.acc_mean,
            eval_trend: 0.0,
            progress: cycle as f64 / cfg.steps_per_episode as f64,
            n_workers: cfg.cluster.n_workers,
        };
        let state = builder.build(&summary, batch, &global);
        let r = reward.compute(&summary, batch);
        t.send(&Msg::StateReport {
            worker: worker_id,
            cycle,
            state,
            reward: r,
            sim_clock: t_start.elapsed().as_secs_f64(),
        })?;
        match t.recv()? {
            Msg::Action { new_batch, .. } => {
                batch = new_batch as usize;
            }
            Msg::Shutdown => break,
            other => anyhow::bail!("expected Action/Shutdown, got {other:?}"),
        }
        cycle += 1;
    }
    println!("[worker {worker_id}] shut down cleanly after {cycle} cycles");
    Ok(())
}
