//! Little-endian binary encoder/decoder with length-prefixed framing.

/// Append-only encoder; `frame()` prepends the u32 length.
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Encoder { buf: Vec::with_capacity(64) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// `[count:u32][count x f32 LE]` — the shard data plane's tensor slabs.
    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// `[count:u32][count x i32 LE]`.
    pub fn i32s(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// UTF-8 string as length-prefixed bytes.
    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }

    /// Finish: [len:u32][body].
    pub fn frame(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.buf.len() + 4);
        out.extend_from_slice(&(self.buf.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.buf);
        out
    }
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

/// Bounds-checked decoder over a frame body.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Decoder { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.buf.len(), "frame underrun");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> anyhow::Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn i32(&mut self) -> anyhow::Result<i32> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> anyhow::Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn bytes(&mut self) -> anyhow::Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Counterpart of [`Encoder::f32s`]. The byte slab is bounds-checked
    /// BEFORE any allocation, so a forged count cannot force a huge alloc.
    pub fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| anyhow::anyhow!("f32 array length overflow"))?,
        )?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Counterpart of [`Encoder::i32s`].
    pub fn i32s(&mut self) -> anyhow::Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| anyhow::anyhow!("i32 array length overflow"))?,
        )?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Counterpart of [`Encoder::str`].
    pub fn str(&mut self) -> anyhow::Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b)
            .map_err(|_| anyhow::anyhow!("invalid utf-8 in wire string"))?
            .to_string())
    }

    /// Assert the frame was fully consumed.
    pub fn finish(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.pos == self.buf.len(), "trailing bytes in frame");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_primitives() {
        let mut e = Encoder::new();
        e.u8(7);
        e.u16(300);
        e.u32(70_000);
        e.i32(-5);
        e.u64(1 << 40);
        e.f64(-2.5);
        e.bytes(b"hello");
        let frame = e.frame();
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        let mut d = Decoder::new(&frame[4..4 + len]);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 300);
        assert_eq!(d.u32().unwrap(), 70_000);
        assert_eq!(d.i32().unwrap(), -5);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f64().unwrap(), -2.5);
        assert_eq!(d.bytes().unwrap(), b"hello");
        d.finish().unwrap();
    }

    #[test]
    fn roundtrip_arrays_and_strings() {
        let mut e = Encoder::new();
        e.f32(1.5);
        e.f32s(&[0.25, -3.0, f32::MIN_POSITIVE]);
        e.i32s(&[-7, 0, i32::MAX]);
        e.str("vgg11_mini");
        e.f32s(&[]);
        let frame = e.frame();
        let mut d = Decoder::new(&frame[4..]);
        assert_eq!(d.f32().unwrap(), 1.5);
        assert_eq!(d.f32s().unwrap(), vec![0.25, -3.0, f32::MIN_POSITIVE]);
        assert_eq!(d.i32s().unwrap(), vec![-7, 0, i32::MAX]);
        assert_eq!(d.str().unwrap(), "vgg11_mini");
        assert_eq!(d.f32s().unwrap(), Vec::<f32>::new());
        d.finish().unwrap();
    }

    #[test]
    fn forged_array_count_errors_without_allocating() {
        // Count claims u32::MAX elements with a 4-byte body: the decoder
        // must bounds-check before allocating anything.
        let mut e = Encoder::new();
        e.u32(u32::MAX);
        e.u32(0);
        let frame = e.frame();
        let mut d = Decoder::new(&frame[4..]);
        assert!(d.f32s().is_err());
        let mut d = Decoder::new(&frame[4..]);
        assert!(d.i32s().is_err());
    }

    #[test]
    fn non_utf8_string_rejected() {
        let mut e = Encoder::new();
        e.bytes(&[0xFF, 0xFE, 0x80]);
        let frame = e.frame();
        let mut d = Decoder::new(&frame[4..]);
        assert!(d.str().is_err());
    }

    #[test]
    fn underrun_detected() {
        let mut d = Decoder::new(&[1, 2]);
        assert!(d.u32().is_err());
    }

    #[test]
    fn trailing_detected() {
        let d = Decoder::new(&[1]);
        assert!(d.finish().is_err());
    }
}
