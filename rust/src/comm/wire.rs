//! Little-endian binary encoder/decoder with length-prefixed framing,
//! plus the gradient-slice payload codecs (`topk`/`q8`).
//!
//! Codec hot-path discipline: every codec has an `_into` variant over
//! caller buffers (the ring touches these once per hop — the owned-`Vec`
//! wrappers exist for tests and one-off callers), the top-k encode is an
//! O(n) partial select rather than a full sort, and the q8 encode/decode
//! carry AVX2 lanes dispatched on the process-wide resolved kernel tier
//! ([`global_tier`] — no env re-reads here). Tier never changes bytes:
//! the SIMD lanes reproduce the scalar rounding sequence exactly, so the
//! PR 8 run-to-run determinism pins hold on every tier.

use crate::runtime::native::exec::{global_tier, KernelTier};

/// Append-only encoder; `frame()` prepends the u32 length.
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Encoder { buf: Vec::with_capacity(64) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// `[count:u32][count x f32 LE]` — the shard data plane's tensor slabs.
    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// `[count:u32][count x i32 LE]`.
    pub fn i32s(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// `[count:u32][count x u32 LE]` — top-k index lists.
    pub fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// UTF-8 string as length-prefixed bytes.
    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }

    /// Finish: [len:u32][body].
    pub fn frame(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.buf.len() + 4);
        out.extend_from_slice(&(self.buf.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.buf);
        out
    }
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

/// Bounds-checked decoder over a frame body.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Decoder { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.buf.len(), "frame underrun");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> anyhow::Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn i32(&mut self) -> anyhow::Result<i32> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> anyhow::Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn bytes(&mut self) -> anyhow::Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    /// Counterpart of [`Encoder::f32s`]. The byte slab is bounds-checked
    /// BEFORE any allocation, so a forged count cannot force a huge alloc.
    pub fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| anyhow::anyhow!("f32 array length overflow"))?,
        )?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Counterpart of [`Encoder::i32s`].
    pub fn i32s(&mut self) -> anyhow::Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| anyhow::anyhow!("i32 array length overflow"))?,
        )?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Counterpart of [`Encoder::u32s`]. Bounds-checked before any
    /// allocation, like [`Decoder::f32s`].
    pub fn u32s(&mut self) -> anyhow::Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| anyhow::anyhow!("u32 array length overflow"))?,
        )?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Counterpart of [`Encoder::str`].
    pub fn str(&mut self) -> anyhow::Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b)
            .map_err(|_| anyhow::anyhow!("invalid utf-8 in wire string"))?
            .to_string())
    }

    /// Assert the frame was fully consumed.
    pub fn finish(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.pos == self.buf.len(), "trailing bytes in frame");
        Ok(())
    }
}

/// Gradient-slice payload codec (`DYNAMIX_WIRE`): how a traveling
/// window's floats are packed into a v4 hop frame.
///
/// The contract is **determinism vs parity**: `Dense` is bit-parity
/// with the fused native fold; `TopK`/`Q8` are lossy vs dense, but
/// every encode/decode here is a pure function of the input bits, so
/// two runs with the same seeds produce identical bytes and identical
/// training trajectories (`tests/zero_parity.rs` pins this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// Full f32 window — bit-parity with the fused native backward.
    Dense,
    /// Deterministic top-k sparsification: keep `ceil(len/4)` largest-
    /// magnitude elements (stable index order), half the dense bytes.
    TopK,
    /// Symmetric int8 quantization with a per-window power-of-two f32
    /// scale — about a quarter of the dense bytes.
    Q8,
}

impl WireMode {
    /// Parse a `DYNAMIX_WIRE` / config / CLI value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "dense" => Ok(WireMode::Dense),
            "topk" => Ok(WireMode::TopK),
            "q8" => Ok(WireMode::Q8),
            other => anyhow::bail!("unknown wire mode {other:?} (dense|topk|q8)"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            WireMode::Dense => "dense",
            WireMode::TopK => "topk",
            WireMode::Q8 => "q8",
        }
    }

    /// Modeled payload bytes for one `n`-float dense window under this
    /// codec (framing/headers excluded — the accounting compares codecs,
    /// not transports): Dense `4n`; TopK `8·ceil(n/4)` (u32 index + f32
    /// value per kept element); Q8 `n + 4` (one i8 per element plus the
    /// f32 scale).
    pub fn payload_bytes(self, n: usize) -> usize {
        match self {
            WireMode::Dense => 4 * n,
            WireMode::TopK => 8 * topk_k(n),
            WireMode::Q8 => n + 4,
        }
    }
}

/// Dense-to-kept sparsification ratio of [`WireMode::TopK`].
pub const TOPK_RATIO: usize = 4;

/// Kept elements for a `len`-float window under top-k.
pub fn topk_k(len: usize) -> usize {
    len.div_ceil(TOPK_RATIO)
}

/// Deterministic top-k selection: order every index by (|value| desc,
/// index asc) using the total order on |v|'s BITS — ties and non-finite
/// values included, the comparison never consults platform float
/// semantics — keep the first `topk_k(len)`, and emit them in strictly
/// increasing index order. Pure function of the input bits.
/// Owned-buffer wrapper over [`topk_encode_into`].
pub fn topk_encode(x: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let (mut order, mut idx, mut val) = (Vec::new(), Vec::new(), Vec::new());
    topk_encode_into(x, &mut order, &mut idx, &mut val);
    (idx, val)
}

/// Allocation-free top-k encode into caller buffers (`order` is index
/// scratch whose capacity persists across hops). O(n + k log k): a
/// quickselect partition on the (|v| bits desc, index asc) key replaces
/// the historical full sort. The key is a duplicate-free total order —
/// every index appears exactly once — so the k-element prefix after the
/// partition is EXACTLY the set the full sort would keep, magnitude
/// ties resolved by index and all; `tests/codec_parity.rs` pins
/// bit-identity against the sort-based reference on adversarial ties.
pub fn topk_encode_into(
    x: &[f32],
    order: &mut Vec<u32>,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
) {
    let k = topk_k(x.len());
    idx.clear();
    val.clear();
    if k == 0 {
        return;
    }
    order.clear();
    order.extend(0..x.len() as u32);
    if k < order.len() {
        // PARITY: the partition key (|v| bits desc, idx asc) is duplicate-
        // free, so the selected prefix is identical to the full-sort
        // reference — ties never consult unstable comparison order.
        order.select_nth_unstable_by_key(k - 1, |&i| {
            (std::cmp::Reverse(x[i as usize].abs().to_bits()), i)
        });
    }
    idx.extend_from_slice(&order[..k]);
    idx.sort_unstable();
    val.extend(idx.iter().map(|&i| x[i as usize]));
}

/// Rebuild the dense window: selected indices get their values, the
/// rest exact zeros. Validates the *declared* dense length against
/// [`crate::comm::MAX_FRAME`] BEFORE allocating — a hostile/corrupt
/// length prefix cannot reserve a huge buffer — plus index bounds,
/// strict monotonicity, and the `topk_k` count contract. Both the v4
/// frame decoder and the shard fold path call this, so loopback and TCP
/// validate identically. Owned-buffer wrapper over [`topk_decode_into`].
pub fn topk_decode(len: usize, idx: &[u32], val: &[f32]) -> anyhow::Result<Vec<f32>> {
    let mut out = Vec::new();
    topk_decode_into(len, idx, val, &mut out)?;
    Ok(out)
}

/// Allocation-free top-k decode: clears and fills `out` (capacity
/// persists across hops — steady-state ring traffic allocates nothing).
/// Same validation contract as [`topk_decode`].
pub fn topk_decode_into(
    len: usize,
    idx: &[u32],
    val: &[f32],
    out: &mut Vec<f32>,
) -> anyhow::Result<()> {
    topk_validate(len, idx, val)?;
    out.clear();
    out.resize(len, 0.0);
    for (&i, &v) in idx.iter().zip(val) {
        out[i as usize] = v;
    }
    Ok(())
}

/// The top-k frame invariants, checkable without allocating: declared
/// dense length under the frame ceiling, `topk_k` count contract,
/// indices strictly increasing and in range. `Msg::decode` runs this at
/// the protocol boundary so a hostile frame is rejected before any
/// dense-buffer allocation anywhere downstream.
pub fn topk_validate(len: usize, idx: &[u32], val: &[f32]) -> anyhow::Result<()> {
    anyhow::ensure!(
        len.checked_mul(4).map_or(false, |b| b <= crate::comm::MAX_FRAME),
        "topk dense length {len} exceeds the frame ceiling"
    );
    anyhow::ensure!(
        idx.len() == val.len() && idx.len() == topk_k(len),
        "topk count mismatch: {} idx / {} val, want {} for len {len}",
        idx.len(),
        val.len(),
        topk_k(len)
    );
    let mut prev: Option<u32> = None;
    for &i in idx {
        anyhow::ensure!((i as usize) < len, "topk index {i} out of range {len}");
        anyhow::ensure!(
            prev.map_or(true, |p| i > p),
            "topk indices must be strictly increasing"
        );
        prev = Some(i);
    }
    Ok(())
}

/// Symmetric int8 quantization with a power-of-two scale.
///
/// `scale = 2^(e-6)` where `e` is the unbiased exponent of the window's
/// max |value|, so `q = round(x/scale)` lands in `(-128, 128)` before
/// the clamp to ±127, and `q·scale` is an EXACT f32 product (power-of-
/// two multiply). Exactness buys byte-stability: the decoded window's
/// max |value| is `q_max·scale` with `q_max ∈ [64, 127]`, which keeps
/// exponent `e`, so re-encoding recovers the identical scale and the
/// identical bytes (`proptest_invariants` pins encode∘decode∘encode).
/// Windows whose max |value| is zero, subnormal-tiny (`e < -120`), or
/// non-finite flush to the all-zero frame with scale 0 — deterministic
/// in, deterministic out.
/// Owned-buffer wrapper over [`q8_encode_into`].
pub fn q8_encode(x: &[f32]) -> (f32, Vec<i8>) {
    let mut q = Vec::new();
    let scale = q8_encode_into(x, &mut q);
    (scale, q)
}

/// Allocation-free q8 encode into a caller buffer (capacity persists
/// across hops): clears and fills `q`, returns the scale. Dispatches
/// the abs-max scan and the quantize loop to AVX2 lanes on the `simd`
/// tier — byte-identical to the scalar path (see [`q8_quantize`]).
pub fn q8_encode_into(x: &[f32], q: &mut Vec<i8>) -> f32 {
    q.clear();
    q.resize(x.len(), 0);
    let max_bits = q8_abs_max_bits(x);
    let e = ((max_bits >> 23) & 0xFF) as i32 - 127;
    if max_bits == 0 || !(-120..=127).contains(&e) {
        return 0.0;
    }
    let scale = f32::from_bits(((e - 6 + 127) as u32) << 23);
    q8_quantize(x, scale, q);
    scale
}

/// Max over the windows' |value| BITS (u32 compare — monotone with
/// magnitude, total on non-finite payloads, and order-free, so the SIMD
/// lane's lane-wise fold is exact).
fn q8_abs_max_bits(x: &[f32]) -> u32 {
    #[cfg(target_arch = "x86_64")]
    if global_tier() == KernelTier::Simd {
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        return unsafe { simd::abs_max_bits(x) };
    }
    x.iter().map(|v| v.abs().to_bits()).max().unwrap_or(0)
}

/// `q[i] = round(x[i]/scale)` clamped to ±127, rounding half away from
/// zero (`f32::round`). The SIMD lane reproduces this byte-for-byte:
/// the power-of-two divide is exact in every lane, and the half-to-even
/// `roundps` result is corrected on exact-tie lanes (detectable exactly,
/// since `t - round(t)` is computed without error) — so tier choice
/// never changes wire bytes.
fn q8_quantize(x: &[f32], scale: f32, q: &mut [i8]) {
    #[cfg(target_arch = "x86_64")]
    if global_tier() == KernelTier::Simd {
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        unsafe { simd::quantize(x, scale, q) };
        return;
    }
    for (qi, &v) in q.iter_mut().zip(x) {
        *qi = (v / scale).round().clamp(-127.0, 127.0) as i8;
    }
}

/// Exact dequantization: `q·scale` with a power-of-two scale is a bit-
/// exact f32 product. `scale` must be finite and non-negative (hostile
/// frames rejected); the element count needs no separate guard — it is
/// bounded by the received frame itself at one byte per element.
/// Owned-buffer wrapper over [`q8_decode_into`].
pub fn q8_decode(scale: f32, q: &[i8]) -> anyhow::Result<Vec<f32>> {
    let mut out = Vec::new();
    q8_decode_into(scale, q, &mut out)?;
    Ok(out)
}

/// Allocation-free q8 decode: clears and fills `out` (capacity persists
/// across hops). The SIMD lane performs the identical single `q·scale`
/// multiply per element, so bytes match the scalar path on any scale.
pub fn q8_decode_into(scale: f32, q: &[i8], out: &mut Vec<f32>) -> anyhow::Result<()> {
    anyhow::ensure!(
        scale.is_finite() && scale >= 0.0,
        "q8 scale must be finite and non-negative"
    );
    out.clear();
    out.resize(q.len(), 0.0);
    #[cfg(target_arch = "x86_64")]
    if global_tier() == KernelTier::Simd {
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        unsafe { simd::dequantize(scale, q, out) };
        return Ok(());
    }
    for (o, &qi) in out.iter_mut().zip(q) {
        *o = qi as f32 * scale;
    }
    Ok(())
}

/// AVX2 lanes for the q8 codec. Byte-stability discipline: every
/// operation is one correctly-rounded IEEE op (div/round/sub/add/
/// min/max/convert — no FMA, no approximations), so each lane computes
/// the exact scalar rounding sequence and the emitted bytes are
/// identical to the scalar codec on every input. The round-half-to-even
/// of `roundps` is corrected to `f32::round`'s half-away-from-zero on
/// exact ties (see `quantize`).
#[cfg(target_arch = "x86_64")]
mod simd {
    use core::arch::x86_64::*;

    /// SAFETY: unsafe solely because of `target_feature` — reached only
    /// through the `global_tier()` dispatch above, which holds `Simd`
    /// only when avx2+fma were detected at tier resolution.
    #[target_feature(enable = "avx2")]
    pub unsafe fn abs_max_bits(x: &[f32]) -> u32 {
        let sign_clear = _mm256_set1_epi32(0x7FFF_FFFF);
        let mut acc = _mm256_setzero_si256();
        let mut chunks = x.chunks_exact(8);
        for c in chunks.by_ref() {
            let v = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
            acc = _mm256_max_epu32(acc, _mm256_and_si256(v, sign_clear));
        }
        let mut lanes = [0u32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut m = lanes.into_iter().max().unwrap_or(0);
        for &v in chunks.remainder() {
            m = m.max(v.abs().to_bits());
        }
        m
    }

    /// SAFETY: same contract as `abs_max_bits` — tier-dispatch gated.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize(x: &[f32], scale: f32, q: &mut [i8]) {
        debug_assert_eq!(x.len(), q.len());
        let vscale = _mm256_set1_ps(scale);
        let sign = _mm256_set1_ps(-0.0);
        let half = _mm256_set1_ps(0.5);
        let one = _mm256_set1_ps(1.0);
        let lo = _mm256_set1_ps(-127.0);
        let hi = _mm256_set1_ps(127.0);
        let n8 = x.len() / 8 * 8;
        for i in (0..n8).step_by(8) {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            // Exact: the power-of-two divisor only shifts the exponent.
            let t = _mm256_div_ps(v, vscale);
            // Half-to-even round, then push exact .5 ties away from zero
            // to match scalar `f32::round`: `t - r` is exact (|t - r| <=
            // 0.5, Sterbenz), so a tie is exactly `copysign(0.5, t)`.
            let r = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(t);
            let ts = _mm256_and_ps(t, sign);
            let tie = _mm256_cmp_ps::<_CMP_EQ_OQ>(_mm256_sub_ps(t, r), _mm256_or_ps(half, ts));
            let fix = _mm256_and_ps(tie, _mm256_or_ps(one, ts));
            let r = _mm256_max_ps(lo, _mm256_min_ps(hi, _mm256_add_ps(r, fix)));
            let qi = _mm256_cvtps_epi32(r);
            let mut lanes = [0i32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, qi);
            for (j, &l) in lanes.iter().enumerate() {
                q[i + j] = l as i8;
            }
        }
        for i in n8..x.len() {
            q[i] = (x[i] / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }

    /// SAFETY: same contract as `abs_max_bits` — tier-dispatch gated.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize(scale: f32, q: &[i8], out: &mut [f32]) {
        debug_assert_eq!(q.len(), out.len());
        let vscale = _mm256_set1_ps(scale);
        let n8 = q.len() / 8 * 8;
        for i in (0..n8).step_by(8) {
            let b = _mm_loadl_epi64(q.as_ptr().add(i) as *const __m128i);
            let w = _mm256_cvtepi8_epi32(b);
            let f = _mm256_cvtepi32_ps(w);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(f, vscale));
        }
        for i in n8..q.len() {
            out[i] = q[i] as f32 * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_primitives() {
        let mut e = Encoder::new();
        e.u8(7);
        e.u16(300);
        e.u32(70_000);
        e.i32(-5);
        e.u64(1 << 40);
        e.f64(-2.5);
        e.bytes(b"hello");
        let frame = e.frame();
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        let mut d = Decoder::new(&frame[4..4 + len]);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u16().unwrap(), 300);
        assert_eq!(d.u32().unwrap(), 70_000);
        assert_eq!(d.i32().unwrap(), -5);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f64().unwrap(), -2.5);
        assert_eq!(d.bytes().unwrap(), b"hello");
        d.finish().unwrap();
    }

    #[test]
    fn roundtrip_arrays_and_strings() {
        let mut e = Encoder::new();
        e.f32(1.5);
        e.f32s(&[0.25, -3.0, f32::MIN_POSITIVE]);
        e.i32s(&[-7, 0, i32::MAX]);
        e.str("vgg11_mini");
        e.f32s(&[]);
        let frame = e.frame();
        let mut d = Decoder::new(&frame[4..]);
        assert_eq!(d.f32().unwrap(), 1.5);
        assert_eq!(d.f32s().unwrap(), vec![0.25, -3.0, f32::MIN_POSITIVE]);
        assert_eq!(d.i32s().unwrap(), vec![-7, 0, i32::MAX]);
        assert_eq!(d.str().unwrap(), "vgg11_mini");
        assert_eq!(d.f32s().unwrap(), Vec::<f32>::new());
        d.finish().unwrap();
    }

    #[test]
    fn forged_array_count_errors_without_allocating() {
        // Count claims u32::MAX elements with a 4-byte body: the decoder
        // must bounds-check before allocating anything.
        let mut e = Encoder::new();
        e.u32(u32::MAX);
        e.u32(0);
        let frame = e.frame();
        let mut d = Decoder::new(&frame[4..]);
        assert!(d.f32s().is_err());
        let mut d = Decoder::new(&frame[4..]);
        assert!(d.i32s().is_err());
    }

    #[test]
    fn non_utf8_string_rejected() {
        let mut e = Encoder::new();
        e.bytes(&[0xFF, 0xFE, 0x80]);
        let frame = e.frame();
        let mut d = Decoder::new(&frame[4..]);
        assert!(d.str().is_err());
    }

    #[test]
    fn underrun_detected() {
        let mut d = Decoder::new(&[1, 2]);
        assert!(d.u32().is_err());
    }

    #[test]
    fn trailing_detected() {
        let d = Decoder::new(&[1]);
        assert!(d.finish().is_err());
    }

    #[test]
    fn u32s_roundtrip_and_forged_count() {
        let mut e = Encoder::new();
        e.u32s(&[0, 7, u32::MAX]);
        let frame = e.frame();
        let mut d = Decoder::new(&frame[4..]);
        assert_eq!(d.u32s().unwrap(), vec![0, 7, u32::MAX]);
        d.finish().unwrap();
        let mut e = Encoder::new();
        e.u32(u32::MAX);
        let frame = e.frame();
        assert!(Decoder::new(&frame[4..]).u32s().is_err());
    }

    fn window(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..len).map(|_| rng.normal() as f32 * 0.37).collect()
    }

    #[test]
    fn topk_roundtrip_keeps_largest_and_zeros_rest() {
        for len in [1usize, 3, 4, 5, 64, 1023] {
            let x = window(11 + len as u64, len);
            let (idx, val) = topk_encode(&x);
            assert_eq!(idx.len(), topk_k(len));
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices not increasing");
            let y = topk_decode(len, &idx, &val).unwrap();
            let kept: std::collections::BTreeSet<u32> = idx.iter().copied().collect();
            let min_kept = idx
                .iter()
                .map(|&i| x[i as usize].abs().to_bits())
                .min()
                .unwrap();
            for i in 0..len {
                if kept.contains(&(i as u32)) {
                    assert_eq!(y[i].to_bits(), x[i].to_bits(), "kept value changed");
                } else {
                    assert_eq!(y[i].to_bits(), 0, "dropped value not zeroed");
                    assert!(
                        x[i].abs().to_bits() <= min_kept,
                        "dropped |x[{i}]| above a kept magnitude"
                    );
                }
            }
        }
    }

    #[test]
    fn topk_decode_rejects_hostile_frames() {
        let x = window(5, 16);
        let (idx, val) = topk_encode(&x);
        // Declared dense length beyond the frame ceiling must fail BEFORE
        // the output allocation (the satellite bugfix).
        assert!(topk_decode(usize::MAX / 8, &idx, &val).is_err());
        assert!(topk_decode(crate::comm::MAX_FRAME, &idx, &val).is_err());
        // Count / bounds / monotonicity violations.
        assert!(topk_decode(16, &idx[1..], &val[1..]).is_err(), "wrong k");
        assert!(topk_decode(16, &idx, &val[1..]).is_err(), "idx/val mismatch");
        let mut bad = idx.clone();
        bad[0] = 16;
        assert!(topk_decode(16, &bad, &val).is_err(), "index out of range");
        let mut bad = idx.clone();
        bad.swap(0, 1);
        assert!(topk_decode(16, &bad, &val).is_err(), "non-increasing indices");
    }

    #[test]
    fn q8_roundtrip_error_is_bounded_and_stable() {
        for len in [1usize, 2, 31, 256] {
            let x = window(40 + len as u64, len);
            let (scale, q) = q8_encode(&x);
            assert!(scale > 0.0 && scale.to_bits().trailing_zeros() >= 23, "power-of-two scale");
            let y = q8_decode(scale, &q).unwrap();
            // Rounding error is ≤ scale/2; the clamp at ±127 can stretch
            // the max element's error toward (but never past) one scale.
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() <= scale, "{a} vs {b} (scale {scale})");
            }
            // Byte-stability: encode ∘ decode ∘ encode is the identity on
            // the wire bytes (the power-of-two-scale property).
            let (scale2, q2) = q8_encode(&y);
            assert_eq!(scale2.to_bits(), scale.to_bits());
            assert_eq!(q2, q);
        }
    }

    #[test]
    fn q8_flushes_degenerate_windows_to_zero() {
        for x in [
            vec![0.0f32; 7],
            vec![1e-38f32.min(f32::MIN_POSITIVE / 2.0); 3],
            vec![f32::NAN, 1.0, -2.0],
            vec![f32::INFINITY, 0.5],
        ] {
            let (scale, q) = q8_encode(&x);
            assert_eq!(scale, 0.0);
            assert!(q.iter().all(|&v| v == 0));
            assert!(q8_decode(scale, &q).unwrap().iter().all(|&v| v == 0.0));
        }
        assert!(q8_decode(f32::NAN, &[0]).is_err());
        assert!(q8_decode(-1.0, &[0]).is_err());
    }

    #[test]
    fn payload_bytes_match_codec_output() {
        for len in [1usize, 4, 5, 1024] {
            let x = window(9 + len as u64, len);
            assert_eq!(WireMode::Dense.payload_bytes(len), 4 * len);
            let (idx, val) = topk_encode(&x);
            assert_eq!(WireMode::TopK.payload_bytes(len), 4 * idx.len() + 4 * val.len());
            let (_, q) = q8_encode(&x);
            assert_eq!(WireMode::Q8.payload_bytes(len), q.len() + 4);
            // Compressed strictly under dense for every window size.
            assert!(WireMode::TopK.payload_bytes(len) < WireMode::Dense.payload_bytes(len) || len < 2);
            assert!(WireMode::Q8.payload_bytes(len) < WireMode::Dense.payload_bytes(len) || len < 2);
        }
        for (s, want) in [("dense", WireMode::Dense), (" TopK ", WireMode::TopK), ("q8", WireMode::Q8)] {
            assert_eq!(WireMode::parse(s).unwrap(), want);
        }
        assert!(WireMode::parse("zstd").is_err());
    }
}
