//! Experiment configuration system.
//!
//! Configs are plain structs with JSON load/save (the offline build has no
//! serde; see `util::json`). Every paper experiment has a named preset in
//! [`presets`], so harness binaries are `dynamix exp --preset fig4-vgg11-sgd
//! --scale quick` rather than hand-assembled flag soup. A `Scale` knob
//! shrinks episode/step counts for CI while preserving every structural
//! parameter (worker counts, k, reward coefficients).

use crate::sim::scenario::ScenarioScript;
use crate::util::json::Json;
use std::path::Path;

/// Optimizer family; selects both the train-step artifact and the paper's
/// reward variant (the eta gradient-stability penalty applies to adaptive
/// optimizers only, §IV-D).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Optimizer {
    Sgd,
    Adam,
}

impl Optimizer {
    pub fn as_str(&self) -> &'static str {
        match self {
            Optimizer::Sgd => "sgd",
            Optimizer::Adam => "adam",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "sgd" => Ok(Optimizer::Sgd),
            "adam" => Ok(Optimizer::Adam),
            _ => anyhow::bail!("unknown optimizer {s:?}"),
        }
    }

    /// Adaptive optimizers get the sigma_norm penalty in the reward.
    pub fn is_adaptive(&self) -> bool {
        matches!(self, Optimizer::Adam)
    }
}

/// Gradient-synchronization topology (paper §VI: Ring All-Reduce on the
/// primary/OSC testbeds, BytePS parameter server on FABRIC).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    RingAllReduce,
    /// BytePS-style parameter server with `servers` server nodes.
    ParameterServer { servers: usize },
}

impl Topology {
    pub fn as_str(&self) -> String {
        match self {
            Topology::RingAllReduce => "ring".into(),
            Topology::ParameterServer { servers } => format!("ps{servers}"),
        }
    }
}

/// Cluster heterogeneity preset (DESIGN.md substitution table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterPreset {
    /// Lambda primary testbed: near-uniform A100 nodes, mild jitter.
    UniformA100,
    /// OSC: uniform A100-PCIE with moderate shared-fabric contention.
    OscA100,
    /// FABRIC: 4 fast (RTX3090-like) + 4 slow (T4-like) workers, noisy net.
    FabricHetero,
    /// Spot-market style: large speed spread + load bursts (stress preset).
    SpotMarket,
}

impl ClusterPreset {
    pub fn as_str(&self) -> &'static str {
        match self {
            ClusterPreset::UniformA100 => "uniform_a100",
            ClusterPreset::OscA100 => "osc_a100",
            ClusterPreset::FabricHetero => "fabric_hetero",
            ClusterPreset::SpotMarket => "spot_market",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "uniform_a100" => ClusterPreset::UniformA100,
            "osc_a100" => ClusterPreset::OscA100,
            "fabric_hetero" => ClusterPreset::FabricHetero,
            "spot_market" => ClusterPreset::SpotMarket,
            _ => anyhow::bail!("unknown cluster preset {s:?}"),
        })
    }
}

/// PPO variant (paper §IV-A describes both; DESIGN.md §6 ablates them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PpoVariant {
    /// Clipped surrogate + GAE (Eq. 1) — default.
    Clipped,
    /// The paper's simplification: cumulative-reward policy gradient.
    Simplified,
}

/// Training-workload half of an experiment.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub optimizer: Optimizer,
    pub lr: f32,
    /// Root seed; model init uses `seed % init_seeds` snapshot.
    pub seed: u64,
    /// Convergence target on eval accuracy (run stops when sustained).
    pub target_acc: f64,
    /// Hard cap on global iterations per run/episode.
    pub max_steps: usize,
    /// Evaluate every `eval_every` global iterations.
    pub eval_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "vgg11_mini".into(),
            optimizer: Optimizer::Sgd,
            lr: 0.05,
            seed: 0,
            target_acc: 0.80,
            max_steps: 400,
            eval_every: 10,
        }
    }
}

/// RL half (paper §IV).
#[derive(Clone, Debug)]
pub struct RlConfig {
    /// Temporal aggregation window: iterations per decision cycle (§III-C).
    pub k: usize,
    pub gamma: f64,
    pub lr: f32,
    pub clip_eps: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
    /// PPO epochs over the trajectory buffer per policy update.
    pub update_epochs: usize,
    pub variant: PpoVariant,
    // Reward coefficients (§IV-D).
    pub alpha: f64,
    pub beta: f64,
    pub delta: f64,
    pub eta: f64,
    /// GAE lambda.
    pub gae_lambda: f64,
    /// Feature ablation switches (DESIGN.md §6).
    pub use_network_features: bool,
    pub use_grad_stats_features: bool,
}

impl Default for RlConfig {
    fn default() -> Self {
        RlConfig {
            k: 5,
            gamma: 0.99,
            lr: 3e-4,
            clip_eps: 0.2,
            ent_coef: 0.01,
            vf_coef: 0.5,
            update_epochs: 4,
            variant: PpoVariant::Clipped,
            alpha: 2.0,
            beta: 0.5,
            delta: 0.05,
            eta: 0.1,
            gae_lambda: 0.95,
            use_network_features: true,
            use_grad_stats_features: true,
        }
    }
}

/// Cluster + network half.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub n_workers: usize,
    pub preset: ClusterPreset,
    pub topology: Topology,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_workers: 16,
            preset: ClusterPreset::UniformA100,
            topology: Topology::RingAllReduce,
            seed: 0,
        }
    }
}

/// Batch-size constraints (paper §IV-C).
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    pub initial: usize,
    pub min: usize,
    pub max: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            initial: 128,
            min: 32,
            max: 1024,
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub train: TrainConfig,
    pub rl: RlConfig,
    pub cluster: ClusterConfig,
    pub batch: BatchConfig,
    /// RL-training episodes (§VI-C: 20).
    pub episodes: usize,
    /// Decision cycles per episode (≈ paper's "steps per episode").
    pub steps_per_episode: usize,
    /// Scripted dynamic-environment timeline (None = stationary run).
    /// Replayed identically — same seed, same events — for the RL policy
    /// and every baseline, and re-armed on each episode reset.
    pub scenario: Option<ScenarioScript>,
    /// Data-plane shards for the sharded compute backend (None = whatever
    /// single-process backend the environment selects). Honored by
    /// `runtime::backend_for`; `DYNAMIX_BACKEND` in the environment wins
    /// over this field. Sharding never changes the math — the sharded
    /// backend is bit-identical to native — only who computes which rows.
    pub shards: Option<usize>,
    /// Kernel tier request (`auto`/`scalar`/`blocked`/`simd`; None =
    /// whatever the environment selects). Applied via
    /// `runtime::apply_kernel_request` before backend construction;
    /// `DYNAMIX_KERNEL` in the environment wins over this field.
    pub kernel: Option<String>,
    /// Zero-plane slice codec request (`dense`/`topk`/`q8`; None =
    /// whatever the environment selects). `DYNAMIX_WIRE` in the
    /// environment wins over this field. Compressed modes trade bit
    /// parity with the fused step for wire bytes while staying exactly
    /// reproducible run to run.
    pub wire: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            train: TrainConfig::default(),
            rl: RlConfig::default(),
            cluster: ClusterConfig::default(),
            batch: BatchConfig::default(),
            episodes: 20,
            steps_per_episode: 100,
            scenario: None,
            shards: None,
            kernel: None,
            wire: None,
        }
    }
}

/// Effort scale for experiment harnesses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized: minutes, preserves structure not asymptotics.
    Quick,
    /// Paper-shaped: what EXPERIMENTS.md reports.
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "quick" => Ok(Scale::Quick),
            "full" => Ok(Scale::Full),
            _ => anyhow::bail!("unknown scale {s:?} (quick|full)"),
        }
    }
}

impl ExperimentConfig {
    /// Validate cross-field invariants; call before running.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.cluster.n_workers >= 1 && self.cluster.n_workers <= 32,
            "n_workers {} outside [1,32] (policy_forward artifact is compiled for 32)",
            self.cluster.n_workers);
        anyhow::ensure!(self.batch.min >= 32, "min batch below paper floor 32");
        anyhow::ensure!(self.batch.max <= 1024, "max batch above paper cap 1024");
        anyhow::ensure!(self.batch.initial >= self.batch.min && self.batch.initial <= self.batch.max,
            "initial batch outside [min,max]");
        anyhow::ensure!(self.rl.k >= 1, "k must be >= 1");
        anyhow::ensure!((0.0..=1.0).contains(&self.rl.gamma), "gamma outside [0,1]");
        anyhow::ensure!(self.train.max_steps >= self.rl.k, "max_steps < k");
        if let Some(n) = self.shards {
            anyhow::ensure!(
                (1..=64).contains(&n),
                "shards {n} outside [1,64] (the data plane's worker ceiling)"
            );
        }
        if let Some(k) = &self.kernel {
            // Delegate to the runtime's parser so the config accept-list
            // can never drift from what the CLI/env accept.
            crate::runtime::native::KernelTier::parse(k)
                .map_err(|e| anyhow::anyhow!("config kernel: {e}"))?;
        }
        if let Some(w) = &self.wire {
            crate::comm::wire::WireMode::parse(w)
                .map_err(|e| anyhow::anyhow!("config wire: {e}"))?;
        }
        if let Some(s) = &self.scenario {
            s.validate(self.cluster.n_workers)?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = crate::jobj! {
            "name" => self.name.clone(),
            "model" => self.train.model.clone(),
            "optimizer" => self.train.optimizer.as_str(),
            "lr" => self.train.lr as f64,
            "seed" => self.train.seed as f64,
            "target_acc" => self.train.target_acc,
            "max_steps" => self.train.max_steps,
            "eval_every" => self.train.eval_every,
            "k" => self.rl.k,
            "gamma" => self.rl.gamma,
            "rl_lr" => self.rl.lr as f64,
            "clip_eps" => self.rl.clip_eps as f64,
            "ent_coef" => self.rl.ent_coef as f64,
            "vf_coef" => self.rl.vf_coef as f64,
            "update_epochs" => self.rl.update_epochs,
            "variant" => match self.rl.variant { PpoVariant::Clipped => "clipped", PpoVariant::Simplified => "simplified" },
            "alpha" => self.rl.alpha,
            "beta" => self.rl.beta,
            "delta" => self.rl.delta,
            "eta" => self.rl.eta,
            "gae_lambda" => self.rl.gae_lambda,
            "use_network_features" => self.rl.use_network_features,
            "use_grad_stats_features" => self.rl.use_grad_stats_features,
            "n_workers" => self.cluster.n_workers,
            "preset" => self.cluster.preset.as_str(),
            "topology" => self.cluster.topology.as_str(),
            "cluster_seed" => self.cluster.seed as f64,
            "batch_initial" => self.batch.initial,
            "batch_min" => self.batch.min,
            "batch_max" => self.batch.max,
            "episodes" => self.episodes,
            "steps_per_episode" => self.steps_per_episode,
        };
        if let Json::Obj(m) = &mut j {
            if let Some(s) = &self.scenario {
                m.insert("scenario".into(), s.to_json());
            }
            if let Some(n) = self.shards {
                m.insert("shards".into(), Json::Num(n as f64));
            }
            if let Some(k) = &self.kernel {
                m.insert("kernel".into(), Json::Str(k.clone()));
            }
            if let Some(w) = &self.wire {
                m.insert("wire".into(), Json::Str(w.clone()));
            }
        }
        j
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let mut c = ExperimentConfig::default();
        let s = |k: &str| v.get(k).and_then(Json::as_str).map(str::to_string);
        let f = |k: &str| v.get(k).and_then(Json::as_f64);
        let u = |k: &str| v.get(k).and_then(Json::as_usize);
        let b = |k: &str| v.get(k).and_then(Json::as_bool);
        if let Some(x) = s("name") { c.name = x; }
        if let Some(x) = s("model") { c.train.model = x; }
        if let Some(x) = s("optimizer") { c.train.optimizer = Optimizer::parse(&x)?; }
        if let Some(x) = f("lr") { c.train.lr = x as f32; }
        if let Some(x) = f("seed") { c.train.seed = x as u64; }
        if let Some(x) = f("target_acc") { c.train.target_acc = x; }
        if let Some(x) = u("max_steps") { c.train.max_steps = x; }
        if let Some(x) = u("eval_every") { c.train.eval_every = x; }
        if let Some(x) = u("k") { c.rl.k = x; }
        if let Some(x) = f("gamma") { c.rl.gamma = x; }
        if let Some(x) = f("rl_lr") { c.rl.lr = x as f32; }
        if let Some(x) = f("clip_eps") { c.rl.clip_eps = x as f32; }
        if let Some(x) = f("ent_coef") { c.rl.ent_coef = x as f32; }
        if let Some(x) = f("vf_coef") { c.rl.vf_coef = x as f32; }
        if let Some(x) = u("update_epochs") { c.rl.update_epochs = x; }
        if let Some(x) = s("variant") {
            c.rl.variant = match x.as_str() {
                "clipped" => PpoVariant::Clipped,
                "simplified" => PpoVariant::Simplified,
                _ => anyhow::bail!("unknown variant {x:?}"),
            };
        }
        if let Some(x) = f("alpha") { c.rl.alpha = x; }
        if let Some(x) = f("beta") { c.rl.beta = x; }
        if let Some(x) = f("delta") { c.rl.delta = x; }
        if let Some(x) = f("eta") { c.rl.eta = x; }
        if let Some(x) = f("gae_lambda") { c.rl.gae_lambda = x; }
        if let Some(x) = b("use_network_features") { c.rl.use_network_features = x; }
        if let Some(x) = b("use_grad_stats_features") { c.rl.use_grad_stats_features = x; }
        if let Some(x) = u("n_workers") { c.cluster.n_workers = x; }
        if let Some(x) = s("preset") { c.cluster.preset = ClusterPreset::parse(&x)?; }
        if let Some(x) = s("topology") {
            c.cluster.topology = if x == "ring" {
                Topology::RingAllReduce
            } else if let Some(n) = x.strip_prefix("ps") {
                Topology::ParameterServer { servers: n.parse()? }
            } else {
                anyhow::bail!("unknown topology {x:?}")
            };
        }
        if let Some(x) = f("cluster_seed") { c.cluster.seed = x as u64; }
        if let Some(x) = u("batch_initial") { c.batch.initial = x; }
        if let Some(x) = u("batch_min") { c.batch.min = x; }
        if let Some(x) = u("batch_max") { c.batch.max = x; }
        if let Some(x) = u("episodes") { c.episodes = x; }
        if let Some(x) = u("steps_per_episode") { c.steps_per_episode = x; }
        if let Some(v) = v.get("scenario") { c.scenario = Some(ScenarioScript::from_json(v)?); }
        if let Some(x) = u("shards") { c.shards = Some(x); }
        if let Some(x) = s("kernel") { c.kernel = Some(x); }
        if let Some(x) = s("wire") { c.wire = Some(x); }
        c.validate()?;
        Ok(c)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

pub mod env;
pub mod presets;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn roundtrip_json() {
        let mut c = ExperimentConfig::default();
        c.name = "t".into();
        c.train.optimizer = Optimizer::Adam;
        c.cluster.topology = Topology::ParameterServer { servers: 2 };
        c.rl.variant = PpoVariant::Simplified;
        c.cluster.n_workers = 8;
        c.scenario = Some(ScenarioScript::by_name("spot_chaos").unwrap());
        c.shards = Some(4);
        c.kernel = Some("simd".into());
        c.wire = Some("q8".into());
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.train.optimizer, Optimizer::Adam);
        assert_eq!(c2.cluster.topology, Topology::ParameterServer { servers: 2 });
        assert_eq!(c2.rl.variant, PpoVariant::Simplified);
        assert_eq!(c2.cluster.n_workers, 8);
        assert_eq!(c2.scenario, c.scenario, "scenario scripts must round-trip");
        assert_eq!(c2.shards, Some(4), "shard config must round-trip");
        assert_eq!(c2.kernel.as_deref(), Some("simd"), "kernel tier must round-trip");
        assert_eq!(c2.wire.as_deref(), Some("q8"), "wire mode must round-trip");
        // No scenario/shards/kernel/wire keys -> None (defaults preserved).
        let plain = ExperimentConfig::from_json(&ExperimentConfig::default().to_json()).unwrap();
        assert!(plain.scenario.is_none());
        assert!(plain.shards.is_none());
        assert!(plain.kernel.is_none());
        assert!(plain.wire.is_none());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = ExperimentConfig::default();
        c.cluster.n_workers = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.cluster.n_workers = 64;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.batch.initial = 8;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.batch.max = 4096;
        assert!(c.validate().is_err());
        // Scenario validation runs against the configured cluster size.
        let mut c = ExperimentConfig::default();
        c.cluster.n_workers = 2;
        c.scenario = Some(ScenarioScript::by_name("preempt_rejoin").unwrap());
        assert!(c.validate().is_err(), "script targets worker 3 of 2");
        // Shard counts outside the data plane's ceiling are rejected.
        let mut c = ExperimentConfig::default();
        c.shards = Some(0);
        assert!(c.validate().is_err());
        c.shards = Some(65);
        assert!(c.validate().is_err());
        c.shards = Some(8);
        c.validate().unwrap();
        // Unknown kernel tiers are rejected; the four knowns pass.
        c.kernel = Some("avx512".into());
        assert!(c.validate().is_err());
        for k in ["auto", "scalar", "blocked", "simd"] {
            c.kernel = Some(k.into());
            c.validate().unwrap();
        }
        // Unknown wire modes are rejected; the three knowns pass.
        c.wire = Some("zstd".into());
        assert!(c.validate().is_err());
        for w in ["dense", "topk", "q8"] {
            c.wire = Some(w.into());
            c.validate().unwrap();
        }
    }

    #[test]
    fn optimizer_and_preset_parse() {
        assert_eq!(Optimizer::parse("adam").unwrap(), Optimizer::Adam);
        assert!(Optimizer::parse("lamb").is_err());
        assert_eq!(
            ClusterPreset::parse("fabric_hetero").unwrap(),
            ClusterPreset::FabricHetero
        );
    }
}
