//! The one sanctioned doorway to `DYNAMIX_*` environment variables.
//!
//! PR 5 shipped (and fixed) a real bug in this class: `Pool::default`
//! re-read `DYNAMIX_THREADS` per call site, so a mid-process env change
//! produced two pools with different shapes. The repo-wide rule — now
//! machine-enforced by `dynamix-lint`'s `env-read` rule — is that
//! `std::env::var` appears only here, in `runtime/native/exec.rs`
//! (process-global `GlobalCfg`, read exactly once through a `OnceLock`),
//! and in `util/bench.rs` (bench-harness knobs). Everything else calls
//! these accessors, which keeps every variable's parsing/defaulting in
//! one grep-able place.
//!
//! These helpers deliberately stay *thin* (no caching): read-once
//! discipline belongs to the callers that need it (`GlobalCfg`), while
//! path-style overrides (`DYNAMIX_RUNS`, `DYNAMIX_ARTIFACTS`) are
//! harmless to re-read and are consulted per call.

use std::path::PathBuf;

/// Raw accessor: `Some` iff the variable is set (possibly empty).
pub fn raw(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

/// `DYNAMIX_RUNS`: override for the run-record directory.
pub fn runs_dir_override() -> Option<PathBuf> {
    raw("DYNAMIX_RUNS").map(PathBuf::from)
}

/// `DYNAMIX_ARTIFACTS`: override for the XLA artifacts directory.
pub fn artifacts_dir_override() -> Option<PathBuf> {
    raw("DYNAMIX_ARTIFACTS").map(PathBuf::from)
}

/// `DYNAMIX_BACKEND`: requested backend name; empty string when unset
/// (the backend selector treats `""` and `"auto"` identically).
pub fn backend_choice() -> String {
    raw("DYNAMIX_BACKEND").unwrap_or_default()
}

/// `DYNAMIX_SHARDS`: requested loopback shard count (>= 1), if any.
pub fn shards() -> Option<usize> {
    raw("DYNAMIX_SHARDS")?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
}

/// `DYNAMIX_KERNEL`: the env-level kernel-tier request, if non-empty.
pub fn kernel_choice() -> Option<String> {
    raw("DYNAMIX_KERNEL").filter(|s| !s.is_empty())
}

/// `DYNAMIX_OVERLAP`: comm/compute overlap in the sharded backward.
/// `on`/`1`/`true` -> `Some(true)`, `off`/`0`/`false` -> `Some(false)`,
/// unset or unrecognized -> `None` (caller default: on). Read once at
/// `ShardedBackend` construction — never mid-run.
pub fn overlap() -> Option<bool> {
    parse_switch(&raw("DYNAMIX_OVERLAP")?)
}

/// `DYNAMIX_BUCKET_KB`: target gradient-bucket size in KiB for the
/// overlapped ring (>= 1; the plan coalesces completion stages up to
/// roughly this many bytes). Unset/invalid -> `None` (caller default).
pub fn bucket_kb() -> Option<usize> {
    raw("DYNAMIX_BUCKET_KB")?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
}

/// `DYNAMIX_WIRE`: gradient-slice payload codec for the ZeRO plane
/// (`dense`/`topk`/`q8`). Unset or unrecognized -> `None` (caller
/// default: dense). Read once at `ShardedBackend`/trainer construction —
/// never mid-run.
pub fn wire_mode() -> Option<crate::comm::wire::WireMode> {
    crate::comm::wire::WireMode::parse(&raw("DYNAMIX_WIRE")?).ok()
}

/// `DYNAMIX_PLANE`: gradient exchange plane — `zero` (reduce-scatter
/// parameter sharding, the default) or `replica` (the PR 4/7
/// full-replica ring, kept as the parity reference). Unset or
/// unrecognized -> `None` (caller default: zero). Read once at backend
/// construction.
pub fn plane() -> Option<String> {
    let s = raw("DYNAMIX_PLANE")?.trim().to_ascii_lowercase();
    matches!(s.as_str(), "zero" | "replica").then_some(s)
}

/// `DYNAMIX_CKPT_DIR`: checkpoint + journal directory for durable runs.
/// Unset or empty -> `None` (checkpointing off). Dedicate a directory per
/// run: restore picks the highest-step `ckpt-<step>.bin` it finds.
pub fn ckpt_dir() -> Option<PathBuf> {
    raw("DYNAMIX_CKPT_DIR")
        .filter(|s| !s.is_empty())
        .map(PathBuf::from)
}

/// `DYNAMIX_CKPT_EVERY`: decision-cycle cadence between checkpoints
/// (>= 1). Unset/invalid -> `None` (caller default: 1, every cycle).
pub fn ckpt_every() -> Option<usize> {
    raw("DYNAMIX_CKPT_EVERY")?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
}

/// `DYNAMIX_CKPT_KEEP`: checkpoint retention — how many of the newest
/// `ckpt-<step>.bin` / `leader-<cycle>.bin` images survive the post-save
/// prune (>= 1; the just-written image always survives). Unset/invalid ->
/// `None` (retention off, every image kept).
pub fn ckpt_keep() -> Option<usize> {
    raw("DYNAMIX_CKPT_KEEP")?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
}

/// `DYNAMIX_RESUME`: resume from the latest checkpoint in
/// `DYNAMIX_CKPT_DIR` instead of starting fresh. `on`/`1`/`true` ->
/// resume; anything else (including unset) -> fresh start.
pub fn resume() -> bool {
    raw("DYNAMIX_RESUME").as_deref().and_then(parse_switch) == Some(true)
}

fn parse_switch(s: &str) -> Option<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "on" | "1" | "true" => Some(true),
        "off" | "0" | "false" => Some(false),
        _ => None,
    }
}

/// Set `DYNAMIX_KERNEL` to the config-file request `k` unless the
/// environment already picked a tier (the env always wins). Must run
/// before the first backend is constructed: `GlobalCfg` reads the
/// variable exactly once, so a later call is a silent no-op.
pub fn request_kernel(k: &str) {
    if kernel_choice().is_none() {
        std::env::set_var("DYNAMIX_KERNEL", k);
    }
}

/// Set `DYNAMIX_WIRE` to the config-file request `w` unless the
/// environment already picked a codec (the env always wins). Must run
/// before the backend/trainer constructions that read the variable.
pub fn request_wire(w: &str) {
    if raw("DYNAMIX_WIRE").map_or(true, |s| s.is_empty()) {
        std::env::set_var("DYNAMIX_WIRE", w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_parses_and_filters() {
        // Parse logic only — exercised via the raw string path to avoid
        // cross-test env races.
        assert_eq!("3".trim().parse::<usize>().ok().filter(|&n| n >= 1), Some(3));
        assert_eq!("0".trim().parse::<usize>().ok().filter(|&n| n >= 1), None);
        assert_eq!("x".trim().parse::<usize>().ok().filter(|&n| n >= 1), None);
        // Unset variable -> None without panicking.
        assert_eq!(raw("DYNAMIX_DEFINITELY_UNSET_VAR_42"), None);
    }

    #[test]
    fn overlap_switch_parses_all_spellings() {
        for s in ["on", "1", "true", " ON "] {
            assert_eq!(parse_switch(s), Some(true), "{s:?}");
        }
        for s in ["off", "0", "false", "Off"] {
            assert_eq!(parse_switch(s), Some(false), "{s:?}");
        }
        for s in ["", "yes", "2"] {
            assert_eq!(parse_switch(s), None, "{s:?}");
        }
    }
}
