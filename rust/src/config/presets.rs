//! Named experiment presets: one per paper table/figure configuration.
//!
//! The preset encodes everything structural; [`scaled`] then shrinks only
//! effort knobs (episodes, steps, max_steps) for `Scale::Quick` runs.

use super::*;

/// Paper §VI-C step counts per episode: VGG11-SGD 100, VGG11-Adam 70,
/// ResNet34-SGD 120 (each step here = one k-iteration decision cycle).
fn base(name: &str, model: &str, opt: Optimizer, lr: f32, steps: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.name = name.into();
    c.train.model = model.into();
    c.train.optimizer = opt;
    c.train.lr = lr;
    c.steps_per_episode = steps;
    c.train.max_steps = steps * c.rl.k;
    // CIFAR-100-family models converge to lower absolute accuracy.
    if model.starts_with("resnet") {
        c.train.target_acc = 0.60;
    }
    c
}

/// All named presets. Returns an error listing valid names on a miss.
pub fn by_name(name: &str) -> anyhow::Result<ExperimentConfig> {
    let c = match name {
        // --- primary testbed configs (Figs 3-5): 16 workers, ring ---
        "vgg11-sgd" => base(name, "vgg11_mini", Optimizer::Sgd, 0.05, 100),
        "vgg11-adam" => {
            let mut c = base(name, "vgg11_mini", Optimizer::Adam, 0.002, 70);
            c.rl.eta = 0.1;
            c
        }
        "resnet34-sgd" => base(name, "resnet34_mini", Optimizer::Sgd, 0.02, 120),

        // --- scalability (Table I): vgg16 on OSC at 8/16/32 nodes ---
        "scal-8" | "scal-16" | "scal-32" => {
            let n: usize = name.strip_prefix("scal-").unwrap().parse()?;
            let mut c = base(name, "vgg16_mini", Optimizer::Sgd, 0.05, 100);
            c.cluster.preset = ClusterPreset::OscA100;
            c.cluster.n_workers = n;
            c
        }

        // --- policy transfer (Fig 6) ---
        "transfer-vgg16-src" => {
            let mut c = base(name, "vgg16_mini", Optimizer::Sgd, 0.05, 100);
            c.cluster.preset = ClusterPreset::OscA100;
            c.cluster.n_workers = 16;
            c
        }
        "transfer-vgg19-dst" => {
            let mut c = base(name, "vgg19_mini", Optimizer::Sgd, 0.05, 100);
            c.cluster.preset = ClusterPreset::OscA100;
            c.cluster.n_workers = 16;
            c
        }
        "transfer-resnet34-src" => {
            let mut c = base(name, "resnet34_mini", Optimizer::Sgd, 0.02, 120);
            c.cluster.preset = ClusterPreset::OscA100;
            c.cluster.n_workers = 32;
            c
        }
        "transfer-resnet50-dst" => {
            let mut c = base(name, "resnet50_mini", Optimizer::Sgd, 0.02, 120);
            c.cluster.preset = ClusterPreset::OscA100;
            c.cluster.n_workers = 32;
            c
        }

        // --- BytePS / FABRIC heterogeneous (§VI-G): 8 workers, PS ---
        "byteps-hetero" => {
            let mut c = base(name, "vgg11_mini", Optimizer::Sgd, 0.05, 100);
            c.cluster.preset = ClusterPreset::FabricHetero;
            c.cluster.n_workers = 8;
            c.cluster.topology = Topology::ParameterServer { servers: 2 };
            c.train.target_acc = 0.75;
            c
        }

        // --- ablation presets (DESIGN.md §6) ---
        "ablate-simplified-ppo" => {
            let mut c = base(name, "vgg11_mini", Optimizer::Sgd, 0.05, 100);
            c.rl.variant = PpoVariant::Simplified;
            c
        }
        "ablate-no-network-state" => {
            let mut c = base(name, "vgg11_mini", Optimizer::Sgd, 0.05, 100);
            c.rl.use_network_features = false;
            c
        }
        "ablate-no-grad-stats" => {
            let mut c = base(name, "vgg11_mini", Optimizer::Sgd, 0.05, 100);
            c.rl.use_grad_stats_features = false;
            c
        }
        _ => anyhow::bail!(
            "unknown preset {name:?}; valid: vgg11-sgd vgg11-adam resnet34-sgd \
             scal-8 scal-16 scal-32 transfer-vgg16-src transfer-vgg19-dst \
             transfer-resnet34-src transfer-resnet50-dst byteps-hetero \
             ablate-simplified-ppo ablate-no-network-state ablate-no-grad-stats"
        ),
    };
    c.validate()?;
    Ok(c)
}

/// Apply an effort scale to a preset: `Quick` shrinks episodes/steps for
/// CI; `Full` is the paper-shaped run recorded in EXPERIMENTS.md.
pub fn scaled(mut c: ExperimentConfig, scale: Scale) -> ExperimentConfig {
    match scale {
        Scale::Full => c,
        Scale::Quick => {
            c.episodes = c.episodes.min(6);
            c.steps_per_episode = c.steps_per_episode.min(30);
            c.train.max_steps = c.steps_per_episode * c.rl.k;
            c
        }
    }
}

/// Every preset name (for CLI help / sweep-all harnesses).
pub const ALL: &[&str] = &[
    "vgg11-sgd",
    "vgg11-adam",
    "resnet34-sgd",
    "scal-8",
    "scal-16",
    "scal-32",
    "transfer-vgg16-src",
    "transfer-vgg19-dst",
    "transfer-resnet34-src",
    "transfer-resnet50-dst",
    "byteps-hetero",
    "ablate-simplified-ppo",
    "ablate-no-network-state",
    "ablate-no-grad-stats",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for name in ALL {
            let c = by_name(name).unwrap();
            c.validate().unwrap();
            assert_eq!(&c.name, name);
        }
    }

    #[test]
    fn unknown_preset_lists_valid_names() {
        let err = by_name("nope").unwrap_err().to_string();
        assert!(err.contains("vgg11-sgd"));
    }

    #[test]
    fn scalability_presets_vary_workers() {
        assert_eq!(by_name("scal-8").unwrap().cluster.n_workers, 8);
        assert_eq!(by_name("scal-32").unwrap().cluster.n_workers, 32);
    }

    #[test]
    fn quick_scale_shrinks_only_effort() {
        let full = by_name("vgg11-sgd").unwrap();
        let quick = scaled(full.clone(), Scale::Quick);
        assert!(quick.episodes <= 6 && quick.steps_per_episode <= 30);
        assert_eq!(quick.cluster.n_workers, full.cluster.n_workers);
        assert_eq!(quick.rl.k, full.rl.k);
    }

    #[test]
    fn byteps_preset_uses_ps_topology() {
        let c = by_name("byteps-hetero").unwrap();
        assert!(matches!(c.cluster.topology, Topology::ParameterServer { .. }));
        assert_eq!(c.cluster.preset, ClusterPreset::FabricHetero);
    }
}
