//! Heterogeneous-cluster simulator.
//!
//! Substitutes the paper's three physical testbeds (Lambda A100, OSC
//! A100-PCIE, FABRIC RTX3090/T4 — DESIGN.md substitution table). DYNAMIX's
//! decisions consume *relative* timing and contention signals, so the
//! simulator models exactly those:
//!
//! * per-worker **speed profile** (samples/sec at reference batch),
//!   calibrated so the 1.0 profile matches a measured real PJRT step;
//! * a **background-load process** per worker — an Ornstein–Uhlenbeck
//!   contention level in [0,1] plus Poisson bursts — standing in for
//!   multi-tenant/spot interference (paper §I, §II-B);
//! * a **memory model**: activation + parameter footprint per batch, used
//!   to refuse batch sizes that would OOM a worker (paper §IV-C
//!   "maintains hardware compatibility by avoiding memory overflows");
//! * the BSP **iteration clock**: per-iteration wall time is
//!   `max_i(compute_i) + sync + barrier`, the straggler structure that
//!   motivates the whole paper.

use crate::config::ClusterPreset;
use crate::util::rng::Rng;

/// Static capability description of one worker.
#[derive(Clone, Debug)]
pub struct WorkerProfile {
    /// Relative throughput multiplier (1.0 = reference GPU).
    pub speed: f64,
    /// Device memory in MiB (for the OOM rule).
    pub mem_mib: f64,
    /// NIC bandwidth in Gbit/s.
    pub bandwidth_gbps: f64,
    /// One-way link latency in ms.
    pub latency_ms: f64,
    /// OU contention parameters: mean level, reversion rate, volatility.
    pub load_mean: f64,
    pub load_rate: f64,
    pub load_vol: f64,
    /// Poisson burst rate (events per simulated second) and burst size.
    pub burst_rate: f64,
    pub burst_level: f64,
}

impl WorkerProfile {
    fn a100() -> Self {
        WorkerProfile {
            speed: 1.0,
            mem_mib: 24_000.0,
            bandwidth_gbps: 25.0,
            latency_ms: 0.15,
            load_mean: 0.05,
            load_rate: 0.5,
            load_vol: 0.05,
            burst_rate: 0.005,
            burst_level: 0.3,
        }
    }

    fn a100_osc() -> Self {
        // Shared HPC fabric: same GPU, more contention + latency.
        WorkerProfile {
            mem_mib: 40_000.0,
            latency_ms: 0.3,
            load_mean: 0.10,
            load_vol: 0.08,
            burst_rate: 0.01,
            ..Self::a100()
        }
    }

    fn rtx3090() -> Self {
        WorkerProfile {
            speed: 0.75,
            mem_mib: 24_000.0,
            bandwidth_gbps: 10.0,
            latency_ms: 1.0,
            load_mean: 0.12,
            load_rate: 0.4,
            load_vol: 0.1,
            burst_rate: 0.01,
            burst_level: 0.35,
        }
    }

    fn t4() -> Self {
        WorkerProfile {
            speed: 0.28,
            mem_mib: 16_000.0,
            bandwidth_gbps: 10.0,
            latency_ms: 1.2,
            load_mean: 0.15,
            load_rate: 0.4,
            load_vol: 0.12,
            burst_rate: 0.015,
            burst_level: 0.4,
        }
    }

    fn spot(rng: &mut Rng) -> Self {
        WorkerProfile {
            speed: rng.uniform_range(0.3, 1.2),
            mem_mib: 16_000.0,
            bandwidth_gbps: rng.uniform_range(5.0, 25.0),
            latency_ms: rng.uniform_range(0.2, 2.0),
            load_mean: rng.uniform_range(0.1, 0.3),
            load_rate: 0.3,
            load_vol: 0.15,
            burst_rate: 0.03,
            burst_level: 0.5,
        }
    }
}

/// Build the worker profile set for a preset.
pub fn profiles(preset: ClusterPreset, n_workers: usize, seed: u64) -> Vec<WorkerProfile> {
    let mut rng = Rng::new(seed ^ 0xC1A5);
    (0..n_workers)
        .map(|i| match preset {
            ClusterPreset::UniformA100 => WorkerProfile::a100(),
            ClusterPreset::OscA100 => WorkerProfile::a100_osc(),
            // FABRIC §VI-G: first half RTX3090, second half T4.
            ClusterPreset::FabricHetero => {
                if i < n_workers / 2 {
                    WorkerProfile::rtx3090()
                } else {
                    WorkerProfile::t4()
                }
            }
            ClusterPreset::SpotMarket => WorkerProfile::spot(&mut rng),
        })
        .collect()
}

/// Evolving state of one simulated worker.
#[derive(Clone, Debug)]
struct WorkerState {
    profile: WorkerProfile,
    /// Current contention level in [0, 0.95].
    load: f64,
    rng: Rng,
}

impl WorkerState {
    /// Advance the OU load process by `dt` simulated seconds.
    fn advance(&mut self, dt: f64) {
        let p = &self.profile;
        let drift = p.load_rate * (p.load_mean - self.load) * dt;
        let diffusion = p.load_vol * dt.sqrt() * self.rng.normal();
        self.load += drift + diffusion;
        // Poisson bursts (multi-tenant neighbours arriving).
        let bursts = self.rng.poisson(p.burst_rate * dt);
        if bursts > 0 {
            self.load += p.burst_level;
        }
        self.load = self.load.clamp(0.0, 0.95);
    }
}

/// Per-sample compute cost model, calibrated from real PJRT step timing.
///
/// `base_us_per_sample` is measured once on the reference profile (see
/// `trainer::calibrate`); everything else scales it.
#[derive(Clone, Copy, Debug)]
pub struct ComputeCostModel {
    pub base_us_per_sample: f64,
    /// Fixed per-iteration launch/framework overhead in microseconds.
    pub fixed_us: f64,
}

impl Default for ComputeCostModel {
    fn default() -> Self {
        // Representative of the mini models on the reference profile; the
        // trainer overwrites this with a measured value at startup.
        ComputeCostModel {
            base_us_per_sample: 120.0,
            fixed_us: 1_500.0,
        }
    }
}

/// Memory model: does `batch` fit on a worker? (paper §IV-C OOM rule)
///
/// footprint = params + optimizer state + activations(batch). Coefficients
/// reflect the full-size models the paper runs (so the 16 GiB T4 actually
/// binds at large batches, as it does in §VI-G).
pub fn batch_fits(profile: &WorkerProfile, param_count: usize, batch: usize) -> bool {
    let param_mib = (param_count * 4 * 3) as f64 / (1024.0 * 1024.0);
    // Full-size VGG-class activation footprint: ~12 MiB per sample.
    let act_mib = batch as f64 * 12.0;
    param_mib + act_mib < profile.mem_mib * 0.9
}

/// The simulated cluster: load processes + the BSP clock.
pub struct SimCluster {
    workers: Vec<WorkerState>,
    pub cost: ComputeCostModel,
    /// Simulated wall-clock (seconds since run start).
    pub clock: f64,
    /// Per-iteration barrier overhead (scheduler + kernel launch), seconds.
    pub barrier_s: f64,
}

/// Per-worker outcome of one simulated BSP iteration.
#[derive(Clone, Debug)]
pub struct ComputeOutcome {
    /// Compute seconds this worker spent on its local batch.
    pub compute_s: f64,
    /// Contention level during the iteration (feeds sysmetrics).
    pub load: f64,
    /// Effective speed (profile speed × (1 - load)).
    pub effective_speed: f64,
}

impl SimCluster {
    pub fn new(preset: ClusterPreset, n_workers: usize, seed: u64) -> Self {
        let profs = profiles(preset, n_workers, seed);
        let root = Rng::new(seed ^ 0xC1C0);
        let workers = profs
            .into_iter()
            .enumerate()
            .map(|(i, profile)| WorkerState {
                load: profile.load_mean,
                profile,
                rng: root.split(i as u64),
            })
            .collect();
        SimCluster {
            workers,
            cost: ComputeCostModel::default(),
            clock: 0.0,
            barrier_s: 0.002,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn profile(&self, w: usize) -> &WorkerProfile {
        &self.workers[w].profile
    }

    /// Largest batch that fits worker `w` for a model of `param_count`.
    pub fn max_batch(&self, w: usize, param_count: usize, cap: usize) -> usize {
        let mut hi = cap;
        while hi > 32 && !batch_fits(&self.workers[w].profile, param_count, hi) {
            hi -= 32;
        }
        hi
    }

    /// Simulate the compute phase of one BSP iteration.
    ///
    /// `batches[w]` is worker w's local batch size. Returns per-worker
    /// outcomes; does NOT advance the clock (the trainer combines compute
    /// with the netsim sync phase first).
    pub fn compute_phase(&mut self, batches: &[usize]) -> Vec<ComputeOutcome> {
        assert_eq!(batches.len(), self.workers.len());
        batches
            .iter()
            .zip(self.workers.iter_mut())
            .map(|(&b, ws)| {
                let effective_speed = ws.profile.speed * (1.0 - ws.load);
                let us =
                    self.cost.fixed_us + b as f64 * self.cost.base_us_per_sample / effective_speed.max(0.05);
                ComputeOutcome {
                    compute_s: us / 1e6,
                    load: ws.load,
                    effective_speed,
                }
            })
            .collect()
    }

    /// Advance the BSP clock by one iteration: slowest worker + sync +
    /// barrier; evolves every worker's load process by that span.
    pub fn advance_iteration(&mut self, outcomes: &[ComputeOutcome], sync_s: f64) -> f64 {
        let compute_max = outcomes
            .iter()
            .map(|o| o.compute_s)
            .fold(0.0f64, f64::max);
        let dt = compute_max + sync_s + self.barrier_s;
        for ws in &mut self.workers {
            ws.advance(dt);
        }
        self.clock += dt;
        dt
    }

    /// Reset clock + load processes (new episode), keeping profiles.
    pub fn reset(&mut self, seed: u64) {
        let root = Rng::new(seed ^ 0xC1C0);
        for (i, ws) in self.workers.iter_mut().enumerate() {
            ws.load = ws.profile.load_mean;
            ws.rng = root.split(i as u64);
        }
        self.clock = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_expected_structure() {
        let u = profiles(ClusterPreset::UniformA100, 4, 0);
        assert!(u.iter().all(|p| (p.speed - 1.0).abs() < 1e-9));
        let f = profiles(ClusterPreset::FabricHetero, 8, 0);
        assert!(f[0].speed > f[7].speed, "3090 should beat T4");
        assert_eq!(f.iter().filter(|p| p.speed > 0.5).count(), 4);
        let s1 = profiles(ClusterPreset::SpotMarket, 8, 1);
        let s2 = profiles(ClusterPreset::SpotMarket, 8, 1);
        assert!((s1[3].speed - s2[3].speed).abs() < 1e-12, "deterministic");
    }

    #[test]
    fn hetero_cluster_has_stragglers() {
        let mut c = SimCluster::new(ClusterPreset::FabricHetero, 8, 0);
        let out = c.compute_phase(&vec![128; 8]);
        let fast = out[0].compute_s;
        let slow = out[7].compute_s;
        assert!(slow > fast * 1.8, "T4 {slow} vs 3090 {fast}");
    }

    #[test]
    fn clock_advances_by_straggler() {
        let mut c = SimCluster::new(ClusterPreset::FabricHetero, 8, 0);
        let out = c.compute_phase(&vec![256; 8]);
        let max_c = out.iter().map(|o| o.compute_s).fold(0.0f64, f64::max);
        let dt = c.advance_iteration(&out, 0.01);
        assert!((dt - (max_c + 0.01 + c.barrier_s)).abs() < 1e-12);
        assert!((c.clock - dt).abs() < 1e-12);
    }

    #[test]
    fn load_process_stays_bounded_and_moves() {
        let mut c = SimCluster::new(ClusterPreset::SpotMarket, 4, 3);
        let mut loads = Vec::new();
        for _ in 0..500 {
            let out = c.compute_phase(&vec![64; 4]);
            loads.push(out[0].load);
            c.advance_iteration(&out, 0.001);
        }
        assert!(loads.iter().all(|&l| (0.0..=0.95).contains(&l)));
        let lo = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = loads.iter().cloned().fold(0.0f64, f64::max);
        assert!(hi - lo > 0.02, "load process frozen: [{lo},{hi}]");
    }

    #[test]
    fn memory_model_binds_on_t4_at_large_batch() {
        let t4 = WorkerProfile::t4();
        let a100 = WorkerProfile::a100();
        let pc = 10_000_000;
        assert!(batch_fits(&t4, pc, 64));
        assert!(!batch_fits(&t4, pc, 1024 + 256), "T4 should OOM above cap");
        assert!(batch_fits(&a100, pc, 1024));
    }

    #[test]
    fn max_batch_monotone_in_memory() {
        let c = SimCluster::new(ClusterPreset::FabricHetero, 8, 0);
        let pc = 10_000_000;
        let fast = c.max_batch(0, pc, 4096);
        let slow = c.max_batch(7, pc, 4096);
        assert!(fast >= slow);
        assert!(slow >= 32);
    }

    #[test]
    fn reset_restores_clock_and_determinism() {
        let mut c = SimCluster::new(ClusterPreset::OscA100, 4, 9);
        let o1: Vec<f64> = {
            let out = c.compute_phase(&vec![128; 4]);
            c.advance_iteration(&out, 0.0);
            out.iter().map(|o| o.compute_s).collect()
        };
        c.reset(9);
        assert_eq!(c.clock, 0.0);
        let o2: Vec<f64> = c.compute_phase(&vec![128; 4]).iter().map(|o| o.compute_s).collect();
        assert_eq!(o1, o2);
    }
}
