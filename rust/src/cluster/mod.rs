//! Heterogeneous-cluster simulator.
//!
//! Substitutes the paper's three physical testbeds (Lambda A100, OSC
//! A100-PCIE, FABRIC RTX3090/T4 — DESIGN.md substitution table). DYNAMIX's
//! decisions consume *relative* timing and contention signals, so the
//! simulator models exactly those:
//!
//! * per-worker **speed profile** (samples/sec at reference batch),
//!   calibrated so the 1.0 profile matches a measured real PJRT step;
//! * a **background-load process** per worker — an Ornstein–Uhlenbeck
//!   contention level in [0,1] plus Poisson bursts — standing in for
//!   multi-tenant/spot interference (paper §I, §II-B);
//! * a **memory model**: activation + parameter footprint per batch, used
//!   to refuse batch sizes that would OOM a worker (paper §IV-C
//!   "maintains hardware compatibility by avoiding memory overflows");
//! * the BSP **iteration clock**: per-iteration wall time is
//!   `max_i(compute_i) + sync + barrier`, the straggler structure that
//!   motivates the whole paper.

//! Dynamics live in `sim::process` (the unified [`DynamicsProcess`]
//! family) and the cluster additionally supports **elastic membership**
//! plus mid-run profile mutation (speed throttles, fabric-wide bandwidth
//! scaling, load-mean shifts) so `sim::scenario` scripts can pose the
//! dynamic environments the paper motivates but never simulates.

use crate::config::ClusterPreset;
use crate::sim::process::{ContentionProcess, DynamicsProcess, ProcessState};
use crate::util::rng::Rng;

/// Static capability description of one worker.
#[derive(Clone, Debug)]
pub struct WorkerProfile {
    /// Relative throughput multiplier (1.0 = reference GPU).
    pub speed: f64,
    /// Device memory in MiB (for the OOM rule).
    pub mem_mib: f64,
    /// NIC bandwidth in Gbit/s.
    pub bandwidth_gbps: f64,
    /// One-way link latency in ms.
    pub latency_ms: f64,
    /// OU contention parameters: mean level, reversion rate, volatility.
    pub load_mean: f64,
    pub load_rate: f64,
    pub load_vol: f64,
    /// Poisson burst rate (events per simulated second) and burst size.
    pub burst_rate: f64,
    pub burst_level: f64,
}

impl WorkerProfile {
    fn a100() -> Self {
        WorkerProfile {
            speed: 1.0,
            mem_mib: 24_000.0,
            bandwidth_gbps: 25.0,
            latency_ms: 0.15,
            load_mean: 0.05,
            load_rate: 0.5,
            load_vol: 0.05,
            burst_rate: 0.005,
            burst_level: 0.3,
        }
    }

    fn a100_osc() -> Self {
        // Shared HPC fabric: same GPU, more contention + latency.
        WorkerProfile {
            mem_mib: 40_000.0,
            latency_ms: 0.3,
            load_mean: 0.10,
            load_vol: 0.08,
            burst_rate: 0.01,
            ..Self::a100()
        }
    }

    fn rtx3090() -> Self {
        WorkerProfile {
            speed: 0.75,
            mem_mib: 24_000.0,
            bandwidth_gbps: 10.0,
            latency_ms: 1.0,
            load_mean: 0.12,
            load_rate: 0.4,
            load_vol: 0.1,
            burst_rate: 0.01,
            burst_level: 0.35,
        }
    }

    fn t4() -> Self {
        WorkerProfile {
            speed: 0.28,
            mem_mib: 16_000.0,
            bandwidth_gbps: 10.0,
            latency_ms: 1.2,
            load_mean: 0.15,
            load_rate: 0.4,
            load_vol: 0.12,
            burst_rate: 0.015,
            burst_level: 0.4,
        }
    }

    fn spot(rng: &mut Rng) -> Self {
        WorkerProfile {
            speed: rng.uniform_range(0.3, 1.2),
            mem_mib: 16_000.0,
            bandwidth_gbps: rng.uniform_range(5.0, 25.0),
            latency_ms: rng.uniform_range(0.2, 2.0),
            load_mean: rng.uniform_range(0.1, 0.3),
            load_rate: 0.3,
            load_vol: 0.15,
            burst_rate: 0.03,
            burst_level: 0.5,
        }
    }
}

/// Build the worker profile set for a preset.
pub fn profiles(preset: ClusterPreset, n_workers: usize, seed: u64) -> Vec<WorkerProfile> {
    let mut rng = Rng::new(seed ^ 0xC1A5);
    (0..n_workers)
        .map(|i| match preset {
            ClusterPreset::UniformA100 => WorkerProfile::a100(),
            ClusterPreset::OscA100 => WorkerProfile::a100_osc(),
            // FABRIC §VI-G: first half RTX3090, second half T4.
            ClusterPreset::FabricHetero => {
                if i < n_workers / 2 {
                    WorkerProfile::rtx3090()
                } else {
                    WorkerProfile::t4()
                }
            }
            ClusterPreset::SpotMarket => WorkerProfile::spot(&mut rng),
        })
        .collect()
}

/// Evolving state of one simulated worker.
#[derive(Clone, Debug)]
struct WorkerState {
    /// Current (possibly scenario-mutated) capability profile.
    profile: WorkerProfile,
    /// Pristine profile from construction; `reset` and the `factor = 1.0`
    /// scenario events restore against it.
    base: WorkerProfile,
    /// Background contention: OU level + Poisson bursts in [0, 0.95].
    load: ContentionProcess,
    /// Cluster membership (false while spot-preempted).
    active: bool,
}

impl WorkerState {
    fn new(profile: WorkerProfile, rng: Rng) -> Self {
        let load = ContentionProcess::new(
            profile.load_mean,
            profile.load_rate,
            profile.load_vol,
            profile.burst_rate,
            profile.burst_level,
            0.0,
            0.95,
            rng,
        );
        WorkerState {
            base: profile.clone(),
            profile,
            load,
            active: true,
        }
    }
}

/// Per-sample compute cost model, calibrated from real PJRT step timing.
///
/// `base_us_per_sample` is measured once on the reference profile (see
/// `trainer::calibrate`); everything else scales it.
#[derive(Clone, Copy, Debug)]
pub struct ComputeCostModel {
    pub base_us_per_sample: f64,
    /// Fixed per-iteration launch/framework overhead in microseconds.
    pub fixed_us: f64,
}

impl Default for ComputeCostModel {
    fn default() -> Self {
        // Representative of the mini models on the reference profile; the
        // trainer overwrites this with a measured value at startup.
        ComputeCostModel {
            base_us_per_sample: 120.0,
            fixed_us: 1_500.0,
        }
    }
}

/// Memory model: does `batch` fit on a worker? (paper §IV-C OOM rule)
///
/// footprint = params + optimizer state + activations(batch). Coefficients
/// reflect the full-size models the paper runs (so the 16 GiB T4 actually
/// binds at large batches, as it does in §VI-G).
pub fn batch_fits(profile: &WorkerProfile, param_count: usize, batch: usize) -> bool {
    let param_mib = (param_count * 4 * 3) as f64 / (1024.0 * 1024.0);
    // Full-size VGG-class activation footprint: ~12 MiB per sample.
    let act_mib = batch as f64 * 12.0;
    param_mib + act_mib < profile.mem_mib * 0.9
}

/// The simulated cluster: load processes + the BSP clock.
pub struct SimCluster {
    workers: Vec<WorkerState>,
    pub cost: ComputeCostModel,
    /// Simulated wall-clock (seconds since run start).
    pub clock: f64,
    /// Per-iteration barrier overhead (scheduler + kernel launch), seconds.
    pub barrier_s: f64,
}

/// Per-worker outcome of one simulated BSP iteration.
#[derive(Clone, Debug)]
pub struct ComputeOutcome {
    /// Compute seconds this worker spent on its local batch.
    pub compute_s: f64,
    /// Contention level during the iteration (feeds sysmetrics).
    pub load: f64,
    /// Effective speed (profile speed × (1 - load)).
    pub effective_speed: f64,
}

impl SimCluster {
    pub fn new(preset: ClusterPreset, n_workers: usize, seed: u64) -> Self {
        let profs = profiles(preset, n_workers, seed);
        let root = Rng::new(seed ^ 0xC1C0);
        let workers = profs
            .into_iter()
            .enumerate()
            .map(|(i, profile)| WorkerState::new(profile, root.split(i as u64)))
            .collect();
        SimCluster {
            workers,
            cost: ComputeCostModel::default(),
            clock: 0.0,
            barrier_s: 0.002,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn profile(&self, w: usize) -> &WorkerProfile {
        &self.workers[w].profile
    }

    // --- elastic membership (scenario preemption / rejoin) ---

    pub fn is_active(&self, w: usize) -> bool {
        self.workers[w].active
    }

    pub fn n_active(&self) -> usize {
        self.workers.iter().filter(|ws| ws.active).count()
    }

    /// Plain membership setter; callers (the trainer) enforce the
    /// never-empty-cluster rule.
    pub fn set_active(&mut self, w: usize, active: bool) {
        self.workers[w].active = active;
    }

    /// Membership mask, one flag per worker.
    pub fn active_mask(&self) -> Vec<bool> {
        self.workers.iter().map(|ws| ws.active).collect()
    }

    /// Profiles of the currently active workers (the netsim collective
    /// only spans machines that are actually present).
    pub fn active_profiles(&self) -> Vec<WorkerProfile> {
        self.workers
            .iter()
            .filter(|ws| ws.active)
            .map(|ws| ws.profile.clone())
            .collect()
    }

    // --- scenario-event mutators (relative to the base profile, so a
    //     factor of 1.0 always restores the pristine value) ---

    /// Scale worker `w`'s compute speed to `factor ×` its base speed.
    pub fn scale_speed(&mut self, w: usize, factor: f64) {
        let ws = &mut self.workers[w];
        ws.profile.speed = (ws.base.speed * factor.max(0.01)).max(1e-3);
    }

    /// Scale every worker's NIC bandwidth to `factor ×` its base value
    /// (fabric-wide event: oversubscription, link flap).
    pub fn scale_bandwidth_all(&mut self, factor: f64) {
        for ws in &mut self.workers {
            ws.profile.bandwidth_gbps = (ws.base.bandwidth_gbps * factor.max(0.01)).max(1e-3);
        }
    }

    /// Shift worker `w`'s background-load OU mean (tenant churn).
    pub fn set_load_mean(&mut self, w: usize, mean: f64) {
        self.workers[w].load.set_mean(mean);
        self.workers[w].profile.load_mean = mean.clamp(0.0, 0.95);
    }

    /// Largest batch that fits worker `w` for a model of `param_count`.
    pub fn max_batch(&self, w: usize, param_count: usize, cap: usize) -> usize {
        let mut hi = cap;
        while hi > 32 && !batch_fits(&self.workers[w].profile, param_count, hi) {
            hi -= 32;
        }
        hi
    }

    /// Simulate the compute phase of one BSP iteration.
    ///
    /// `batches[w]` is worker w's local batch size. Returns per-worker
    /// outcomes (a preempted worker costs nothing: `compute_s = 0`); does
    /// NOT advance the clock (the trainer combines compute with the netsim
    /// sync phase first).
    pub fn compute_phase(&mut self, batches: &[usize]) -> Vec<ComputeOutcome> {
        assert_eq!(batches.len(), self.workers.len());
        batches
            .iter()
            .zip(self.workers.iter_mut())
            .map(|(&b, ws)| {
                let load = ws.load.value();
                if !ws.active {
                    return ComputeOutcome {
                        compute_s: 0.0,
                        load,
                        effective_speed: 0.0,
                    };
                }
                let effective_speed = ws.profile.speed * (1.0 - load);
                let us =
                    self.cost.fixed_us + b as f64 * self.cost.base_us_per_sample / effective_speed.max(0.05);
                ComputeOutcome {
                    compute_s: us / 1e6,
                    load,
                    effective_speed,
                }
            })
            .collect()
    }

    /// Advance the BSP clock by one iteration: slowest worker + sync +
    /// barrier; evolves every worker's load process by that span (absent
    /// workers' background processes keep evolving — the machine is still
    /// busy, just not ours — which also keeps RNG streams aligned across
    /// membership histories).
    pub fn advance_iteration(&mut self, outcomes: &[ComputeOutcome], sync_s: f64) -> f64 {
        let compute_max = outcomes
            .iter()
            .map(|o| o.compute_s)
            .fold(0.0f64, f64::max);
        let dt = compute_max + sync_s + self.barrier_s;
        for ws in &mut self.workers {
            ws.load.advance(dt);
        }
        self.clock += dt;
        dt
    }

    /// Reset clock, membership, profiles and load processes (new episode).
    /// Scenario-mutated profiles restore to their pristine base.
    pub fn reset(&mut self, seed: u64) {
        let root = Rng::new(seed ^ 0xC1C0);
        for (i, ws) in self.workers.iter_mut().enumerate() {
            *ws = WorkerState::new(ws.base.clone(), root.split(i as u64));
        }
        self.clock = 0.0;
    }

    /// Checkpoint image: the sim clock plus every worker's membership,
    /// current + pristine profiles, and load-process state (including its
    /// RNG stream), so a restored cluster replays bit-for-bit.
    pub fn snapshot(&self) -> ClusterState {
        ClusterState {
            clock: self.clock,
            barrier_s: self.barrier_s,
            cost: self.cost,
            workers: self
                .workers
                .iter()
                .map(|ws| WorkerSnap {
                    active: ws.active,
                    profile: ws.profile.clone(),
                    base: ws.base.clone(),
                    load: ws.load.snapshot(),
                })
                .collect(),
        }
    }

    /// Restore from a [`SimCluster::snapshot`]; worker counts must match
    /// (the checkpoint header's fingerprint rejects a mismatched config
    /// before this is reached).
    pub fn restore(&mut self, s: &ClusterState) -> anyhow::Result<()> {
        anyhow::ensure!(
            s.workers.len() == self.workers.len(),
            "cluster snapshot has {} workers, this cluster has {}",
            s.workers.len(),
            self.workers.len()
        );
        self.clock = s.clock;
        self.barrier_s = s.barrier_s;
        self.cost = s.cost;
        for (ws, snap) in self.workers.iter_mut().zip(&s.workers) {
            ws.active = snap.active;
            ws.profile = snap.profile.clone();
            ws.base = snap.base.clone();
            ws.load.restore(&snap.load);
        }
        Ok(())
    }
}

/// Checkpoint image of one worker (see [`SimCluster::snapshot`]).
#[derive(Clone, Debug)]
pub struct WorkerSnap {
    pub active: bool,
    pub profile: WorkerProfile,
    pub base: WorkerProfile,
    pub load: ProcessState,
}

/// Checkpoint image of the whole simulated cluster.
#[derive(Clone, Debug)]
pub struct ClusterState {
    pub clock: f64,
    pub barrier_s: f64,
    pub cost: ComputeCostModel,
    pub workers: Vec<WorkerSnap>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_expected_structure() {
        let u = profiles(ClusterPreset::UniformA100, 4, 0);
        assert!(u.iter().all(|p| (p.speed - 1.0).abs() < 1e-9));
        let f = profiles(ClusterPreset::FabricHetero, 8, 0);
        assert!(f[0].speed > f[7].speed, "3090 should beat T4");
        assert_eq!(f.iter().filter(|p| p.speed > 0.5).count(), 4);
        let s1 = profiles(ClusterPreset::SpotMarket, 8, 1);
        let s2 = profiles(ClusterPreset::SpotMarket, 8, 1);
        assert!((s1[3].speed - s2[3].speed).abs() < 1e-12, "deterministic");
    }

    #[test]
    fn hetero_cluster_has_stragglers() {
        let mut c = SimCluster::new(ClusterPreset::FabricHetero, 8, 0);
        let out = c.compute_phase(&vec![128; 8]);
        let fast = out[0].compute_s;
        let slow = out[7].compute_s;
        assert!(slow > fast * 1.8, "T4 {slow} vs 3090 {fast}");
    }

    #[test]
    fn clock_advances_by_straggler() {
        let mut c = SimCluster::new(ClusterPreset::FabricHetero, 8, 0);
        let out = c.compute_phase(&vec![256; 8]);
        let max_c = out.iter().map(|o| o.compute_s).fold(0.0f64, f64::max);
        let dt = c.advance_iteration(&out, 0.01);
        assert!((dt - (max_c + 0.01 + c.barrier_s)).abs() < 1e-12);
        assert!((c.clock - dt).abs() < 1e-12);
    }

    #[test]
    fn load_process_stays_bounded_and_moves() {
        let mut c = SimCluster::new(ClusterPreset::SpotMarket, 4, 3);
        let mut loads = Vec::new();
        for _ in 0..500 {
            let out = c.compute_phase(&vec![64; 4]);
            loads.push(out[0].load);
            c.advance_iteration(&out, 0.001);
        }
        assert!(loads.iter().all(|&l| (0.0..=0.95).contains(&l)));
        let lo = loads.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = loads.iter().cloned().fold(0.0f64, f64::max);
        assert!(hi - lo > 0.02, "load process frozen: [{lo},{hi}]");
    }

    #[test]
    fn memory_model_binds_on_t4_at_large_batch() {
        let t4 = WorkerProfile::t4();
        let a100 = WorkerProfile::a100();
        let pc = 10_000_000;
        assert!(batch_fits(&t4, pc, 64));
        assert!(!batch_fits(&t4, pc, 1024 + 256), "T4 should OOM above cap");
        assert!(batch_fits(&a100, pc, 1024));
    }

    #[test]
    fn max_batch_monotone_in_memory() {
        let c = SimCluster::new(ClusterPreset::FabricHetero, 8, 0);
        let pc = 10_000_000;
        let fast = c.max_batch(0, pc, 4096);
        let slow = c.max_batch(7, pc, 4096);
        assert!(fast >= slow);
        assert!(slow >= 32);
    }

    #[test]
    fn reset_restores_clock_and_determinism() {
        let mut c = SimCluster::new(ClusterPreset::OscA100, 4, 9);
        let o1: Vec<f64> = {
            let out = c.compute_phase(&vec![128; 4]);
            c.advance_iteration(&out, 0.0);
            out.iter().map(|o| o.compute_s).collect()
        };
        c.reset(9);
        assert_eq!(c.clock, 0.0);
        let o2: Vec<f64> = c.compute_phase(&vec![128; 4]).iter().map(|o| o.compute_s).collect();
        assert_eq!(o1, o2);
    }

    #[test]
    fn preempted_worker_costs_nothing_and_rejoins() {
        let mut c = SimCluster::new(ClusterPreset::UniformA100, 4, 0);
        assert_eq!(c.n_active(), 4);
        c.set_active(2, false);
        assert_eq!(c.n_active(), 3);
        assert!(!c.is_active(2));
        let out = c.compute_phase(&vec![128; 4]);
        assert_eq!(out[2].compute_s, 0.0);
        assert_eq!(out[2].effective_speed, 0.0);
        assert!(out[0].compute_s > 0.0);
        assert_eq!(c.active_profiles().len(), 3);
        assert_eq!(c.active_mask(), vec![true, true, false, true]);
        c.set_active(2, true);
        let out = c.compute_phase(&vec![128; 4]);
        assert!(out[2].compute_s > 0.0, "rejoined worker computes again");
    }

    #[test]
    fn scale_speed_slows_compute_and_is_base_relative() {
        let mut c = SimCluster::new(ClusterPreset::UniformA100, 2, 0);
        let t0 = c.compute_phase(&vec![256; 2])[0].compute_s;
        c.scale_speed(0, 0.25);
        let t_slow = c.compute_phase(&vec![256; 2])[0].compute_s;
        assert!(t_slow > t0 * 2.0, "{t_slow} !> {t0}*2");
        // factor = 1.0 restores the pristine speed, not 0.25 * 0.25.
        c.scale_speed(0, 1.0);
        let t1 = c.compute_phase(&vec![256; 2])[0].compute_s;
        assert_eq!(t0, t1);
    }

    #[test]
    fn bandwidth_scaling_hits_every_profile_and_restores() {
        let mut c = SimCluster::new(ClusterPreset::UniformA100, 3, 0);
        let base = c.profile(1).bandwidth_gbps;
        c.scale_bandwidth_all(0.2);
        assert!((c.profile(1).bandwidth_gbps - base * 0.2).abs() < 1e-12);
        c.scale_bandwidth_all(1.0);
        assert_eq!(c.profile(1).bandwidth_gbps, base);
    }

    #[test]
    fn load_shift_moves_the_observed_load() {
        let mut c = SimCluster::new(ClusterPreset::UniformA100, 2, 1);
        c.set_load_mean(0, 0.7);
        let batches = vec![64; 2];
        let mut last = 0.0;
        for _ in 0..300 {
            let out = c.compute_phase(&batches);
            last = out[0].load;
            c.advance_iteration(&out, 0.0);
        }
        assert!(last > 0.4, "load did not climb toward shifted mean: {last}");
    }

    #[test]
    fn snapshot_restore_resumes_bitwise_with_mutations() {
        let mut c = SimCluster::new(ClusterPreset::SpotMarket, 4, 7);
        // Walk the load processes and mutate mid-run state.
        for _ in 0..25 {
            let out = c.compute_phase(&vec![96; 4]);
            c.advance_iteration(&out, 0.002);
        }
        c.scale_speed(1, 0.5);
        c.set_load_mean(2, 0.6);
        c.set_active(3, false);
        let snap = c.snapshot();
        let tail = |c: &mut SimCluster| -> Vec<u64> {
            let mut bits = Vec::new();
            for _ in 0..30 {
                let out = c.compute_phase(&vec![96; 4]);
                for o in &out {
                    bits.push(o.compute_s.to_bits());
                    bits.push(o.load.to_bits());
                }
                bits.push(c.advance_iteration(&out, 0.002).to_bits());
            }
            bits.push(c.clock.to_bits());
            bits
        };
        let want = tail(&mut c);
        // Restore over a freshly constructed cluster (the restore path).
        let mut r = SimCluster::new(ClusterPreset::SpotMarket, 4, 7);
        r.restore(&snap).unwrap();
        assert!(!r.is_active(3));
        assert_eq!(tail(&mut r), want);
        // Mismatched worker counts are rejected.
        let mut bad = SimCluster::new(ClusterPreset::SpotMarket, 3, 7);
        assert!(bad.restore(&snap).is_err());
    }

    #[test]
    fn reset_undoes_scenario_mutations() {
        let mut c = SimCluster::new(ClusterPreset::FabricHetero, 4, 0);
        let speed0 = c.profile(0).speed;
        let bw0 = c.profile(0).bandwidth_gbps;
        c.scale_speed(0, 0.1);
        c.scale_bandwidth_all(0.1);
        c.set_load_mean(0, 0.9);
        c.set_active(3, false);
        c.reset(0);
        assert_eq!(c.profile(0).speed, speed0);
        assert_eq!(c.profile(0).bandwidth_gbps, bw0);
        assert_eq!(c.profile(0).load_mean, WorkerProfile::rtx3090().load_mean);
        assert!(c.is_active(3));
    }
}
