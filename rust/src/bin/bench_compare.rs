//! `bench_compare` — print p50 deltas between the last two recorded runs
//! of each bench suite in `BENCH_native.json`, so perf regressions are
//! visible directly in PR output (`make bench-compare`).
//!
//! For every suite with >= 2 recorded runs, the latest run is compared
//! against the previous one, matching results by bench name. Output is a
//! fixed-width table plus a one-line verdict per suite; missing files or
//! suites with fewer than two runs are reported, never an error (the tool
//! is advisory by default — CI runs it after the bench smoke).
//!
//! `--gate <pct>` flips it to blocking: exit 1 when any suite's worst
//! p50 regression exceeds `<pct>` percent. Suites with fewer than two
//! recorded runs never trip the gate (there is nothing to compare), so
//! the gate only starts biting once a before/after pair exists — the
//! deterministic `overlap/bandwidth-sweep` suite is the first to qualify
//! (its simulated-timeline numbers reproduce exactly, so any nonzero
//! delta there is a cost-model change, not noise).

use dynamix::util::bench::out_path;
use dynamix::util::json::Json;
use std::collections::BTreeMap;

/// (bench name -> p50 seconds) plus run metadata, from one run record.
struct Run {
    note: String,
    git_rev: String,
    threads: usize,
    kernel: String,
    p50: BTreeMap<String, f64>,
}

fn parse_run(run: &Json) -> Run {
    let s = |k: &str| run.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    let mut p50 = BTreeMap::new();
    if let Some(results) = run.get("results").and_then(Json::as_arr) {
        for r in results {
            if let (Some(name), Some(v)) = (
                r.get("bench").and_then(Json::as_str),
                r.get("p50_s").and_then(Json::as_f64),
            ) {
                p50.insert(name.to_string(), v);
            }
        }
    }
    Run {
        note: s("note"),
        git_rev: s("git_rev"),
        threads: run.get("threads").and_then(Json::as_usize).unwrap_or(0),
        kernel: s("kernel"),
        p50,
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// `--gate <pct>` from argv, or `None` (advisory). Bad usage exits 2.
fn parse_gate() -> Option<f64> {
    let mut args = std::env::args().skip(1);
    let mut gate = None;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--gate" => match args.next().and_then(|v| v.parse::<f64>().ok()) {
                Some(pct) => gate = Some(pct),
                None => {
                    eprintln!("bench-compare: --gate needs a numeric percent, e.g. --gate 50");
                    std::process::exit(2);
                }
            },
            other => {
                eprintln!("bench-compare: unknown argument {other:?} (usage: bench_compare [--gate <pct>])");
                std::process::exit(2);
            }
        }
    }
    gate
}

fn main() {
    let gate = parse_gate();
    let path = out_path();
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(_) => {
            println!("bench-compare: no {} (run `make bench` first)", path.display());
            return;
        }
    };
    let root = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            println!("bench-compare: {} is not valid JSON: {e}", path.display());
            return;
        }
    };
    let runs = match root.get("runs").and_then(Json::as_arr) {
        Some(r) if !r.is_empty() => r,
        _ => {
            println!("bench-compare: {} has no recorded runs", path.display());
            return;
        }
    };

    // Group run indices by suite, preserving record order (append-only).
    let mut by_suite: BTreeMap<String, Vec<&Json>> = BTreeMap::new();
    for run in runs {
        let suite = run
            .get("suite")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        by_suite.entry(suite).or_default().push(run);
    }

    let mut worst_overall: Option<(f64, String)> = None;
    for (suite, runs) in &by_suite {
        if runs.len() < 2 {
            println!("suite {suite}: only {} recorded run(s), nothing to compare", runs.len());
            continue;
        }
        let prev = parse_run(runs[runs.len() - 2]);
        let last = parse_run(runs[runs.len() - 1]);
        println!(
            "suite {suite}: {} [{} t{} {}] -> {} [{} t{} {}]",
            prev.git_rev,
            if prev.note.is_empty() { "-" } else { &prev.note },
            prev.threads,
            if prev.kernel.is_empty() { "?" } else { &prev.kernel },
            last.git_rev,
            if last.note.is_empty() { "-" } else { &last.note },
            last.threads,
            if last.kernel.is_empty() { "?" } else { &last.kernel },
        );
        let mut worst: Option<(f64, String)> = None;
        for (name, &new_p50) in &last.p50 {
            match prev.p50.get(name) {
                Some(&old_p50) if old_p50 > 0.0 => {
                    let delta = 100.0 * (new_p50 - old_p50) / old_p50;
                    println!(
                        "  {name:<44} p50 {:>10} -> {:>10}  ({delta:+6.1}%)",
                        fmt_time(old_p50),
                        fmt_time(new_p50)
                    );
                    if worst.as_ref().map(|(w, _)| delta > *w).unwrap_or(true) {
                        worst = Some((delta, name.clone()));
                    }
                }
                _ => println!("  {name:<44} p50 {:>10} (new entry)", fmt_time(new_p50)),
            }
        }
        if let Some((delta, name)) = worst {
            println!("  worst delta: {delta:+.1}% on {name}");
            let qualified = format!("{suite}/{name}");
            if worst_overall.as_ref().map(|(w, _)| delta > *w).unwrap_or(true) {
                worst_overall = Some((delta, qualified));
            }
        }
        println!();
    }

    if let Some(gate_pct) = gate {
        match worst_overall {
            Some((delta, name)) if delta > gate_pct => {
                eprintln!(
                    "bench-compare: GATE FAILED — worst p50 regression {delta:+.1}% on {name} exceeds --gate {gate_pct}%"
                );
                std::process::exit(1);
            }
            Some((delta, name)) => println!(
                "bench-compare: gate ok — worst p50 delta {delta:+.1}% on {name} within --gate {gate_pct}%"
            ),
            None => println!("bench-compare: gate ok — no suite has two runs to compare yet"),
        }
    }
}
