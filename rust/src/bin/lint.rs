//! `dynamix-lint` — run the repo-native invariant catalogue (see
//! `dynamix::util::lint`) over `rust/{src,tests,benches}`.
//!
//! ```text
//! dynamix-lint [--root <crate dir>] [--format text|json] [--self-test]
//! ```
//!
//! Exit codes: 0 = clean, 1 = violations found (or self-test failures),
//! 2 = usage/IO error. `--self-test` runs every rule against its
//! embedded known-bad/known-good fixture pair instead of scanning the
//! tree — CI runs both.

use dynamix::util::lint;
use std::path::PathBuf;

struct Opts {
    root: PathBuf,
    json: bool,
    self_test: bool,
}

fn usage() -> ! {
    eprintln!("usage: dynamix-lint [--root <crate dir>] [--format text|json] [--self-test]");
    std::process::exit(2);
}

fn parse_args() -> Opts {
    let mut opts = Opts {
        root: PathBuf::from(env!("CARGO_MANIFEST_DIR")),
        json: false,
        self_test: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => opts.root = PathBuf::from(p),
                None => usage(),
            },
            "--format" => match args.next().as_deref() {
                Some("json") => opts.json = true,
                Some("text") => opts.json = false,
                _ => usage(),
            },
            "--self-test" => opts.self_test = true,
            "--help" | "-h" => usage(),
            _ => usage(),
        }
    }
    opts
}

fn main() {
    let opts = parse_args();

    if opts.self_test {
        let fails = lint::self_test();
        if fails.is_empty() {
            println!(
                "dynamix-lint self-test: all {} rules fire on their fixtures",
                lint::RULES.len()
            );
            return;
        }
        for f in &fails {
            eprintln!("self-test FAIL: {f}");
        }
        std::process::exit(1);
    }

    let (violations, files) = match lint::scan_tree(&opts.root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("dynamix-lint: scanning {}: {e}", opts.root.display());
            std::process::exit(2);
        }
    };

    if opts.json {
        println!("{}", lint::report_json(&violations, files));
    } else {
        for v in &violations {
            println!("{}", v.render());
        }
        println!(
            "dynamix-lint: {} file(s) scanned, {} violation(s)",
            files,
            violations.len()
        );
    }
    if !violations.is_empty() {
        std::process::exit(1);
    }
}
