//! Experiment harness: one regenerator per paper table/figure.
//!
//! Each function reproduces the *procedure* of one §VI experiment on the
//! simulated testbed and returns a JSON summary (also written under
//! `runs/`). The `examples/exp_*.rs` binaries are thin CLI wrappers.
//!
//! | fn                 | paper artifact |
//! |--------------------|----------------|
//! | [`fig2_baselines`] | Fig. 2 static-batch trajectories |
//! | [`fig3_rl_training`] | Fig. 3 cumulative-reward curves (+ policy snapshots) |
//! | [`fig4_fig5_inference`] | Fig. 4 accuracy trajectories, Fig. 5 batch adaptation |
//! | [`table1_scalability`] | Table I 8/16/32-node scalability |
//! | [`fig6_transfer`] | Fig. 6 policy transfer |
//! | [`byteps_integration`] | §VI-G parameter-server + heterogeneous GPUs |
//! | [`overhead_analysis`] | §VI-H decision-overhead study |
//! | [`fig7_dynamics`] | dynamic-environment scenarios (paper §I/§II-B motivation; beyond the paper's static testbeds) |

use crate::baselines::{run_baseline, GnsHeuristicPolicy, StaticPolicy};
use crate::config::{presets, ExperimentConfig, Scale};
use crate::coordinator::Coordinator;
use crate::metrics::RunRecord;
use crate::runtime::Backend;
use crate::sim::scenario::ScenarioScript;
use crate::util::json::Json;
use std::path::PathBuf;

/// Where run records land (`$DYNAMIX_RUNS` or `<repo>/runs`).
pub fn runs_dir() -> PathBuf {
    crate::config::env::runs_dir_override()
        .unwrap_or_else(|| PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/runs")))
}

fn save(json: &Json, rel: &str) -> anyhow::Result<PathBuf> {
    let path = runs_dir().join(rel);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, json.to_string())?;
    Ok(path)
}

/// Policy snapshot location for a preset (shared across harnesses).
pub fn policy_path(preset: &str) -> PathBuf {
    runs_dir().join("policies").join(format!("{preset}.theta.f32"))
}

/// Decision-cycle budget for an inference/baseline run at a given scale.
fn cycle_budget(cfg: &ExperimentConfig, scale: Scale) -> usize {
    match scale {
        Scale::Full => cfg.steps_per_episode * 2,
        Scale::Quick => cfg.steps_per_episode.min(30),
    }
}

// ---------------------------------------------------------------------------
// Fig. 2 — static batch baselines
// ---------------------------------------------------------------------------

/// Paper Fig. 2: convergence trajectories of BSP training under fixed
/// batch sizes. Sweeps the paper's (model, optimizer, batch) grid, several
/// seeds each; records every trajectory and the summary grid.
pub fn fig2_baselines(backend: Backend, scale: Scale) -> anyhow::Result<Json> {
    // (panel, preset, batch sizes) following Fig. 2a-2h.
    let grid: Vec<(&str, &str, Vec<usize>)> = vec![
        ("vgg11-sgd", "vgg11-sgd", vec![32, 64]),
        ("vgg11-adam", "vgg11-adam", vec![32, 64]),
        ("resnet34-sgd", "resnet34-sgd", vec![32, 64, 128, 256]),
    ];
    let seeds: &[u64] = match scale {
        Scale::Full => &[0, 1, 2],
        Scale::Quick => &[0],
    };
    let mut rows = Vec::new();
    for (panel, preset, batches) in grid {
        let base_cfg = presets::scaled(presets::by_name(preset)?, scale);
        for &b in &batches {
            for &seed in seeds {
                let mut cfg = base_cfg.clone();
                cfg.train.seed = seed;
                cfg.batch.initial = b;
                let mut record = RunRecord::new(&format!("fig2-{panel}-b{b}-s{seed}"));
                let mut policy = StaticPolicy(b);
                let cycles = cycle_budget(&cfg, scale);
                let s = run_baseline(&cfg, backend.clone(), &mut policy, cycles, &mut record)?;
                record
                    .save_json(&runs_dir().join("fig2").join(format!("{}.json", record.name)))?;
                println!(
                    "[fig2] {panel} b={b} seed={seed}: final={:.3} best={:.3} conv={:?} sim_t={:.0}s",
                    s.final_eval_acc, s.best_eval_acc, s.convergence_time, s.total_sim_time
                );
                rows.push(crate::jobj! {
                    "panel" => panel,
                    "batch" => b,
                    "seed" => seed as f64,
                    "final_acc" => s.final_eval_acc,
                    "best_acc" => s.best_eval_acc,
                    "conv_time" => s.convergence_time.unwrap_or(-1.0),
                    "sim_time" => s.total_sim_time,
                });
            }
        }
    }
    let out = crate::jobj! { "experiment" => "fig2", "rows" => Json::Arr(rows) };
    save(&out, "fig2/summary.json")?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig. 3 — RL agent training
// ---------------------------------------------------------------------------

/// Paper Fig. 3: train the PPO agent; record per-episode mean/median
/// cumulative rewards; snapshot the trained policy for Figs. 4-6.
/// `scenario` (CLI `--scenario`) trains under a scripted dynamic
/// environment, re-armed identically every episode.
pub fn fig3_rl_training(
    backend: Backend,
    preset: &str,
    scale: Scale,
    scenario: Option<ScenarioScript>,
) -> anyhow::Result<Json> {
    let mut cfg = presets::scaled(presets::by_name(preset)?, scale);
    cfg.scenario = scenario;
    cfg.validate()?;
    let cfg = cfg;
    let episodes = cfg.episodes;
    let mut coord = Coordinator::new(cfg, backend)?;
    let results = coord.train_rl(episodes)?;
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            println!(
                "[fig3:{preset}] ep {:2}: mean_R={:+.2} median_R={:+.2} acc={:.3} kl={:.4}",
                r.episode, r.mean_return, r.median_return, r.final_eval_acc, r.update.approx_kl
            );
            crate::jobj! {
                "episode" => r.episode,
                "mean_return" => r.mean_return,
                "median_return" => r.median_return,
                "final_train_acc" => r.final_train_acc,
                "final_eval_acc" => r.final_eval_acc,
                "sim_time" => r.sim_time,
                "entropy" => r.update.entropy as f64,
                "approx_kl" => r.update.approx_kl as f64,
            }
        })
        .collect();
    let ppath = policy_path(preset);
    std::fs::create_dir_all(ppath.parent().unwrap())?;
    coord.agent.save_theta(&ppath)?;
    let out = crate::jobj! {
        "experiment" => "fig3",
        "preset" => preset,
        "episodes" => Json::Arr(rows),
        "policy_file" => ppath.to_string_lossy().to_string(),
    };
    save(&out, &format!("fig3/{preset}.json"))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig. 4 + Fig. 5 — inference trajectories + batch adaptation dynamics
// ---------------------------------------------------------------------------

/// Paper Figs. 4/5: deploy the trained policy greedily, compare against
/// the two reference static baselines, and record the batch-size
/// adaptation trace (mean ± std across workers). `scenario` (CLI
/// `--scenario`) runs policy AND baselines under the identical scripted
/// timeline.
pub fn fig4_fig5_inference(
    backend: Backend,
    preset: &str,
    scale: Scale,
    scenario: Option<ScenarioScript>,
) -> anyhow::Result<Json> {
    let mut cfg = presets::scaled(presets::by_name(preset)?, scale);
    cfg.scenario = scenario;
    cfg.validate()?;
    let cfg = cfg;
    let cycles = cycle_budget(&cfg, scale);

    // DYNAMIX run (uses the fig3 policy snapshot; trains briefly if absent).
    let mut coord = Coordinator::new(cfg.clone(), backend.clone())?;
    let ppath = policy_path(preset);
    if ppath.exists() {
        coord.agent.load_theta_file(&ppath)?;
    } else {
        println!("[fig4:{preset}] no policy snapshot; training a short one");
        coord.train_rl(cfg.episodes.min(4))?;
    }
    let mut dyn_record = RunRecord::new(&format!("fig4-{preset}-dynamix"));
    let dyn_summary = coord.run_inference(cycles, &mut dyn_record)?;
    dyn_record.save_json(&runs_dir().join("fig4").join(format!("{}.json", dyn_record.name)))?;

    // Static baselines at the paper's reference batch sizes.
    let mut baseline_rows = Vec::new();
    for b in [32usize, 64] {
        let mut bcfg = cfg.clone();
        bcfg.batch.initial = b;
        let mut record = RunRecord::new(&format!("fig4-{preset}-static{b}"));
        let mut policy = StaticPolicy(b);
        let s = run_baseline(&bcfg, backend.clone(), &mut policy, cycles, &mut record)?;
        record.save_json(&runs_dir().join("fig4").join(format!("{}.json", record.name)))?;
        baseline_rows.push(crate::jobj! {
            "batch" => b,
            "final_acc" => s.final_eval_acc,
            "best_acc" => s.best_eval_acc,
            "conv_time" => s.convergence_time.unwrap_or(-1.0),
            "sim_time" => s.total_sim_time,
        });
        println!(
            "[fig4:{preset}] static-{b}: final={:.3} conv={:?}",
            s.final_eval_acc, s.convergence_time
        );
    }

    // Fig. 5 trace: per-cycle batch mean/std.
    let trace: Vec<Json> = dyn_summary
        .batch_trace
        .iter()
        .map(|(c, m, s)| crate::jobj! { "cycle" => *c, "mean" => *m, "std" => *s })
        .collect();

    println!(
        "[fig4:{preset}] DYNAMIX: final={:.3} best={:.3} conv={:?} sim_t={:.0}s",
        dyn_summary.final_eval_acc,
        dyn_summary.best_eval_acc,
        dyn_summary.convergence_time,
        dyn_summary.total_sim_time
    );

    let out = crate::jobj! {
        "experiment" => "fig4_fig5",
        "preset" => preset,
        "dynamix" => crate::jobj! {
            "final_acc" => dyn_summary.final_eval_acc,
            "best_acc" => dyn_summary.best_eval_acc,
            "conv_time" => dyn_summary.convergence_time.unwrap_or(-1.0),
            "sim_time" => dyn_summary.total_sim_time,
        },
        "static_baselines" => Json::Arr(baseline_rows),
        "batch_trace" => Json::Arr(trace),
    };
    save(&out, &format!("fig4/{preset}-summary.json"))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table I — scalability
// ---------------------------------------------------------------------------

/// Paper Table I: VGG16/CIFAR-10/SGD at 8/16/32 nodes on the OSC profile.
/// For each scale: best static config from a batch sweep vs DYNAMIX.
pub fn table1_scalability(backend: Backend, scale: Scale) -> anyhow::Result<Json> {
    let mut rows = Vec::new();
    for preset in ["scal-8", "scal-16", "scal-32"] {
        let cfg = presets::scaled(presets::by_name(preset)?, scale);
        let cycles = cycle_budget(&cfg, scale);

        // Static sweep (the paper reports the best per scale).
        let sweep: &[usize] = &[64, 128, 256];
        let mut best: Option<(usize, f64, f64)> = None; // (batch, acc, time)
        for &b in sweep {
            let mut bcfg = cfg.clone();
            bcfg.batch.initial = b;
            let mut record = RunRecord::new(&format!("table1-{preset}-static{b}"));
            let mut pol = StaticPolicy(b);
            let s = run_baseline(&bcfg, backend.clone(), &mut pol, cycles, &mut record)?;
            let time = s.convergence_time.unwrap_or(s.total_sim_time);
            println!(
                "[table1:{preset}] static-{b}: acc={:.3} time={:.0}s",
                s.final_eval_acc, time
            );
            let better = match best {
                None => true,
                Some((_, acc, t)) => {
                    s.final_eval_acc > acc + 0.01
                        || ((s.final_eval_acc - acc).abs() <= 0.01 && time < t)
                }
            };
            if better {
                best = Some((b, s.final_eval_acc, time));
            }
        }
        let (best_b, static_acc, static_time) = best.unwrap();

        // DYNAMIX: reuse the vgg16 transfer-source policy if present.
        let mut coord = Coordinator::new(cfg.clone(), backend.clone())?;
        let ppath = policy_path("transfer-vgg16-src");
        if ppath.exists() {
            coord.agent.load_theta_file(&ppath)?;
        } else {
            coord.train_rl(cfg.episodes.min(4))?;
        }
        let mut record = RunRecord::new(&format!("table1-{preset}-dynamix"));
        let s = coord.run_inference(cycles, &mut record)?;
        record.save_json(&runs_dir().join("table1").join(format!("{}.json", record.name)))?;
        let dyn_time = s.convergence_time.unwrap_or(s.total_sim_time);
        println!(
            "[table1:{preset}] DYNAMIX: acc={:.3} time={:.0}s (static best b={best_b} acc={static_acc:.3} time={static_time:.0}s)",
            s.best_eval_acc, dyn_time
        );
        rows.push(crate::jobj! {
            "nodes" => cfg.cluster.n_workers,
            "static_batch" => best_b,
            "static_acc" => static_acc,
            "static_time" => static_time,
            "dynamix_acc" => s.best_eval_acc,
            "dynamix_time" => dyn_time,
            "time_reduction" => 1.0 - dyn_time / static_time.max(1e-9),
        });
    }
    let out = crate::jobj! { "experiment" => "table1", "rows" => Json::Arr(rows) };
    save(&out, "table1/summary.json")?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig. 6 — policy transfer
// ---------------------------------------------------------------------------

/// Paper Fig. 6: train on the source architecture, deploy unchanged on the
/// deeper family member; compare with the target's tuned static baseline.
pub fn fig6_transfer(
    backend: Backend,
    src_preset: &str,
    dst_preset: &str,
    scale: Scale,
) -> anyhow::Result<Json> {
    // 1. source policy (train if fig3 didn't already).
    let src_cfg = presets::scaled(presets::by_name(src_preset)?, scale);
    let ppath = policy_path(src_preset);
    if !ppath.exists() {
        println!("[fig6] training source policy {src_preset}");
        let mut coord = Coordinator::new(src_cfg.clone(), backend.clone())?;
        coord.train_rl(src_cfg.episodes)?;
        std::fs::create_dir_all(ppath.parent().unwrap())?;
        coord.agent.save_theta(&ppath)?;
    }

    // 2. transferred inference on the destination model.
    let dst_cfg = presets::scaled(presets::by_name(dst_preset)?, scale);
    let cycles = cycle_budget(&dst_cfg, scale);
    let mut coord = Coordinator::new(dst_cfg.clone(), backend.clone())?;
    coord.agent.load_theta_file(&ppath)?;
    let mut record = RunRecord::new(&format!("fig6-{src_preset}-to-{dst_preset}"));
    let s = coord.run_inference(cycles, &mut record)?;
    record.save_json(&runs_dir().join("fig6").join(format!("{}.json", record.name)))?;
    let dyn_time = s.convergence_time.unwrap_or(s.total_sim_time);

    // 3. tuned static baseline on the destination.
    let mut best: Option<(usize, f64, f64)> = None;
    for &b in &[32usize, 64, 128] {
        let mut bcfg = dst_cfg.clone();
        bcfg.batch.initial = b;
        let mut rec = RunRecord::new(&format!("fig6-{dst_preset}-static{b}"));
        let mut pol = StaticPolicy(b);
        let bs = run_baseline(&bcfg, backend.clone(), &mut pol, cycles, &mut rec)?;
        let t = bs.convergence_time.unwrap_or(bs.total_sim_time);
        let better = match best {
            None => true,
            Some((_, acc, bt)) => {
                bs.final_eval_acc > acc + 0.01
                    || ((bs.final_eval_acc - acc).abs() <= 0.01 && t < bt)
            }
        };
        if better {
            best = Some((b, bs.final_eval_acc, t));
        }
    }
    let (bb, bacc, btime) = best.unwrap();
    println!(
        "[fig6] {src_preset}->{dst_preset}: transferred acc={:.3} time={:.0}s vs static-{bb} acc={bacc:.3} time={btime:.0}s",
        s.best_eval_acc, dyn_time
    );
    let out = crate::jobj! {
        "experiment" => "fig6",
        "source" => src_preset,
        "target" => dst_preset,
        "transferred_acc" => s.best_eval_acc,
        "transferred_time" => dyn_time,
        "static_batch" => bb,
        "static_acc" => bacc,
        "static_time" => btime,
    };
    save(&out, &format!("fig6/{src_preset}-to-{dst_preset}.json"))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// §VI-G — BytePS / parameter-server integration
// ---------------------------------------------------------------------------

/// Paper §VI-G: heterogeneous 8-GPU cluster (4 RTX3090-like + 4 T4-like)
/// under a parameter-server topology; static-64 vs DYNAMIX.
pub fn byteps_integration(backend: Backend, scale: Scale) -> anyhow::Result<Json> {
    let cfg = presets::scaled(presets::by_name("byteps-hetero")?, scale);
    let cycles = cycle_budget(&cfg, scale);

    let mut bcfg = cfg.clone();
    bcfg.batch.initial = 64;
    let mut record = RunRecord::new("byteps-static64");
    let mut pol = StaticPolicy(64);
    let base = run_baseline(&bcfg, backend.clone(), &mut pol, cycles, &mut record)?;
    record.save_json(&runs_dir().join("byteps").join("static64.json"))?;
    let base_time = base.convergence_time.unwrap_or(base.total_sim_time);

    let mut coord = Coordinator::new(cfg.clone(), backend.clone())?;
    let ppath = policy_path("byteps-hetero");
    if ppath.exists() {
        coord.agent.load_theta_file(&ppath)?;
    } else {
        coord.train_rl(cfg.episodes.min(6))?;
        std::fs::create_dir_all(ppath.parent().unwrap())?;
        coord.agent.save_theta(&ppath)?;
    }
    let mut drec = RunRecord::new("byteps-dynamix");
    let s = coord.run_inference(cycles, &mut drec)?;
    drec.save_json(&runs_dir().join("byteps").join("dynamix.json"))?;
    let dyn_time = s.convergence_time.unwrap_or(s.total_sim_time);

    println!(
        "[byteps] static-64 acc={:.3} t={:.0}s | DYNAMIX acc={:.3} t={:.0}s (Δacc={:+.1}pp, time {:+.0}%)",
        base.final_eval_acc,
        base_time,
        s.best_eval_acc,
        dyn_time,
        (s.best_eval_acc - base.final_eval_acc) * 100.0,
        (dyn_time / base_time.max(1e-9) - 1.0) * 100.0
    );
    let out = crate::jobj! {
        "experiment" => "byteps",
        "static_acc" => base.final_eval_acc,
        "static_time" => base_time,
        "dynamix_acc" => s.best_eval_acc,
        "dynamix_time" => dyn_time,
    };
    save(&out, "byteps/summary.json")?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// §VI-H — overhead analysis
// ---------------------------------------------------------------------------

/// Paper §VI-H: decision-making overhead (state aggregation + policy
/// inference + action distribution) as a fraction of iteration time.
/// Both sides are REAL wall-clock on this host.
pub fn overhead_analysis(backend: Backend, cycles: usize) -> anyhow::Result<Json> {
    let mut cfg = presets::by_name("vgg11-sgd")?;
    cfg.cluster.n_workers = 16;
    cfg.batch.initial = 128;
    let mut coord = Coordinator::new(cfg, backend)?;
    let mut record = RunRecord::new("overhead");
    coord.run_inference(cycles, &mut record)?;

    let exec_total = coord.trainer.runtime.exec_seconds_total;
    let exec_count = coord.trainer.runtime.exec_count.max(1);
    let infer: Vec<f64> = coord.agent.inference_seconds.clone();
    let (infer_mean, _) = crate::metrics::mean_std(&infer);
    let iter_mean = exec_total / exec_count as f64;
    // One decision per k iterations: amortize.
    let k = coord.cfg.rl.k as f64;
    let overhead_frac = infer_mean / (iter_mean * k);
    println!(
        "[overhead] iter={:.2}ms decision={:.3}ms amortized_overhead={:.4}% (n={})",
        iter_mean * 1e3,
        infer_mean * 1e3,
        overhead_frac * 100.0,
        infer.len()
    );
    let out = crate::jobj! {
        "experiment" => "overhead",
        "iter_mean_s" => iter_mean,
        "decision_mean_s" => infer_mean,
        "overhead_fraction" => overhead_frac,
        "decisions" => infer.len(),
    };
    save(&out, "overhead/summary.json")?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig. 7 (beyond the paper) — scripted dynamic-environment scenarios
// ---------------------------------------------------------------------------

/// Scenario catalogue the dynamics experiment sweeps: four distinct
/// dynamic environments, including spot preemption/rejoin — the cases the
/// paper motivates (§I, §II-B) but its static testbeds never pose.
pub const DYNAMICS_SCENARIOS: &[&str] = &[
    "preempt_rejoin",
    "bandwidth_collapse",
    "congestion_storm",
    "load_shift",
];

/// Dynamic-environment evaluation: the frozen RL policy vs static
/// baselines and the GNS heuristic, each under the IDENTICAL scripted
/// event timeline (same seed ⇒ bitwise-identical scenario traces; the
/// timeline is recorded in every run record). One row per scenario.
pub fn fig7_dynamics(backend: Backend, scale: Scale) -> anyhow::Result<Json> {
    let mut base = presets::scaled(presets::by_name("vgg11-sgd")?, scale);
    // 8 workers: enough for churn to hurt, cheap enough for the CI smoke
    // leg; the built-in scripts only target workers 0-3.
    base.cluster.n_workers = 8;

    // One frozen policy for every scenario (ISSUE: the policy is trained
    // once, then evaluated where static baselines break). Reuse the fig3
    // snapshot when present; otherwise train a short one, stationarily.
    let mut ppath = policy_path("vgg11-sgd");
    if !ppath.exists() {
        ppath = policy_path("fig7-dynamics");
        if !ppath.exists() {
            println!("[fig7] no policy snapshot; training a short one");
            let mut coord = Coordinator::new(base.clone(), backend.clone())?;
            coord.train_rl(base.episodes.min(2))?;
            std::fs::create_dir_all(ppath.parent().unwrap())?;
            coord.agent.save_theta(&ppath)?;
        }
    }

    let cycles = cycle_budget(&base, scale);
    let mut rows = Vec::new();
    for &scen in DYNAMICS_SCENARIOS {
        let script = ScenarioScript::by_name(scen)?;
        let mut cfg = base.clone();
        cfg.name = format!("fig7-{scen}");
        cfg.scenario = Some(script.clone());
        cfg.validate()?;

        // DYNAMIX: frozen policy, greedy actions.
        let mut coord = Coordinator::new(cfg.clone(), backend.clone())?;
        coord.agent.load_theta_file(&ppath)?;
        let mut drec = RunRecord::new(&format!("fig7-{scen}-dynamix"));
        let ds = coord.run_inference(cycles, &mut drec)?;
        drec.save_json(&runs_dir().join("fig7").join(format!("{}.json", drec.name)))?;
        let dyn_events = coord.trainer.events_applied.len();
        let dyn_time = ds.convergence_time.unwrap_or(ds.total_sim_time);

        // Static baselines under the identical timeline.
        let mut static_rows = Vec::new();
        for b in [64usize, 256] {
            let mut bcfg = cfg.clone();
            bcfg.batch.initial = b;
            let mut rec = RunRecord::new(&format!("fig7-{scen}-static{b}"));
            let mut pol = StaticPolicy(b);
            let s = run_baseline(&bcfg, backend.clone(), &mut pol, cycles, &mut rec)?;
            rec.save_json(&runs_dir().join("fig7").join(format!("{}.json", rec.name)))?;
            static_rows.push(crate::jobj! {
                "batch" => b,
                "final_acc" => s.final_eval_acc,
                "best_acc" => s.best_eval_acc,
                "conv_time" => s.convergence_time.unwrap_or(-1.0),
                "sim_time" => s.total_sim_time,
            });
        }

        // Strongest non-RL adaptive comparator.
        let mut grec = RunRecord::new(&format!("fig7-{scen}-gns"));
        let mut gns = GnsHeuristicPolicy::default();
        let gs = run_baseline(&cfg, backend.clone(), &mut gns, cycles, &mut grec)?;
        grec.save_json(&runs_dir().join("fig7").join(format!("{}.json", grec.name)))?;

        println!(
            "[fig7:{scen}] DYNAMIX acc={:.3} t={:.0}s ({} events) | gns acc={:.3} | static-64 see runs/",
            ds.best_eval_acc, dyn_time, dyn_events, gs.best_eval_acc
        );
        rows.push(crate::jobj! {
            "scenario" => scen,
            "events_fired" => dyn_events,
            "dynamix_acc" => ds.best_eval_acc,
            "dynamix_final_acc" => ds.final_eval_acc,
            "dynamix_time" => dyn_time,
            "dynamix_conv_time" => ds.convergence_time.unwrap_or(-1.0),
            "gns_acc" => gs.best_eval_acc,
            "gns_time" => gs.convergence_time.unwrap_or(gs.total_sim_time),
            "static" => Json::Arr(static_rows),
            "timeline" => script.to_json(),
        });
    }
    let out = crate::jobj! {
        "experiment" => "fig7_dynamics",
        "preset" => "vgg11-sgd",
        "n_workers" => 8usize,
        "scenarios" => Json::Arr(rows),
    };
    save(&out, "fig7/summary.json")?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamics_catalogue_is_valid_and_includes_churn() {
        assert!(DYNAMICS_SCENARIOS.len() >= 4);
        assert!(DYNAMICS_SCENARIOS.contains(&"preempt_rejoin"));
        for s in DYNAMICS_SCENARIOS {
            ScenarioScript::by_name(s).unwrap().validate(8).unwrap();
        }
    }

    #[test]
    fn cycle_budget_scales() {
        let cfg = presets::by_name("vgg11-sgd").unwrap();
        assert!(cycle_budget(&cfg, Scale::Quick) <= 30);
        assert_eq!(cycle_budget(&cfg, Scale::Full), cfg.steps_per_episode * 2);
    }

    #[test]
    fn policy_path_is_under_runs() {
        assert!(policy_path("x").to_string_lossy().contains("policies"));
    }
}
