//! Baseline batch-size policies (paper §VI-B + related-work heuristics).
//!
//! * [`StaticPolicy`]       — the paper's primary baseline: a fixed batch
//!   size for the whole run (Fig. 2, Table I "Static Batch Size").
//! * [`LinearScalingPolicy`] — Goyal et al. [9]: batch fixed at
//!   `base × n_workers` (the "scale the batch with the cluster" rule).
//! * [`SmithSchedulePolicy`] — Smith et al. [32]: increase the batch size
//!   at fixed milestones instead of decaying the learning rate.
//! * [`GnsHeuristicPolicy`]  — gradient-noise-scale heuristic: grow the
//!   batch when the measured gradient noise (σ_norm) is high, shrink when
//!   low — the strongest non-RL adaptive comparator we ablate against.
//!
//! All implement [`BatchPolicy`] over the same `BspTrainer`, so baseline
//! and DYNAMIX runs share every other moving part.

use crate::config::ExperimentConfig;
use crate::metrics::{mean_std_usize, ConvergenceDetector, RunRecord, TracePoint};
use crate::runtime::Backend;
use crate::sysmetrics::WindowSummary;
use crate::trainer::BspTrainer;

/// A non-RL batch-size controller, consulted every k iterations.
pub trait BatchPolicy {
    fn name(&self) -> String;

    /// Decide every worker's next batch size. `windows[w]` is worker w's
    /// just-finished k-iteration summary; `cycle` counts decision points.
    fn adjust(
        &mut self,
        cycle: usize,
        batches: &mut [usize],
        windows: &[WindowSummary],
        min: usize,
        max: usize,
    );
}

/// Fixed batch size (paper's static baseline).
pub struct StaticPolicy(pub usize);

impl BatchPolicy for StaticPolicy {
    fn name(&self) -> String {
        format!("static-{}", self.0)
    }

    fn adjust(&mut self, _c: usize, batches: &mut [usize], _w: &[WindowSummary], min: usize, max: usize) {
        let b = self.0.clamp(min, max);
        batches.iter_mut().for_each(|x| *x = b);
    }
}

/// Linear scaling rule: per-worker batch = base (global = base × N).
/// Kept distinct from Static for sweep labelling.
pub struct LinearScalingPolicy {
    pub base: usize,
}

impl BatchPolicy for LinearScalingPolicy {
    fn name(&self) -> String {
        format!("linear-scaling-{}", self.base)
    }

    fn adjust(&mut self, _c: usize, batches: &mut [usize], _w: &[WindowSummary], min: usize, max: usize) {
        let b = self.base.clamp(min, max);
        batches.iter_mut().for_each(|x| *x = b);
    }
}

/// Smith et al.: multiply batch by `factor` every `every` cycles.
pub struct SmithSchedulePolicy {
    pub initial: usize,
    pub factor: usize,
    pub every: usize,
}

impl BatchPolicy for SmithSchedulePolicy {
    fn name(&self) -> String {
        format!("smith-x{}-every{}", self.factor, self.every)
    }

    fn adjust(&mut self, cycle: usize, batches: &mut [usize], _w: &[WindowSummary], min: usize, max: usize) {
        let doublings = cycle / self.every.max(1);
        let b = (self.initial * self.factor.pow(doublings as u32)).clamp(min, max);
        batches.iter_mut().for_each(|x| *x = b);
    }
}

/// Gradient-noise-scale heuristic: σ_norm high -> gradients are noisy ->
/// a larger batch is statistically efficient; σ_norm low -> shrink to buy
/// more updates per epoch. Deadband avoids thrash.
pub struct GnsHeuristicPolicy {
    pub high: f64,
    pub low: f64,
    pub step: usize,
}

impl Default for GnsHeuristicPolicy {
    fn default() -> Self {
        GnsHeuristicPolicy {
            high: 1.05,
            low: 0.95,
            step: 64,
        }
    }
}

impl BatchPolicy for GnsHeuristicPolicy {
    fn name(&self) -> String {
        "gns-heuristic".into()
    }

    fn adjust(&mut self, _c: usize, batches: &mut [usize], windows: &[WindowSummary], min: usize, max: usize) {
        for (b, w) in batches.iter_mut().zip(windows) {
            if w.sigma_norm > self.high {
                *b = (*b + self.step).min(max);
            } else if w.sigma_norm < self.low {
                *b = b.saturating_sub(self.step).max(min);
            }
        }
    }
}

/// Summary of one baseline run (mirrors `InferenceSummary`).
#[derive(Clone, Debug)]
pub struct BaselineSummary {
    pub policy: String,
    pub final_eval_acc: f64,
    pub best_eval_acc: f64,
    pub convergence_time: Option<f64>,
    pub total_sim_time: f64,
    pub total_iters: usize,
}

/// Drive a [`BatchPolicy`] over a fresh trainer for `max_cycles` decision
/// cycles of `k` iterations, recording the trajectory exactly like the
/// DYNAMIX inference runner (so Fig. 2/4 overlays are apples-to-apples).
pub fn run_baseline(
    cfg: &ExperimentConfig,
    backend: Backend,
    policy: &mut dyn BatchPolicy,
    max_cycles: usize,
    record: &mut RunRecord,
) -> anyhow::Result<BaselineSummary> {
    let mut trainer = BspTrainer::new(cfg, backend)?;
    trainer.calibrate()?;
    trainer.reset_episode(cfg.train.seed, cfg.batch.initial)?;
    // Apply the policy's initial choice before the first iteration.
    let init_windows: Vec<WindowSummary> = vec![WindowSummary::default(); trainer.n_workers()];
    let mut batches = trainer.batches.clone();
    policy.adjust(0, &mut batches, &init_windows, cfg.batch.min, cfg.batch.max);
    trainer.batches = batches;

    let mut detector = ConvergenceDetector::new(cfg.train.target_acc, 2);
    let k = cfg.rl.k;
    let mut final_eval = 0.0;
    for cycle in 0..max_cycles {
        let mut last_acc = 0.0;
        let mut last_loss = 0.0;
        for _ in 0..k {
            let out = trainer.iterate()?;
            last_acc = out.acc;
            last_loss = out.loss;
        }
        let (_, eval_acc) = trainer.eval()?;
        final_eval = eval_acc;
        let windows: Vec<WindowSummary> =
            trainer.windows.iter_mut().map(|w| w.finish()).collect();
        // Trace statistics span the live membership only (scenario runs
        // can preempt workers mid-run; see `sim::scenario`).
        let (bm, bs) = mean_std_usize(&trainer.active_batches());
        record.push(TracePoint {
            iter: trainer.iter,
            sim_time: trainer.cluster.clock,
            train_acc: last_acc,
            eval_acc,
            loss: last_loss,
            batch_mean: bm,
            batch_std: bs,
            global_batch: trainer.global_batch(),
        });
        detector.observe(eval_acc, trainer.cluster.clock);
        if detector.converged() {
            break;
        }
        let mut batches = trainer.batches.clone();
        policy.adjust(cycle + 1, &mut batches, &windows, cfg.batch.min, cfg.batch.max);
        // Absent workers keep their frozen pre-preemption batch (the same
        // contract the coordinator enforces): only live workers take the
        // policy's new sizes.
        for w in 0..batches.len() {
            if trainer.is_active(w) {
                trainer.batches[w] = batches[w];
            }
        }
    }
    record.final_eval_acc = final_eval;
    record.convergence_time = detector.time();
    trainer.annotate_record(record);
    Ok(BaselineSummary {
        policy: policy.name(),
        final_eval_acc: final_eval,
        best_eval_acc: record.best_eval_acc(),
        convergence_time: detector.time(),
        total_sim_time: trainer.cluster.clock,
        total_iters: trainer.iter,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.cluster.n_workers = 4;
        c.batch.initial = 64;
        c.rl.k = 2;
        c
    }

    fn backend() -> Backend {
        crate::runtime::native_backend()
    }

    #[test]
    fn static_policy_pins_batches() {
        let mut p = StaticPolicy(128);
        let mut b = vec![64, 96, 32];
        p.adjust(3, &mut b, &[], 32, 1024);
        assert_eq!(b, vec![128; 3]);
        // Clamped when out of range.
        let mut p = StaticPolicy(4096);
        p.adjust(0, &mut b, &[], 32, 1024);
        assert_eq!(b, vec![1024; 3]);
    }

    #[test]
    fn smith_schedule_doubles_on_milestones() {
        let mut p = SmithSchedulePolicy { initial: 64, factor: 2, every: 3 };
        let mut b = vec![64];
        p.adjust(0, &mut b, &[], 32, 1024);
        assert_eq!(b[0], 64);
        p.adjust(3, &mut b, &[], 32, 1024);
        assert_eq!(b[0], 128);
        p.adjust(9, &mut b, &[], 32, 1024);
        assert_eq!(b[0], 512);
        p.adjust(90, &mut b, &[], 32, 1024);
        assert_eq!(b[0], 1024, "clamped at max");
    }

    #[test]
    fn gns_heuristic_tracks_noise() {
        let mut p = GnsHeuristicPolicy::default();
        let mut b = vec![128, 128];
        let noisy = WindowSummary { sigma_norm: 1.5, ..Default::default() };
        let quiet = WindowSummary { sigma_norm: 0.2, ..Default::default() };
        p.adjust(0, &mut b, &[noisy, quiet], 32, 1024);
        assert_eq!(b, vec![192, 64]);
        // Bounds hold under repeated pressure.
        for _ in 0..50 {
            let w = vec![
                WindowSummary { sigma_norm: 1.5, ..Default::default() },
                WindowSummary { sigma_norm: 0.2, ..Default::default() },
            ];
            p.adjust(0, &mut b, &w, 32, 1024);
        }
        assert_eq!(b, vec![1024, 32]);
    }

    #[test]
    fn run_baseline_end_to_end_records_trace() {
        let c = cfg();
        let mut record = RunRecord::new("static-64");
        let mut p = StaticPolicy(64);
        let s = run_baseline(&c, backend(), &mut p, 4, &mut record).unwrap();
        assert_eq!(s.policy, "static-64");
        assert_eq!(record.points.len(), 4);
        assert!(s.total_iters == 8, "4 cycles x k=2: {}", s.total_iters);
        assert!(record.points.windows(2).all(|w| w[0].sim_time < w[1].sim_time));
    }
}
