//! DYNAMIX: RL-based adaptive batch size optimization for distributed ML.
//!
//! Reproduction of Dai, He & Wang (cs.LG 2025). Three-layer stack:
//! this Rust crate is the L3 coordinator (RL arbitrator + BSP trainer +
//! cluster/network simulators); L2 is a JAX model zoo AOT-lowered to HLO
//! text; L1 is a set of Pallas kernels inside that HLO. Python never runs
//! at runtime — `runtime` loads `artifacts/*.hlo.txt` via PJRT.

pub mod util;
pub mod config;
pub mod runtime;
pub mod data;
pub mod cluster;
pub mod netsim;
pub mod sysmetrics;
pub mod comm;
pub mod rl;
pub mod trainer;
pub mod coordinator;
pub mod baselines;
pub mod metrics;
pub mod harness;
