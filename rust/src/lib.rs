//! DYNAMIX: RL-based adaptive batch size optimization for distributed ML.
//!
//! Reproduction of Dai, He & Wang (cs.LG 2025). Three-layer stack: this
//! Rust crate is the L3 coordinator (RL arbitrator + BSP trainer +
//! cluster/network simulators) over a pluggable compute seam
//! ([`runtime::ComputeBackend`]). The default **native** backend runs the
//! L1/L2 math (MLP zoo, PPO policy, grad stats) in pure Rust — no Python,
//! no artifacts. The optional **xla** backend (`backend-xla` feature)
//! executes the original JAX/Pallas AOT HLO artifacts via PJRT; Python is
//! compile-time only either way.

// Style: this crate favours explicit index loops in the numeric kernels
// and >7-arg step signatures that mirror the AOT artifact I/O contract.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod util;
pub mod sim;
pub mod config;
pub mod runtime;
pub mod data;
pub mod cluster;
pub mod netsim;
pub mod sysmetrics;
pub mod comm;
pub mod rl;
pub mod trainer;
pub mod ckpt;
pub mod coordinator;
pub mod baselines;
pub mod metrics;
pub mod harness;
