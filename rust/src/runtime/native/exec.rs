//! Multi-threaded execution layer for the native backend.
//!
//! A [`Pool`] decides how many worker threads a kernel may fan out over and
//! hands kernels a deterministic row partition. Threads are plain scoped
//! `std::thread` spawns (no external thread-pool crate: the build must stay
//! offline); each parallel region lives exactly as long as one kernel call,
//! so there is no queue, no channel and no shared mutable state — kernels
//! split their output buffer into disjoint row chunks and every thread owns
//! one chunk.
//!
//! Determinism: the partition is a pure function of the row count and the
//! configured thread count, and every kernel assigns each output row to
//! exactly one thread without changing any per-row summation order. Results
//! are therefore bitwise identical across runs *and* across
//! `DYNAMIX_THREADS` settings; only blocked-vs-scalar kernel differences
//! (lane-wise partial sums) introduce float-level (~1e-7) deviations.
//!
//! Sizing: `DYNAMIX_THREADS=N` pins the worker count; unset or invalid
//! falls back to `std::thread::available_parallelism`. Small problems run
//! sequentially — a scoped spawn costs ~10-50us, so fanning out only pays
//! above [`PAR_FLOP_CUTOFF`] of work.

/// Minimum approximate FLOP count of one kernel call before it is worth
/// spawning threads at all (a scoped spawn is ~10-50us; 1 MFLOP of matmul
/// is ~100-300us of single-core work).
pub const PAR_FLOP_CUTOFF: usize = 1 << 20;

/// Minimum rows handed to each thread (keeps chunks cache-friendly and
/// caps the thread count on small-M problems).
pub const MIN_ROWS_PER_THREAD: usize = 32;

/// Hard ceiling on the worker count (sanity clamp for absurd env values).
pub const MAX_THREADS: usize = 64;

/// Thread-count policy for native kernels. Cheap to copy around; owns no
/// threads (parallel regions are scoped per kernel call).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Default for Pool {
    fn default() -> Self {
        Self::from_env()
    }
}

impl Pool {
    /// Resolve the worker count from `DYNAMIX_THREADS`, falling back to the
    /// machine's available parallelism.
    pub fn from_env() -> Self {
        let threads = std::env::var("DYNAMIX_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            });
        Pool {
            threads: threads.min(MAX_THREADS),
        }
    }

    /// Fixed worker count (tests / explicit overrides).
    pub fn with_threads(threads: usize) -> Self {
        Pool {
            threads: threads.max(1).min(MAX_THREADS),
        }
    }

    /// Single-threaded pool (the scalar-reference execution mode).
    pub fn sequential() -> Self {
        Pool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Rows per chunk for an `m`-row problem whose per-row cost is roughly
    /// `row_flops` FLOPs. Returns `m` (one chunk — run sequentially, no
    /// spawn) when the problem is too small to amortize thread startup.
    /// Deterministic in (m, row_flops, threads) only.
    pub fn rows_per_chunk(&self, m: usize, row_flops: usize) -> usize {
        if self.threads <= 1 || m < 2 * MIN_ROWS_PER_THREAD {
            return m.max(1);
        }
        if m.saturating_mul(row_flops) < PAR_FLOP_CUTOFF {
            return m.max(1);
        }
        let chunks = self.threads.min(m / MIN_ROWS_PER_THREAD).max(1);
        (m + chunks - 1) / chunks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_pool_never_partitions() {
        let p = Pool::sequential();
        assert_eq!(p.threads(), 1);
        assert_eq!(p.rows_per_chunk(4096, 1 << 20), 4096);
    }

    #[test]
    fn small_problems_stay_sequential() {
        let p = Pool::with_threads(8);
        // Tiny row count.
        assert_eq!(p.rows_per_chunk(8, 1 << 20), 8);
        assert_eq!(p.rows_per_chunk(32, 1 << 20), 32);
        // Large row count but trivial per-row work.
        assert_eq!(p.rows_per_chunk(4096, 4), 4096);
    }

    #[test]
    fn large_problems_partition_deterministically() {
        let p = Pool::with_threads(4);
        let per = p.rows_per_chunk(4096, 2 * 128 * 64);
        assert_eq!(per, 1024);
        // Same inputs -> same partition.
        assert_eq!(per, p.rows_per_chunk(4096, 2 * 128 * 64));
        // Chunk floor: never hands a thread fewer than MIN_ROWS_PER_THREAD.
        let per = Pool::with_threads(64).rows_per_chunk(64, 1 << 20);
        assert!(per >= MIN_ROWS_PER_THREAD, "per={per}");
    }

    #[test]
    fn with_threads_clamps() {
        assert_eq!(Pool::with_threads(0).threads(), 1);
        assert_eq!(Pool::with_threads(10_000).threads(), MAX_THREADS);
    }
}
