//! Execution layer for the native backend: kernel-tier selection plus a
//! **persistent** worker pool.
//!
//! ## Kernel tiers
//!
//! [`KernelTier`] names the three kernel implementations in
//! [`super::linalg`], selected by `DYNAMIX_KERNEL=auto|scalar|blocked|simd`:
//!
//! * `scalar` — the plain reference triple loops. Always sequential; the
//!   numerical ground truth the other tiers are held to.
//! * `blocked` — cache-tiled, lane-unrolled portable kernels (the PR 2
//!   hot path), row-partitioned across the worker pool.
//! * `simd` — arch-gated AVX2/FMA intrinsics (`core::arch::x86_64` behind
//!   `is_x86_feature_detected!`). On hardware without AVX2+FMA — or on
//!   non-x86 targets — the request **resolves to `blocked`** (the portable
//!   fallback), so `DYNAMIX_KERNEL=simd` is safe everywhere.
//!
//! `auto` (or unset) picks the fastest supported tier. Every constructor
//! funnels through [`KernelTier::resolved`], so a [`Pool`] can only ever
//! hold a tier the current CPU can execute — the `unsafe` AVX2 dispatch in
//! `linalg` leans on exactly that invariant.
//!
//! Bit-parity contract: the reduce-sensitive kernels (`matmul_at`,
//! `col_sums`) fold rows sequentially per output element **in every tier**
//! (the simd tier uses mul+add, not FMA, for these), so the sharded data
//! plane's chained reduction stays bit-identical to the fused step under
//! every `DYNAMIX_KERNEL` value. Forward/input-grad kernels (`matmul_acc`,
//! `matmul_bt`) may use FMA and differ *across* tiers at float tolerance,
//! but are deterministic and batch-shape-independent *within* a tier.
//!
//! ## Persistent workers
//!
//! One process-wide [`WorkerSet`] of parked threads executes every parallel
//! region; kernels submit disjoint-chunk closures over a channel-style
//! queue and the calling thread runs the first chunk itself. This replaces
//! the per-call `std::thread::scope` spawns: a scoped spawn costs ~10-50us
//! per thread per kernel call, a queue hand-off well under a microsecond,
//! so the sequential cutoff drops ([`PAR_FLOP_CUTOFF`]) and small buckets
//! profit from threading too. `rust/benches/train_step.rs` prices the pool
//! against the old scoped-spawn strategy ([`run_scoped`]) and records the
//! delta in `BENCH_native.json`.
//!
//! `DYNAMIX_THREADS` and `DYNAMIX_KERNEL` are read **once per process**
//! (first [`Pool::global`] touch); every backend shares the same worker
//! set — including the sharded data plane's loopback shard threads, which
//! previously nested their own scoped spawns. Tests pin both axes with
//! [`Pool::with_config`], which never reads the environment.
//!
//! Determinism: a chunk plan is a pure function of (row count, per-row
//! cost, configured thread count); each output row belongs to exactly one
//! chunk and no per-row summation order depends on the plan, so results
//! are bitwise identical across `DYNAMIX_THREADS` settings and across
//! which physical worker executes which chunk.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Minimum approximate FLOP count of one kernel call before it fans out.
/// With persistent workers the hand-off is a queue push (no spawn), so the
/// cutoff sits 4x below the old scoped-spawn threshold of `1 << 20`.
pub const PAR_FLOP_CUTOFF: usize = 1 << 18;

/// Minimum rows handed to each chunk (keeps chunks cache-friendly and
/// caps the fan-out on small-M problems). Half the scoped-spawn era's 32:
/// cheap hand-offs make narrower chunks profitable.
pub const MIN_ROWS_PER_THREAD: usize = 16;

/// Hard ceiling on the configured thread count (sanity clamp for absurd
/// env values).
pub const MAX_THREADS: usize = 64;

/// Which kernel implementation the linalg entry points dispatch to.
/// See the module docs for the tier contract; construct via
/// [`KernelTier::parse`] / [`KernelTier::from_env`] or pass through
/// [`KernelTier::resolved`] so `Simd` is never held on unsupported
/// hardware.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Reference triple loops; sequential regardless of the thread count.
    Scalar,
    /// Cache-blocked, lane-unrolled portable kernels (threaded).
    Blocked,
    /// AVX2/FMA intrinsics (threaded); resolves to `Blocked` off-arch.
    Simd,
}

impl KernelTier {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Blocked => "blocked",
            KernelTier::Simd => "simd",
        }
    }

    /// Parse a `DYNAMIX_KERNEL` / `--kernel` value. `auto` (and the empty
    /// string) pick the fastest supported tier; `simd` resolves to its
    /// portable fallback when the CPU lacks AVX2+FMA.
    pub fn parse(s: &str) -> anyhow::Result<KernelTier> {
        match s {
            "scalar" => Ok(KernelTier::Scalar),
            "blocked" => Ok(KernelTier::Blocked),
            "simd" => Ok(KernelTier::Simd.resolved()),
            "auto" | "" => Ok(KernelTier::detect()),
            other => anyhow::bail!("unknown kernel tier {other:?} (auto|scalar|blocked|simd)"),
        }
    }

    /// Tier from `DYNAMIX_KERNEL`; unset, empty or invalid values fall
    /// back to `auto` (the CLI's `--kernel` validates loudly instead).
    pub fn from_env() -> KernelTier {
        match std::env::var("DYNAMIX_KERNEL") {
            Ok(v) => KernelTier::parse(v.trim()).unwrap_or_else(|_| KernelTier::detect()),
            Err(_) => KernelTier::detect(),
        }
    }

    /// The fastest tier this CPU supports (`auto`).
    pub fn detect() -> KernelTier {
        if simd_supported() {
            KernelTier::Simd
        } else {
            KernelTier::Blocked
        }
    }

    /// Downgrade `Simd` to `Blocked` when the CPU lacks AVX2+FMA. Every
    /// `Pool` constructor applies this, making the tier safe to dispatch
    /// on without re-checking CPU features per kernel call.
    pub fn resolved(self) -> KernelTier {
        if self == KernelTier::Simd && !simd_supported() {
            KernelTier::Blocked
        } else {
            self
        }
    }

    /// Every tier executable on this machine (parity suites iterate this:
    /// `[Scalar, Blocked]` plus `Simd` where supported).
    pub fn available() -> Vec<KernelTier> {
        let mut tiers = vec![KernelTier::Scalar, KernelTier::Blocked];
        if simd_supported() {
            tiers.push(KernelTier::Simd);
        }
        tiers
    }
}

/// Whether the `simd` tier's AVX2+FMA kernels can run on this CPU.
pub fn simd_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        return std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma");
    }
    #[allow(unreachable_code)]
    false
}

/// One queued parallel-region chunk: the closure plus the region's
/// completion latch.
struct Task {
    job: Box<dyn FnOnce() + Send + 'static>,
    sync: Arc<RegionSync>,
}

/// Completion latch of one parallel region: counts outstanding worker
/// chunks and records whether any of them panicked.
struct RegionSync {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl RegionSync {
    fn new(outstanding: usize) -> Self {
        RegionSync {
            remaining: Mutex::new(outstanding),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn finish(&self, panicked: bool) {
        if panicked {
            self.panicked.store(true, Ordering::Relaxed);
        }
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every outstanding chunk has finished (success or
    /// panic). Must return before the submitting frame unwinds — the
    /// chunks borrow its stack.
    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done.wait(rem).unwrap();
        }
    }

    fn any_panicked(&self) -> bool {
        self.panicked.load(Ordering::Relaxed)
    }
}

/// The process-wide set of persistent, parked kernel worker threads.
/// Spawned once (lazily) and never torn down — workers block on the queue
/// condvar between regions, costing nothing while idle.
pub struct WorkerSet {
    queue: Mutex<VecDeque<Task>>,
    ready: Condvar,
    workers: usize,
}

impl WorkerSet {
    /// Spawn `workers` parked threads (the calling thread of each parallel
    /// region always executes one chunk itself, so `configured - 1`).
    fn spawn(workers: usize) -> Arc<WorkerSet> {
        let set = Arc::new(WorkerSet {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            workers,
        });
        for i in 0..workers {
            let s = set.clone();
            std::thread::Builder::new()
                .name(format!("dynamix-kern-{i}"))
                .spawn(move || s.worker_loop())
                .expect("spawn kernel worker thread");
        }
        set
    }

    /// Physical worker threads (excluding region callers).
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    fn worker_loop(&self) {
        loop {
            let task = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(t) = q.pop_front() {
                        break t;
                    }
                    q = self.ready.wait(q).unwrap();
                }
            };
            let Task { job, sync } = task;
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            sync.finish(r.is_err());
        }
    }

    /// Execute `jobs` as one parallel region: the first job runs on the
    /// calling thread, the rest go to the parked workers. Blocks until
    /// every job has completed; a panicking job panics the caller *after*
    /// the region has fully drained (the jobs borrow the caller's stack).
    fn run<'scope, F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'scope,
    {
        debug_assert!(jobs.len() > 1, "single-job regions run inline");
        let sync = Arc::new(RegionSync::new(jobs.len() - 1));
        let mut it = jobs.into_iter();
        let first = it.next().expect("jobs is non-empty");
        {
            let mut q = self.queue.lock().unwrap();
            for job in it {
                let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(job);
                // SAFETY: erasing 'scope to 'static is sound because no
                // borrow inside `job` can outlive this call frame:
                // (1) every queued Task is executed exactly once by
                //     `worker_loop`, under `catch_unwind`, and signals
                //     `sync.finish()` on both the success and panic paths;
                // (2) the caller-run first chunk is also `catch_unwind`'d
                //     below, so control always reaches `sync.wait()` —
                //     `resume_unwind` happens strictly *after* the wait;
                // (3) `wait()` blocks until `remaining == 0`, i.e. until
                //     every job (and its borrows of the frame) is done;
                // (4) the queue never clones or leaks a Task, and
                //     `F: Send` bounds the cross-thread hand-off.
                let job: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(job) };
                q.push_back(Task { job, sync: sync.clone() });
            }
        }
        self.ready.notify_all();
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(first));
        sync.wait();
        match caller {
            Err(p) => std::panic::resume_unwind(p),
            Ok(()) if sync.any_panicked() => panic!("kernel worker chunk panicked"),
            Ok(()) => {}
        }
    }
}

/// Process-global execution configuration, read from the environment
/// exactly once (`DYNAMIX_THREADS`, `DYNAMIX_KERNEL`). Every
/// `Pool::global()` / `Pool::default()` site shares this — no per-site
/// env re-reads, no per-backend worker sets.
struct GlobalCfg {
    threads: usize,
    tier: KernelTier,
}

fn global_cfg() -> &'static GlobalCfg {
    static CFG: OnceLock<GlobalCfg> = OnceLock::new();
    CFG.get_or_init(|| GlobalCfg {
        threads: threads_from_env(),
        tier: KernelTier::from_env(),
    })
}

/// The process-wide resolved kernel tier (`DYNAMIX_KERNEL`, read once).
/// Exposed for pool-less hot paths — the wire codecs in `comm::wire`
/// dispatch their SIMD lanes on this without re-reading the environment.
pub fn global_tier() -> KernelTier {
    global_cfg().tier
}

fn threads_from_env() -> usize {
    std::env::var("DYNAMIX_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .min(MAX_THREADS)
}

/// The one worker set every pool in the process shares (lazily spawned;
/// sized from the global config so `DYNAMIX_THREADS=N` bounds the process
/// at `N-1` persistent workers plus the calling threads).
fn shared_workers() -> Arc<WorkerSet> {
    static WORKERS: OnceLock<Arc<WorkerSet>> = OnceLock::new();
    WORKERS
        .get_or_init(|| WorkerSet::spawn(global_cfg().threads.saturating_sub(1)))
        .clone()
}

/// Scoped-spawn execution baseline: the pre-pool strategy (one
/// `std::thread::scope` spawn per chunk per kernel call), kept **only** so
/// `benches/train_step.rs` can price the persistent pool against it.
/// Production kernels never call this.
pub fn run_scoped<F: FnOnce() + Send>(jobs: Vec<F>) {
    std::thread::scope(|s| {
        for j in jobs {
            s.spawn(j);
        }
    });
}

/// Kernel execution policy: the partition width (configured thread
/// count), the kernel tier, and a handle to the shared persistent
/// workers. Cheap to clone; owns no threads of its own.
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    tier: KernelTier,
    workers: Option<Arc<WorkerSet>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("threads", &self.threads)
            .field("tier", &self.tier)
            .field(
                "workers",
                &self.workers.as_ref().map(|w| w.worker_count()),
            )
            .finish()
    }
}

impl Default for Pool {
    fn default() -> Self {
        Self::global()
    }
}

impl Pool {
    /// The process-wide pool: `DYNAMIX_THREADS` + `DYNAMIX_KERNEL` read
    /// once (first call), one shared worker set for every backend. This is
    /// what backends constructed without explicit overrides use.
    pub fn global() -> Self {
        let cfg = global_cfg();
        Self::with_config(cfg.threads, cfg.tier)
    }

    /// Re-read the environment (uncached). Exists for the env-plumbing
    /// tests and the CLI docs; production paths share [`Pool::global`].
    pub fn from_env() -> Self {
        Self::with_config(threads_from_env(), KernelTier::from_env())
    }

    /// Pinned partition width, global kernel tier (tests that sweep the
    /// thread axis without touching the process environment).
    pub fn with_threads(threads: usize) -> Self {
        Self::with_config(threads, global_cfg().tier)
    }

    /// Pinned partition width *and* kernel tier — never reads the
    /// environment. The tier is [`KernelTier::resolved`] so requesting
    /// `Simd` on unsupported hardware gets the portable fallback. Pools
    /// that can never dispatch a parallel region (single partition, or
    /// the always-sequential scalar tier) skip the worker-set attachment,
    /// so e.g. a `--threads 1` shard-worker process spawns no idle
    /// kernel threads.
    pub fn with_config(threads: usize, tier: KernelTier) -> Self {
        let threads = threads.clamp(1, MAX_THREADS);
        let tier = tier.resolved();
        let workers = if threads > 1 && tier != KernelTier::Scalar {
            Some(shared_workers())
        } else {
            None
        };
        Pool { threads, tier, workers }
    }

    /// Single-threaded pool at the global kernel tier (compat wrappers,
    /// golden tests). Never partitions and never touches the worker set.
    pub fn sequential() -> Self {
        Pool {
            threads: 1,
            tier: global_cfg().tier,
            workers: None,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Execute the chunk closures as one parallel region on the shared
    /// persistent workers (caller runs the first chunk). Falls back to
    /// inline sequential execution for 0/1-job regions or when no workers
    /// exist (sequential pools, single-core machines) — same results
    /// either way, since chunks are disjoint by construction.
    pub fn run<'scope, F>(&self, mut jobs: Vec<F>)
    where
        F: FnOnce() + Send + 'scope,
    {
        match &self.workers {
            Some(ws) if jobs.len() > 1 && ws.worker_count() > 0 => ws.run(jobs),
            _ => {
                for j in jobs.drain(..) {
                    j();
                }
            }
        }
    }

    /// Rows per chunk for an `m`-row problem whose per-row cost is roughly
    /// `row_flops` FLOPs. Returns `m` (one chunk — run inline) when the
    /// problem is too small to be worth handing off. Deterministic in
    /// (m, row_flops, threads) only — never in the physical worker count.
    pub fn rows_per_chunk(&self, m: usize, row_flops: usize) -> usize {
        if self.threads <= 1 || m < 2 * MIN_ROWS_PER_THREAD {
            return m.max(1);
        }
        if m.saturating_mul(row_flops) < PAR_FLOP_CUTOFF {
            return m.max(1);
        }
        let chunks = self.threads.min(m / MIN_ROWS_PER_THREAD).max(1);
        (m + chunks - 1) / chunks
    }
}

/// A single-threaded, order-preserving executor for blocking transport
/// sends — the **comm lane** of the overlapped sharded backward.
///
/// Deliberately NOT part of the kernel [`WorkerSet`]: a stalled socket
/// write must never occupy a compute worker, and a single dedicated
/// thread is what preserves per-link send order (two lanes could reorder
/// frames on one TCP stream). Each [`crate::runtime::sharded`] backend
/// owns one lane; the thread parks between sends and exits when the lane
/// drops, after flushing every queued job.
pub struct CommLane {
    shared: Arc<LaneShared>,
    handle: Option<std::thread::JoinHandle<()>>,
}

struct LaneShared {
    state: Mutex<LaneState>,
    /// Signals the lane thread: new job or stop requested.
    ready: Condvar,
    /// Signals drainers: queue empty and nothing in flight.
    idle: Condvar,
}

struct LaneState {
    jobs: VecDeque<Box<dyn FnOnce() -> anyhow::Result<()> + Send + 'static>>,
    in_flight: bool,
    stop: bool,
    /// First failure since the last drain (later sends still run; the
    /// receiver side surfaces its own error with step/bucket context).
    failed: Option<String>,
}

impl Default for CommLane {
    fn default() -> Self {
        Self::new()
    }
}

impl CommLane {
    pub fn new() -> Self {
        let shared = Arc::new(LaneShared {
            state: Mutex::new(LaneState {
                jobs: VecDeque::new(),
                in_flight: false,
                stop: false,
                failed: None,
            }),
            ready: Condvar::new(),
            idle: Condvar::new(),
        });
        let s = shared.clone();
        let handle = std::thread::Builder::new()
            .name("dynamix-comm".into())
            .spawn(move || s.lane_loop())
            .expect("spawn comm lane thread");
        CommLane { shared, handle: Some(handle) }
    }

    /// Queue one send. Jobs execute strictly in submission order on the
    /// lane thread; failures are recorded and surfaced by [`Self::drain`].
    pub fn submit(&self, job: impl FnOnce() -> anyhow::Result<()> + Send + 'static) {
        let mut st = self.shared.state.lock().unwrap();
        st.jobs.push_back(Box::new(job));
        drop(st);
        self.shared.ready.notify_one();
    }

    /// Block until every queued job has executed, then report the first
    /// failure recorded since the previous drain (if any).
    pub fn drain(&self) -> anyhow::Result<()> {
        let mut st = self.shared.state.lock().unwrap();
        while !st.jobs.is_empty() || st.in_flight {
            st = self.shared.idle.wait(st).unwrap();
        }
        match st.failed.take() {
            Some(e) => anyhow::bail!("comm lane send failed: {e}"),
            None => Ok(()),
        }
    }
}

impl Drop for CommLane {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.stop = true;
        }
        self.shared.ready.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl LaneShared {
    fn lane_loop(&self) {
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if let Some(j) = st.jobs.pop_front() {
                        st.in_flight = true;
                        break j;
                    }
                    if st.stop {
                        return; // queue flushed; lane retires
                    }
                    st = self.ready.wait(st).unwrap();
                }
            };
            // A panicking send must not kill the lane (drain would hang);
            // record it like a send error.
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            let mut st = self.state.lock().unwrap();
            match r {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if st.failed.is_none() {
                        st.failed = Some(format!("{e:#}"));
                    }
                }
                Err(_) => {
                    if st.failed.is_none() {
                        st.failed = Some("send job panicked".into());
                    }
                }
            }
            st.in_flight = false;
            if st.jobs.is_empty() {
                self.idle.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn comm_lane_runs_jobs_in_order_and_reports_first_error() {
        let lane = CommLane::new();
        let seen = Arc::new(Mutex::new(Vec::new()));
        for i in 0..32 {
            let seen = seen.clone();
            lane.submit(move || {
                seen.lock().unwrap().push(i);
                Ok(())
            });
        }
        lane.drain().unwrap();
        assert_eq!(*seen.lock().unwrap(), (0..32).collect::<Vec<_>>());

        // First failure wins; later jobs still run; drain clears the slate.
        let ran_after = Arc::new(AtomicBool::new(false));
        lane.submit(|| anyhow::bail!("link down"));
        lane.submit(|| anyhow::bail!("second failure"));
        let flag = ran_after.clone();
        lane.submit(move || {
            flag.store(true, Ordering::SeqCst);
            Ok(())
        });
        let err = lane.drain().unwrap_err().to_string();
        assert!(err.contains("link down"), "{err}");
        assert!(ran_after.load(Ordering::SeqCst));
        lane.drain().unwrap();

        // A panicking job is contained and surfaced as a failure.
        lane.submit(|| panic!("boom"));
        let err = lane.drain().unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        drop(lane); // join must not hang
    }

    #[test]
    fn sequential_pool_never_partitions() {
        let p = Pool::sequential();
        assert_eq!(p.threads(), 1);
        assert_eq!(p.rows_per_chunk(4096, 1 << 20), 4096);
    }

    #[test]
    fn small_problems_stay_sequential() {
        let p = Pool::with_threads(8);
        // Tiny row count: below 2 * MIN_ROWS_PER_THREAD.
        assert_eq!(p.rows_per_chunk(8, 1 << 20), 8);
        assert_eq!(p.rows_per_chunk(2 * MIN_ROWS_PER_THREAD - 1, 1 << 20), 31);
        // Large row count but trivial per-row work.
        assert_eq!(p.rows_per_chunk(4096, 4), 4096);
        // The persistent pool's cutoff sits below the old 1 MFLOP spawn
        // threshold: a 32-row, 8 KFLOP/row problem (256 KFLOP) now fans out.
        assert_eq!(p.rows_per_chunk(32, 1 << 13), 16);
    }

    #[test]
    fn large_problems_partition_deterministically() {
        let p = Pool::with_threads(4);
        let per = p.rows_per_chunk(4096, 2 * 128 * 64);
        assert_eq!(per, 1024);
        // Same inputs -> same partition.
        assert_eq!(per, p.rows_per_chunk(4096, 2 * 128 * 64));
        // Chunk floor: never hands a chunk fewer than MIN_ROWS_PER_THREAD.
        let per = Pool::with_threads(64).rows_per_chunk(64, 1 << 20);
        assert!(per >= MIN_ROWS_PER_THREAD, "per={per}");
    }

    #[test]
    fn with_threads_clamps() {
        assert_eq!(Pool::with_threads(0).threads(), 1);
        assert_eq!(Pool::with_threads(10_000).threads(), MAX_THREADS);
    }

    #[test]
    fn run_executes_every_job_exactly_once() {
        // Exercised through the shared persistent workers when present.
        let hits = AtomicUsize::new(0);
        let p = Pool::with_threads(4);
        p.run(
            (0..7)
                .map(|_| || {
                    hits.fetch_add(1, Ordering::SeqCst);
                })
                .collect(),
        );
        assert_eq!(hits.load(Ordering::SeqCst), 7);
        // Regions are reusable back to back (workers park between).
        p.run(
            (0..3)
                .map(|_| || {
                    hits.fetch_add(1, Ordering::SeqCst);
                })
                .collect(),
        );
        assert_eq!(hits.load(Ordering::SeqCst), 10);
        // Empty and single-job regions run inline.
        p.run(Vec::<fn()>::new());
        Pool::sequential().run(vec![|| {
            hits.fetch_add(1, Ordering::SeqCst);
        }]);
        assert_eq!(hits.load(Ordering::SeqCst), 11);
    }

    #[test]
    fn run_borrows_caller_stack_mutably() {
        // The whole point of the region latch: chunks may borrow
        // stack-local buffers, disjointly, like the kernels do.
        let mut buf = vec![0u32; 64];
        let p = Pool::with_threads(4);
        p.run(
            buf.chunks_mut(16)
                .enumerate()
                .map(|(i, c)| {
                    move || {
                        for v in c.iter_mut() {
                            *v = i as u32 + 1;
                        }
                    }
                })
                .collect(),
        );
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, (i / 16) as u32 + 1);
        }
    }

    #[test]
    fn global_pool_is_shared_and_reads_env_once() {
        // Two global handles agree on config and on worker attachment;
        // when attached, they share one worker set (pointer-identical
        // Arc) — the per-site env re-read is gone.
        let a = Pool::global();
        let b = Pool::default();
        assert_eq!(a.threads(), b.threads());
        assert_eq!(a.tier(), b.tier());
        match (&a.workers, &b.workers) {
            (Some(wa), Some(wb)) => {
                assert!(Arc::ptr_eq(wa, wb), "global pools must share one WorkerSet")
            }
            (None, None) => {}
            _ => panic!("global pools must agree on worker attachment"),
        }
        // Pinned multi-thread pools at a threaded tier share the same
        // physical workers; degenerate configs attach none.
        let c = Pool::with_config(7, KernelTier::Blocked);
        assert_eq!(c.threads(), 7, "partition width is the pinned value");
        let cw = c.workers.as_ref().expect("threaded pool attaches workers");
        if let Some(wa) = &a.workers {
            assert!(Arc::ptr_eq(wa, cw), "pinned pools share the process workers");
        }
        assert!(Pool::with_config(1, KernelTier::Blocked).workers.is_none());
        assert!(Pool::with_config(8, KernelTier::Scalar).workers.is_none());
        assert!(Pool::sequential().workers.is_none());
    }

    #[test]
    fn tier_parse_and_resolution() {
        assert_eq!(KernelTier::parse("scalar").unwrap(), KernelTier::Scalar);
        assert_eq!(KernelTier::parse("blocked").unwrap(), KernelTier::Blocked);
        assert!(KernelTier::parse("avx512").is_err());
        // auto and simd both resolve to something executable here.
        let auto = KernelTier::parse("auto").unwrap();
        let simd = KernelTier::parse("simd").unwrap();
        assert_ne!(auto, KernelTier::Scalar);
        if simd_supported() {
            assert_eq!(simd, KernelTier::Simd);
            assert_eq!(auto, KernelTier::Simd);
        } else {
            assert_eq!(simd, KernelTier::Blocked);
            assert_eq!(auto, KernelTier::Blocked);
        }
        // with_config can never hold an unexecutable tier.
        let p = Pool::with_config(2, KernelTier::Simd);
        assert_eq!(p.tier(), KernelTier::Simd.resolved());
        // available() always contains the resolved tiers.
        let avail = KernelTier::available();
        assert!(avail.contains(&KernelTier::Scalar));
        assert!(avail.contains(&KernelTier::Blocked));
        assert_eq!(avail.contains(&KernelTier::Simd), simd_supported());
    }

    #[test]
    #[should_panic(expected = "kernel worker chunk panicked")]
    fn worker_panic_propagates_after_drain() {
        let p = Pool::with_config(4, KernelTier::Blocked);
        if p.workers.as_ref().unwrap().worker_count() == 0 {
            // Single-core machine: jobs would run inline; raise the
            // expected message directly so the harness still passes.
            panic!("kernel worker chunk panicked");
        }
        // First job (caller-run) succeeds; a worker job panics.
        p.run(vec![
            Box::new(|| {}) as Box<dyn FnOnce() + Send>,
            Box::new(|| panic!("boom")),
        ]);
    }
}
