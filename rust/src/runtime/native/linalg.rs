//! Dense linear algebra for the native backend: three kernel tiers behind
//! one dispatch seam, row-partitioned across the persistent worker pool.
//!
//! Shapes follow the JAX convention used by `python/compile`: activations
//! are `[M, K]` row-major, weights `[K, N]` row-major (`fan_in` rows). The
//! three multiply kernels cover forward (`x @ w`), input gradients
//! (`dy @ w^T`) and weight gradients (`x^T @ dy`).
//!
//! ## Tiers (see [`super::exec::KernelTier`])
//!
//! * [`scalar`] — the reference triple loops: no tiling, no unrolling, no
//!   threading, no sparsity skips. Numerical ground truth.
//! * `blocked` — cache-tiled ([`TILE_I`]/[`TILE_K`]), [`LANE`]-unrolled
//!   portable kernels with a row-level all-zero skip (padded/masked rows
//!   cost one O(len) scan instead of O(len*n) multiply-adds).
//! * `simd` — AVX2/FMA intrinsics with the same blocking structure,
//!   reached only through a [`KernelTier::resolved`] tier (so the
//!   `unsafe` feature-gated calls are sound by construction).
//!
//! ## Bit-parity rules
//!
//! The **reduce-sensitive** kernels fold the batch dimension sequentially
//! per output element in *every* tier:
//!
//! * [`matmul_at`] — each `dw[kk,j]` accumulates rows `i = 0..m` in order,
//!   one `mul`+`add` rounding pair per step; the simd tier deliberately
//!   avoids FMA here so all three tiers produce **identical bits**.
//! * [`col_sums`] — parallelism partitions output *columns*; each
//!   element's row fold stays sequential in every tier.
//!
//! The **elementwise layer** ([`relu`]/[`tanh`] + backwards, [`add_bias`],
//! [`log_softmax`]) and the **pooled optimizer apply** ([`sgd_apply`],
//! [`adam_apply`]) are order-free per element, so every tier, chunk plan
//! and thread count is BITWISE identical to the scalar reference: the simd
//! lanes use only correctly-rounded ops (no FMA contraction), libm-bound
//! ops (`tanh`, `exp`, `ln`) stay scalar per element and parallelize at
//! chunk/row granularity only, and per-row folds (`log_softmax`'s
//! log-sum-exp) never split a row.
//!
//! This is what lets the sharded data plane chain shard backwards through
//! a traveling accumulator and reproduce the fused gradient bit for bit
//! under any `DYNAMIX_KERNEL` setting (`tests/sharded_parity.rs`).
//!
//! The forward/input-grad kernels ([`matmul_acc`], [`matmul_bt`]) are
//! per-row independent — a row's value never depends on the batch size or
//! the chunk plan — but *across* tiers they may differ at float tolerance
//! (the simd tier uses FMA; the packed-panel `bt` folds `j` in a different
//! association), which the parity suite pins to 1e-5 of scalar.
//!
//! ## Packed panels
//!
//! `matmul_bt`'s weight operand is walked row-by-row as a dot product; the
//! workspace-backed entry point [`matmul_bt_ws`] instead packs `w` into a
//! k-major `[N, K]` panel (cached per generation in
//! [`super::workspace::PanelCache`]) and streams it as an axpy
//! accumulation — contiguous loads, no horizontal reductions, and the
//! panel is reused for every use within a step and invalidated by the
//! next step's generation bump (optimizer updates change `w`).

use super::exec::{KernelTier, Pool};
use super::workspace::PanelCache;

/// Unroll width of the innermost (column) loops. 8 f32 lanes = one AVX2
/// register / two NEON registers; LLVM vectorizes the fixed-size bodies.
pub const LANE: usize = 8;

/// Row-block size of `matmul_acc` (output rows revisited per `w` slab).
pub const TILE_I: usize = 32;

/// Reduction-block size of `matmul_acc`: a `TILE_K x n` slab of `w` is
/// `64*n*4` bytes — L1-resident for every zoo width.
pub const TILE_K: usize = 64;

#[inline]
fn row_all_zero(row: &[f32]) -> bool {
    // Dense rows exit on the first element; padded rows cost one O(len)
    // scan in exchange for skipping O(len * n) multiply-adds.
    row.iter().all(|&v| v == 0.0)
}

/// Scalar reference kernels: the straightforward triple loops, kept as the
/// numerical ground truth for parity tests and for documenting intent.
/// No tiling, no unrolling, no threading, no sparsity skips.
pub mod scalar {
    /// `out[M,N] += x[M,K] @ w[K,N]`.
    pub fn matmul_acc(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in xrow.iter().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * wrow[j];
                }
            }
        }
    }

    /// `dx[M,K] = dy[M,N] @ w[K,N]^T` (overwrites `dx`).
    pub fn matmul_bt(dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
        for i in 0..m {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            for kk in 0..k {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut s = 0.0f32;
                for j in 0..n {
                    s += dyrow[j] * wrow[j];
                }
                dxrow[kk] = s;
            }
        }
    }

    /// `dw[K,N] += x[M,K]^T @ dy[M,N]` (accumulates).
    pub fn matmul_at(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let dyrow = &dy[i * n..(i + 1) * n];
            for (kk, &a) in xrow.iter().enumerate() {
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                for j in 0..n {
                    dwrow[j] += a * dyrow[j];
                }
            }
        }
    }

    // --- elementwise / activation references -----------------------------
    //
    // Per-element ops with no cross-element data flow: any disjoint
    // tiling, thread count, or vector width that reproduces the exact
    // per-element rounding sequence below is BITWISE identical to these
    // loops. They are the ground truth the tier dispatch and the simd
    // lanes are pinned against (`tests/linalg_parity.rs`).

    /// `out[i*n..][j] += b[j]` — broadcast-add a bias row.
    pub fn add_bias(out: &mut [f32], b: &[f32], m: usize, n: usize) {
        for i in 0..m {
            let row = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                row[j] += b[j];
            }
        }
    }

    /// `db[j] += sum_i dy[i, j0 + j]` over the column window owned by
    /// `db`. The row fold per output element is sequential (`i = 0..m`,
    /// one add per step), so column-partitioned runs and shard-chained
    /// folds replay it exactly.
    pub fn col_sums_cols(dy: &[f32], m: usize, n: usize, j0: usize, db: &mut [f32]) {
        let w = db.len();
        for i in 0..m {
            let row = &dy[i * n + j0..i * n + j0 + w];
            for j in 0..w {
                db[j] += row[j];
            }
        }
    }

    /// In-place ReLU. Deliberately `if v < 0 { 0 }` rather than
    /// `max(0, v)`: NaN and `-0.0` pass through unchanged, and the simd
    /// lane mirrors that with a compare+blend.
    pub fn relu(x: &mut [f32]) {
        for v in x.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// In-place tanh. libm-bound: there is no simd lane for this (a
    /// polynomial approximation would break bitwise parity with the
    /// scalar tier), only chunk-level pool parallelism.
    pub fn tanh(x: &mut [f32]) {
        for v in x.iter_mut() {
            *v = v.tanh();
        }
    }

    /// Zero `grad` wherever the post-activation `act` is <= 0 (ReLU
    /// derivative, using the identity `relu(z) > 0 <=> z > 0`).
    pub fn relu_backward(grad: &mut [f32], act: &[f32]) {
        for (g, &a) in grad.iter_mut().zip(act) {
            if a <= 0.0 {
                *g = 0.0;
            }
        }
    }

    /// Scale `grad` by `1 - act^2` (tanh derivative from the
    /// post-activation). Rounding sequence per element: `a*a`, `1 - _`,
    /// `g * _` — three roundings the simd lane reproduces with
    /// `mul`/`sub`/`mul` (no FMA contraction).
    pub fn tanh_backward(grad: &mut [f32], act: &[f32]) {
        for (g, &a) in grad.iter_mut().zip(act) {
            *g *= 1.0 - a * a;
        }
    }

    /// Row-wise log-softmax of `logits[M,N]` into `logp` (may alias
    /// shapes, not storage). Numerically stable (max-subtracted).
    pub fn log_softmax(logits: &[f32], m: usize, n: usize, logp: &mut [f32]) {
        for i in 0..m {
            let row = &logits[i * n..(i + 1) * n];
            let out = &mut logp[i * n..(i + 1) * n];
            let mut mx = f32::NEG_INFINITY;
            for &v in row {
                if v > mx {
                    mx = v;
                }
            }
            // PARITY: the log-sum-exp fold is sequential left-to-right
            // within each row in every tier and chunk plan — rows are the
            // parallel unit, never the elements of one row.
            let mut lse = 0.0f32;
            for &v in row {
                lse += (v - mx).exp();
            }
            let lse = lse.ln() + mx;
            for j in 0..n {
                out[j] = row[j] - lse;
            }
        }
    }

    // --- optimizer references --------------------------------------------

    /// One SGD-with-momentum step over a parameter window:
    /// `mom = momentum*mom + g; p -= lr*mom`. Elementwise — any disjoint
    /// tiling of (params, mom, g) applies bit-identically.
    pub fn sgd_apply(params: &mut [f32], mom: &mut [f32], g: &[f32], lr: f32, momentum: f32) {
        for i in 0..g.len() {
            mom[i] = momentum * mom[i] + g[i];
            params[i] -= lr * mom[i];
        }
    }

    /// One Adam step over a parameter window. `c1`/`c2` are the caller's
    /// bias corrections (computed once per step from the step count — NOT
    /// per window, so tiled applies match the fused loop bitwise). Every
    /// operation (`mul`/`add`/`sub`/`div`/`sqrt`) is correctly rounded,
    /// which is what lets the simd lane reproduce this sequence exactly.
    #[allow(clippy::too_many_arguments)]
    pub fn adam_apply(
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        b1: f32,
        b2: f32,
        eps: f32,
        c1: f32,
        c2: f32,
    ) {
        for i in 0..g.len() {
            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
            v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
            let m_hat = m[i] / c1;
            let v_hat = v[i] / c2;
            params[i] -= lr * m_hat / (v_hat.sqrt() + eps);
        }
    }
}

/// Cache-blocked, lane-unrolled portable kernels (the `blocked` tier; also
/// the portable fallback bodies the `simd` tier shadows with intrinsics).
mod blocked {
    use super::{row_all_zero, LANE, TILE_I, TILE_K};

    pub(super) fn matmul_acc_block(
        x: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        let mut i0 = 0;
        while i0 < rows {
            let i1 = (i0 + TILE_I).min(rows);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + TILE_K).min(k);
                for i in i0..i1 {
                    let xrow = &x[i * k + k0..i * k + k1];
                    if row_all_zero(xrow) {
                        continue; // padded row: whole k-slab contributes nothing
                    }
                    let orow = &mut out[i * n..(i + 1) * n];
                    let mut kk = 0;
                    let kt = k1 - k0;
                    while kk + 4 <= kt {
                        let a0 = xrow[kk];
                        let a1 = xrow[kk + 1];
                        let a2 = xrow[kk + 2];
                        let a3 = xrow[kk + 3];
                        let w0 = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let w1 = &w[(k0 + kk + 1) * n..(k0 + kk + 1) * n + n];
                        let w2 = &w[(k0 + kk + 2) * n..(k0 + kk + 2) * n + n];
                        let w3 = &w[(k0 + kk + 3) * n..(k0 + kk + 3) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let o = &mut orow[j..j + LANE];
                            let v0 = &w0[j..j + LANE];
                            let v1 = &w1[j..j + LANE];
                            let v2 = &w2[j..j + LANE];
                            let v3 = &w3[j..j + LANE];
                            for l in 0..LANE {
                                o[l] += a0 * v0[l] + a1 * v1[l] + a2 * v2[l] + a3 * v3[l];
                            }
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a0 * w0[j] + a1 * w1[j] + a2 * w2[j] + a3 * w3[j];
                            j += 1;
                        }
                        kk += 4;
                    }
                    while kk < kt {
                        let a = xrow[kk];
                        let wrow = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let o = &mut orow[j..j + LANE];
                            let v = &wrow[j..j + LANE];
                            for l in 0..LANE {
                                o[l] += a * v[l];
                            }
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a * wrow[j];
                            j += 1;
                        }
                        kk += 1;
                    }
                }
                k0 = k1;
            }
            i0 = i1;
        }
    }

    pub(super) fn matmul_bt_block(
        dy: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            if row_all_zero(dyrow) {
                dxrow.fill(0.0); // masked sample: gradient row is exactly zero
                continue;
            }
            for kk in 0..k {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = [0.0f32; LANE];
                let mut j = 0;
                while j + LANE <= n {
                    let d = &dyrow[j..j + LANE];
                    let v = &wrow[j..j + LANE];
                    for l in 0..LANE {
                        acc[l] += d[l] * v[l];
                    }
                    j += LANE;
                }
                let mut s = 0.0f32;
                while j < n {
                    s += dyrow[j] * wrow[j];
                    j += 1;
                }
                for &a in &acc {
                    s += a;
                }
                dxrow[kk] = s;
            }
        }
    }

    /// Packed-panel input gradient: `wt` is the k-major `[N, K]` transpose
    /// of `w` (`wt[j*k + kk] == w[kk*n + j]`), streamed as an axpy over
    /// `j` — contiguous loads, no horizontal reductions. Overwrites `dx`.
    pub(super) fn matmul_bt_packed_block(
        dy: &[f32],
        wt: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            dxrow.fill(0.0);
            if row_all_zero(dyrow) {
                continue; // masked sample: gradient row is exactly zero
            }
            for j in 0..n {
                let d = dyrow[j];
                let wtrow = &wt[j * k..(j + 1) * k];
                let mut kk = 0;
                while kk + LANE <= k {
                    let o = &mut dxrow[kk..kk + LANE];
                    let v = &wtrow[kk..kk + LANE];
                    for l in 0..LANE {
                        o[l] += d * v[l];
                    }
                    kk += LANE;
                }
                while kk < k {
                    dxrow[kk] += d * wtrow[kk];
                    kk += 1;
                }
            }
        }
    }

    pub(super) fn matmul_at_block(
        x: &[f32],
        dy: &[f32],
        m: usize,
        k: usize,
        n: usize,
        k0: usize,
        dw: &mut [f32],
    ) {
        let kr = dw.len() / n;
        for i in 0..m {
            let dyrow = &dy[i * n..(i + 1) * n];
            if row_all_zero(dyrow) {
                continue; // masked sample contributes no weight gradient
            }
            let xrow = &x[i * k + k0..i * k + k0 + kr];
            for kk in 0..kr {
                let a = xrow[kk];
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                let mut j = 0;
                while j + LANE <= n {
                    let o = &mut dwrow[j..j + LANE];
                    let d = &dyrow[j..j + LANE];
                    for l in 0..LANE {
                        o[l] += a * d[l];
                    }
                    j += LANE;
                }
                while j < n {
                    dwrow[j] += a * dyrow[j];
                    j += 1;
                }
            }
        }
    }
}

/// AVX2/FMA kernels (x86_64 only). Every function is `unsafe` with
/// `target_feature(enable = "avx2,fma")`; callers reach them exclusively
/// through the tier dispatch below, and a [`KernelTier::Simd`] pool can
/// only be constructed after `is_x86_feature_detected!` confirmed support
/// ([`KernelTier::resolved`]), which is what makes the calls sound.
///
/// `matmul_at` deliberately uses `mul`+`add` (NOT `fmadd`): one rounding
/// per operation, matching the scalar/blocked fold bit for bit. The
/// forward/input-grad kernels use FMA freely (cross-tier tolerance 1e-5).
#[cfg(target_arch = "x86_64")]
mod simd {
    use super::{row_all_zero, LANE, TILE_I, TILE_K};
    use core::arch::x86_64::*;

    /// Horizontal sum of 8 lanes (deterministic pairwise association).
    // SAFETY: unsafe solely because of `target_feature`; operates on a
    // register value, no memory access. Callers are themselves
    // avx2-gated kernels in this module.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    // SAFETY: unsafe solely because of `target_feature` — reached only
    // through the tier dispatch below, which holds `KernelTier::Simd`
    // only after runtime AVX2+FMA detection. All loads/stores are
    // unaligned (`loadu`/`storeu`, no alignment precondition) through
    // pointers derived from the argument slices, with every vector
    // access guarded by `j + LANE <= n` and scalar tails for the rest.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matmul_acc_block(
        x: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        let mut i0 = 0;
        while i0 < rows {
            let i1 = (i0 + TILE_I).min(rows);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + TILE_K).min(k);
                for i in i0..i1 {
                    let xrow = &x[i * k + k0..i * k + k1];
                    if row_all_zero(xrow) {
                        continue; // padded row: whole k-slab contributes nothing
                    }
                    let orow = &mut out[i * n..(i + 1) * n];
                    let kt = k1 - k0;
                    let mut kk = 0;
                    while kk + 4 <= kt {
                        let (a0, a1, a2, a3) =
                            (xrow[kk], xrow[kk + 1], xrow[kk + 2], xrow[kk + 3]);
                        let va0 = _mm256_set1_ps(a0);
                        let va1 = _mm256_set1_ps(a1);
                        let va2 = _mm256_set1_ps(a2);
                        let va3 = _mm256_set1_ps(a3);
                        let w0 = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let w1 = &w[(k0 + kk + 1) * n..(k0 + kk + 1) * n + n];
                        let w2 = &w[(k0 + kk + 2) * n..(k0 + kk + 2) * n + n];
                        let w3 = &w[(k0 + kk + 3) * n..(k0 + kk + 3) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let mut o = _mm256_loadu_ps(orow.as_ptr().add(j));
                            o = _mm256_fmadd_ps(va0, _mm256_loadu_ps(w0.as_ptr().add(j)), o); // PARITY: fma — forward path, 1e-5 tier contract
                            o = _mm256_fmadd_ps(va1, _mm256_loadu_ps(w1.as_ptr().add(j)), o); // PARITY: fma — forward path, 1e-5 tier contract
                            o = _mm256_fmadd_ps(va2, _mm256_loadu_ps(w2.as_ptr().add(j)), o); // PARITY: fma — forward path, 1e-5 tier contract
                            o = _mm256_fmadd_ps(va3, _mm256_loadu_ps(w3.as_ptr().add(j)), o); // PARITY: fma — forward path, 1e-5 tier contract
                            _mm256_storeu_ps(orow.as_mut_ptr().add(j), o);
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a0 * w0[j] + a1 * w1[j] + a2 * w2[j] + a3 * w3[j];
                            j += 1;
                        }
                        kk += 4;
                    }
                    while kk < kt {
                        let a = xrow[kk];
                        let va = _mm256_set1_ps(a);
                        let wrow = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let mut o = _mm256_loadu_ps(orow.as_ptr().add(j));
                            o = _mm256_fmadd_ps(va, _mm256_loadu_ps(wrow.as_ptr().add(j)), o); // PARITY: fma — forward path, 1e-5 tier contract
                            _mm256_storeu_ps(orow.as_mut_ptr().add(j), o);
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a * wrow[j];
                            j += 1;
                        }
                        kk += 1;
                    }
                }
                k0 = k1;
            }
            i0 = i1;
        }
    }

    // SAFETY: same contract as `matmul_acc_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived loads bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matmul_bt_block(
        dy: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            if row_all_zero(dyrow) {
                dxrow.fill(0.0);
                continue;
            }
            for kk in 0..k {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = _mm256_setzero_ps();
                let mut j = 0;
                while j + LANE <= n {
                    acc = _mm256_fmadd_ps( // PARITY: fma — input-grad path, 1e-5 tier contract
                        _mm256_loadu_ps(dyrow.as_ptr().add(j)),
                        _mm256_loadu_ps(wrow.as_ptr().add(j)),
                        acc,
                    );
                    j += LANE;
                }
                let mut s = hsum256(acc);
                while j < n {
                    s += dyrow[j] * wrow[j];
                    j += 1;
                }
                dxrow[kk] = s;
            }
        }
    }

    // SAFETY: same contract as `matmul_acc_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived loads bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matmul_bt_packed_block(
        dy: &[f32],
        wt: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            dxrow.fill(0.0);
            if row_all_zero(dyrow) {
                continue;
            }
            for j in 0..n {
                let d = dyrow[j];
                let vd = _mm256_set1_ps(d);
                let wtrow = &wt[j * k..(j + 1) * k];
                let mut kk = 0;
                while kk + LANE <= k {
                    let mut o = _mm256_loadu_ps(dxrow.as_ptr().add(kk));
                    o = _mm256_fmadd_ps(vd, _mm256_loadu_ps(wtrow.as_ptr().add(kk)), o); // PARITY: fma — input-grad path, 1e-5 tier contract
                    _mm256_storeu_ps(dxrow.as_mut_ptr().add(kk), o);
                    kk += LANE;
                }
                while kk < k {
                    dxrow[kk] += d * wtrow[kk];
                    kk += 1;
                }
            }
        }
    }

    /// Bitwise-parity-critical: `mul`+`add` only (no FMA), same rounding
    /// sequence per output element as the scalar and blocked folds.
    // SAFETY: unsafe only for `target_feature` (avx2 alone — no FMA, see
    // the parity note above), dispatch-gated on detected AVX2, unaligned
    // slice-derived loads bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_at_block(
        x: &[f32],
        dy: &[f32],
        m: usize,
        k: usize,
        n: usize,
        k0: usize,
        dw: &mut [f32],
    ) {
        let kr = dw.len() / n;
        for i in 0..m {
            let dyrow = &dy[i * n..(i + 1) * n];
            if row_all_zero(dyrow) {
                continue;
            }
            let xrow = &x[i * k + k0..i * k + k0 + kr];
            for kk in 0..kr {
                let a = xrow[kk];
                let va = _mm256_set1_ps(a);
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                let mut j = 0;
                while j + LANE <= n {
                    let o = _mm256_add_ps(
                        _mm256_loadu_ps(dwrow.as_ptr().add(j)),
                        _mm256_mul_ps(va, _mm256_loadu_ps(dyrow.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(dwrow.as_mut_ptr().add(j), o);
                    j += LANE;
                }
                while j < n {
                    dwrow[j] += a * dyrow[j];
                    j += 1;
                }
            }
        }
    }

    // --- elementwise / optimizer lanes ------------------------------------
    //
    // Bitwise-parity-critical, like `matmul_at_block`: NO fmadd anywhere
    // in this section (an fma would contract the scalar reference's two
    // roundings into one). Only `mul`/`add`/`sub`/`div`/`sqrt`/compare/
    // blend — each correctly rounded, reproducing `scalar`'s per-element
    // sequence bit for bit. The lanes are `avx2`-only; dispatch still
    // requires AVX2+FMA (one tier, one gate).

    // SAFETY: unsafe solely because of `target_feature` — reached only
    // through the tier dispatch below, which holds `KernelTier::Simd`
    // only after runtime AVX2+FMA detection. Unaligned `loadu`/`storeu`
    // through slice-derived pointers, every vector access guarded by
    // `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add_bias_block(out: &mut [f32], b: &[f32], rows: usize, n: usize) {
        for i in 0..rows {
            let row = &mut out[i * n..(i + 1) * n];
            let mut j = 0;
            while j + LANE <= n {
                let o = _mm256_add_ps(
                    _mm256_loadu_ps(row.as_ptr().add(j)),
                    _mm256_loadu_ps(b.as_ptr().add(j)),
                );
                _mm256_storeu_ps(row.as_mut_ptr().add(j), o);
                j += LANE;
            }
            while j < n {
                row[j] += b[j];
                j += 1;
            }
        }
    }

    /// Column-window bias gradient: per output element the row fold is
    /// sequential (`i = 0..m`, one add per step) — vectorizing across
    /// columns `j` never reorders any element's fold.
    // SAFETY: same contract as `add_bias_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived accesses bounded by `j + LANE <= w` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn col_sums_block(dy: &[f32], m: usize, n: usize, j0: usize, db: &mut [f32]) {
        let w = db.len();
        for i in 0..m {
            let row = &dy[i * n + j0..i * n + j0 + w];
            let mut j = 0;
            while j + LANE <= w {
                let o = _mm256_add_ps(
                    _mm256_loadu_ps(db.as_ptr().add(j)),
                    _mm256_loadu_ps(row.as_ptr().add(j)),
                );
                _mm256_storeu_ps(db.as_mut_ptr().add(j), o);
                j += LANE;
            }
            while j < w {
                db[j] += row[j];
                j += 1;
            }
        }
    }

    /// `if v < 0 { 0 }` as compare+blend: `-0.0` and NaN lanes pass
    /// through untouched, exactly like the scalar branch.
    // SAFETY: same contract as `add_bias_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived accesses bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn relu_block(x: &mut [f32]) {
        let zero = _mm256_setzero_ps();
        let n = x.len();
        let mut j = 0;
        while j + LANE <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(j));
            let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(v, zero);
            _mm256_storeu_ps(x.as_mut_ptr().add(j), _mm256_blendv_ps(v, zero, lt));
            j += LANE;
        }
        while j < n {
            if x[j] < 0.0 {
                x[j] = 0.0;
            }
            j += 1;
        }
    }

    /// `if a <= 0 { g = 0 }` as compare+andnot (the mask is all-ones or
    /// all-zeros per lane, so the bit-select is exact); NaN activations
    /// compare false and leave the gradient lane untouched, like scalar.
    // SAFETY: same contract as `add_bias_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived accesses bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn relu_backward_block(grad: &mut [f32], act: &[f32]) {
        let zero = _mm256_setzero_ps();
        let n = grad.len();
        let mut j = 0;
        while j + LANE <= n {
            let a = _mm256_loadu_ps(act.as_ptr().add(j));
            let g = _mm256_loadu_ps(grad.as_ptr().add(j));
            let le = _mm256_cmp_ps::<_CMP_LE_OQ>(a, zero);
            _mm256_storeu_ps(grad.as_mut_ptr().add(j), _mm256_andnot_ps(le, g));
            j += LANE;
        }
        while j < n {
            if act[j] <= 0.0 {
                grad[j] = 0.0;
            }
            j += 1;
        }
    }

    /// `g *= 1 - a*a` with the scalar's three roundings: `mul`, `sub`,
    /// `mul` — no fma contraction.
    // SAFETY: same contract as `add_bias_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived accesses bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn tanh_backward_block(grad: &mut [f32], act: &[f32]) {
        let one = _mm256_set1_ps(1.0);
        let n = grad.len();
        let mut j = 0;
        while j + LANE <= n {
            let a = _mm256_loadu_ps(act.as_ptr().add(j));
            let g = _mm256_loadu_ps(grad.as_ptr().add(j));
            let d = _mm256_sub_ps(one, _mm256_mul_ps(a, a));
            _mm256_storeu_ps(grad.as_mut_ptr().add(j), _mm256_mul_ps(g, d));
            j += LANE;
        }
        while j < n {
            grad[j] *= 1.0 - act[j] * act[j];
            j += 1;
        }
    }

    /// SGD window step: `mom = momentum*mom + g` (mul, add), then
    /// `p -= lr*mom` (mul, sub) — four roundings, same as scalar.
    // SAFETY: same contract as `add_bias_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived accesses bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sgd_apply_block(
        params: &mut [f32],
        mom: &mut [f32],
        g: &[f32],
        lr: f32,
        momentum: f32,
    ) {
        let vmu = _mm256_set1_ps(momentum);
        let vlr = _mm256_set1_ps(lr);
        let n = g.len();
        let mut j = 0;
        while j + LANE <= n {
            let mj = _mm256_add_ps(
                _mm256_mul_ps(vmu, _mm256_loadu_ps(mom.as_ptr().add(j))),
                _mm256_loadu_ps(g.as_ptr().add(j)),
            );
            _mm256_storeu_ps(mom.as_mut_ptr().add(j), mj);
            let p = _mm256_sub_ps(
                _mm256_loadu_ps(params.as_ptr().add(j)),
                _mm256_mul_ps(vlr, mj),
            );
            _mm256_storeu_ps(params.as_mut_ptr().add(j), p);
            j += LANE;
        }
        while j < n {
            mom[j] = momentum * mom[j] + g[j];
            params[j] -= lr * mom[j];
            j += 1;
        }
    }

    /// Adam window step, mirroring `scalar::adam_apply` operation for
    /// operation: `b1*m + (1-b1)*g` is add(mul, mul); the second-moment
    /// term keeps the scalar's left association `((1-b2)*g)*g`; `div` and
    /// `sqrt` are IEEE correctly rounded, so the whole update is bitwise.
    // SAFETY: same contract as `add_bias_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived accesses bounded by `j + LANE <= n` with scalar tails.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn adam_apply_block(
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        lr: f32,
        b1: f32,
        b2: f32,
        eps: f32,
        c1: f32,
        c2: f32,
    ) {
        let vb1 = _mm256_set1_ps(b1);
        let vb1c = _mm256_set1_ps(1.0 - b1);
        let vb2 = _mm256_set1_ps(b2);
        let vb2c = _mm256_set1_ps(1.0 - b2);
        let vlr = _mm256_set1_ps(lr);
        let veps = _mm256_set1_ps(eps);
        let vc1 = _mm256_set1_ps(c1);
        let vc2 = _mm256_set1_ps(c2);
        let n = g.len();
        let mut j = 0;
        while j + LANE <= n {
            let gj = _mm256_loadu_ps(g.as_ptr().add(j));
            let mj = _mm256_add_ps(
                _mm256_mul_ps(vb1, _mm256_loadu_ps(m.as_ptr().add(j))),
                _mm256_mul_ps(vb1c, gj),
            );
            _mm256_storeu_ps(m.as_mut_ptr().add(j), mj);
            let vj = _mm256_add_ps(
                _mm256_mul_ps(vb2, _mm256_loadu_ps(v.as_ptr().add(j))),
                _mm256_mul_ps(_mm256_mul_ps(vb2c, gj), gj),
            );
            _mm256_storeu_ps(v.as_mut_ptr().add(j), vj);
            let m_hat = _mm256_div_ps(mj, vc1);
            let v_hat = _mm256_div_ps(vj, vc2);
            let den = _mm256_add_ps(_mm256_sqrt_ps(v_hat), veps);
            let p = _mm256_sub_ps(
                _mm256_loadu_ps(params.as_ptr().add(j)),
                _mm256_div_ps(_mm256_mul_ps(vlr, m_hat), den),
            );
            _mm256_storeu_ps(params.as_mut_ptr().add(j), p);
            j += LANE;
        }
        while j < n {
            m[j] = b1 * m[j] + (1.0 - b1) * g[j];
            v[j] = b2 * v[j] + (1.0 - b2) * g[j] * g[j];
            let m_hat = m[j] / c1;
            let v_hat = v[j] / c2;
            params[j] -= lr * m_hat / (v_hat.sqrt() + eps);
            j += 1;
        }
    }
}

// --- tier dispatch (one leaf call per chunk; `Simd` is only reachable
// through a resolved tier, which guarantees AVX2+FMA support) ---

fn acc_block(tier: KernelTier, x: &[f32], w: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_acc_block(x, w, rows, k, n, out) },
        _ => blocked::matmul_acc_block(x, w, rows, k, n, out),
    }
}

fn bt_block(tier: KernelTier, dy: &[f32], w: &[f32], rows: usize, k: usize, n: usize, dx: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_bt_block(dy, w, rows, k, n, dx) },
        _ => blocked::matmul_bt_block(dy, w, rows, k, n, dx),
    }
}

fn bt_packed_block(tier: KernelTier, dy: &[f32], wt: &[f32], rows: usize, k: usize, n: usize, dx: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_bt_packed_block(dy, wt, rows, k, n, dx) },
        _ => blocked::matmul_bt_packed_block(dy, wt, rows, k, n, dx),
    }
}

fn at_block(tier: KernelTier, x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, k0: usize, dw: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_at_block(x, dy, m, k, n, k0, dw) },
        _ => blocked::matmul_at_block(x, dy, m, k, n, k0, dw),
    }
}

/// Pack `w[K,N]` into its k-major transpose `wt[N,K]` (row `j` of `wt` is
/// column `j` of `w`), reusing `wt`'s capacity.
pub fn pack_wt(w: &[f32], k: usize, n: usize, wt: &mut Vec<f32>) {
    debug_assert_eq!(w.len(), k * n);
    // The loop below writes every slot, so a warm recycled buffer of the
    // right length skips the resize's redundant zero-fill entirely.
    if wt.len() != k * n {
        wt.clear();
        wt.resize(k * n, 0.0);
    }
    for kk in 0..k {
        let wrow = &w[kk * n..(kk + 1) * n];
        for (j, &v) in wrow.iter().enumerate() {
            wt[j * k + kk] = v;
        }
    }
}

/// `out[M,N] += x[M,K] @ w[K,N]`. `out` must be pre-zeroed by the caller
/// (or hold a partial sum to accumulate into).
pub fn matmul_acc(pool: &Pool, x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_acc(x, w, m, k, n, out);
        return;
    }
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        acc_block(tier, x, w, m, k, n, out);
        return;
    }
    pool.run(
        x.chunks(per * k)
            .zip(out.chunks_mut(per * n))
            .map(|(xc, oc)| move || acc_block(tier, xc, w, xc.len() / k, k, n, oc))
            .collect(),
    );
}

/// `dx[M,K] = dy[M,N] @ w[K,N]^T` (input gradient; overwrites `dx`).
/// Unpacked entry point (dot-product walk over `w` rows); hot paths with a
/// workspace use [`matmul_bt_ws`] instead.
pub fn matmul_bt(pool: &Pool, dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_bt(dy, w, m, k, n, dx);
        return;
    }
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        bt_block(tier, dy, w, m, k, n, dx);
        return;
    }
    pool.run(
        dy.chunks(per * n)
            .zip(dx.chunks_mut(per * k))
            .map(|(dyc, dxc)| move || bt_block(tier, dyc, w, dxc.len() / k, k, n, dxc))
            .collect(),
    );
}

/// [`matmul_bt`] through a generation-tagged packed panel of `w`: the
/// k-major `[N,K]` transpose is built at most once per (layer, step) in
/// `panels` (keyed by `key` — the layer's weight offset — and `gen` — the
/// workspace's step generation, bumped by every optimizer update) and then
/// streamed as a contiguous axpy read. Scalar tier bypasses the panel and
/// runs the reference loops.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt_ws(
    pool: &Pool,
    panels: &mut PanelCache,
    gen: u64,
    key: usize,
    dy: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_bt(dy, w, m, k, n, dx);
        return;
    }
    let (wt, fresh) = panels.slot(key, gen, k, n);
    if fresh {
        pack_wt(w, k, n, wt);
    }
    let wt: &[f32] = wt;
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        bt_packed_block(tier, dy, wt, m, k, n, dx);
        return;
    }
    pool.run(
        dy.chunks(per * n)
            .zip(dx.chunks_mut(per * k))
            .map(|(dyc, dxc)| move || bt_packed_block(tier, dyc, wt, dxc.len() / k, k, n, dxc))
            .collect(),
    );
}

/// `dw[K,N] += x[M,K]^T @ dy[M,N]` (weight gradient; accumulates).
/// Reduce-sensitive: every tier folds rows `i = 0..m` sequentially per
/// output element with one mul+add rounding pair per step, so the three
/// tiers agree **bitwise** and shard-chained folds replay exactly.
pub fn matmul_at(pool: &Pool, x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_at(x, dy, m, k, n, dw);
        return;
    }
    // Partition the K (output) rows: every chunk scans all M samples but
    // owns a disjoint dw row range, so the i-summation order per output
    // row is identical to the sequential kernel.
    let per = pool.rows_per_chunk(k, 2 * m * n);
    if per >= k {
        at_block(tier, x, dy, m, k, n, 0, dw);
        return;
    }
    pool.run(
        dw.chunks_mut(per * n)
            .enumerate()
            .map(|(ci, dwc)| move || at_block(tier, x, dy, m, k, n, ci * per, dwc))
            .collect(),
    );
}

// --- elementwise / activation layer (pooled + tier-dispatched) -----------
//
// Every op below is BITWISE identical across {scalar,blocked,simd} × any
// thread count: per-element rounding sequences are fixed (see the
// `scalar` references), chunks are disjoint, and the simd lanes use no
// FMA and no libm approximations. The `blocked` tier shares the scalar
// bodies (there is nothing to cache-block in a streaming elementwise op)
// but still fans out across the pool.

/// Approximate per-element flop weight of one libm call (`tanh`, `exp`);
/// feeds [`Pool::rows_per_chunk`] so libm-bound ops fan out much earlier
/// than single-flop stream ops.
const LIBM_FLOPS: usize = 32;

fn elem_block(tier: KernelTier, x: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::relu_block(x) },
        _ => scalar::relu(x),
    }
}

fn relu_bwd_block(tier: KernelTier, grad: &mut [f32], act: &[f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::relu_backward_block(grad, act) },
        _ => scalar::relu_backward(grad, act),
    }
}

fn tanh_bwd_block(tier: KernelTier, grad: &mut [f32], act: &[f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::tanh_backward_block(grad, act) },
        _ => scalar::tanh_backward(grad, act),
    }
}

fn bias_block(tier: KernelTier, out: &mut [f32], b: &[f32], rows: usize, n: usize) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::add_bias_block(out, b, rows, n) },
        _ => scalar::add_bias(out, b, rows, n),
    }
}

fn cs_block(tier: KernelTier, dy: &[f32], m: usize, n: usize, j0: usize, db: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::col_sums_block(dy, m, n, j0, db) },
        _ => scalar::col_sums_cols(dy, m, n, j0, db),
    }
}

fn sgd_block(tier: KernelTier, params: &mut [f32], mom: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::sgd_apply_block(params, mom, g, lr, mu) },
        _ => scalar::sgd_apply(params, mom, g, lr, mu),
    }
}

#[allow(clippy::too_many_arguments)]
fn adam_block(
    tier: KernelTier,
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    c1: f32,
    c2: f32,
) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe {
            simd::adam_apply_block(params, m, v, g, lr, b1, b2, eps, c1, c2)
        },
        _ => scalar::adam_apply(params, m, v, g, lr, b1, b2, eps, c1, c2),
    }
}

/// `out[i*n..][j] += b[j]` — broadcast-add a bias row. Row-partitioned;
/// BITWISE across tiers and thread counts.
pub fn add_bias(pool: &Pool, out: &mut [f32], b: &[f32], m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(b.len(), n);
    if m == 0 || n == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::add_bias(out, b, m, n);
        return;
    }
    let per = pool.rows_per_chunk(m, n);
    if per >= m {
        bias_block(tier, out, b, m, n);
        return;
    }
    pool.run(
        out.chunks_mut(per * n)
            .map(|oc| move || bias_block(tier, oc, b, oc.len() / n, n))
            .collect(),
    );
}

/// `db[j] += sum_i dy[i,j]` — bias gradient (column sums; accumulates).
/// Parallelism partitions the N output *columns*: each chunk owns a
/// disjoint `db` window and folds rows `i = 0..m` sequentially per
/// element, so shard-chained folds replay exactly and every tier/thread
/// combination is identical by construction (BITWISE).
pub fn col_sums(pool: &Pool, dy: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(db.len(), n);
    if m == 0 || n == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::col_sums_cols(dy, m, n, 0, db);
        return;
    }
    let per = pool.rows_per_chunk(n, 2 * m);
    if per >= n {
        cs_block(tier, dy, m, n, 0, db);
        return;
    }
    pool.run(
        db.chunks_mut(per)
            .enumerate()
            .map(|(ci, dbc)| move || cs_block(tier, dy, m, n, ci * per, dbc))
            .collect(),
    );
}

/// In-place ReLU (`if v < 0 { 0 }`; NaN/`-0.0` untouched). Chunk-
/// partitioned; BITWISE across tiers and thread counts.
pub fn relu(pool: &Pool, x: &mut [f32]) {
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::relu(x);
        return;
    }
    let per = pool.rows_per_chunk(x.len(), 1);
    if per >= x.len() {
        elem_block(tier, x);
        return;
    }
    pool.run(x.chunks_mut(per).map(|c| move || elem_block(tier, c)).collect());
}

/// In-place tanh. libm-bound: every tier runs the same scalar `tanh` per
/// element (a vector approximation would break bitwise parity), so the
/// only speedup is chunk-level pool fan-out — still BITWISE everywhere.
pub fn tanh(pool: &Pool, x: &mut [f32]) {
    if pool.tier() == KernelTier::Scalar {
        scalar::tanh(x);
        return;
    }
    let per = pool.rows_per_chunk(x.len(), LIBM_FLOPS);
    if per >= x.len() {
        scalar::tanh(x);
        return;
    }
    pool.run(x.chunks_mut(per).map(|c| move || scalar::tanh(c)).collect());
}

/// Zero `grad` wherever the post-activation `act` is <= 0 (ReLU derivative,
/// using the identity `relu(z) > 0 <=> z > 0`). BITWISE across tiers and
/// thread counts.
pub fn relu_backward(pool: &Pool, grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::relu_backward(grad, act);
        return;
    }
    let per = pool.rows_per_chunk(grad.len(), 1);
    if per >= grad.len() {
        relu_bwd_block(tier, grad, act);
        return;
    }
    pool.run(
        grad.chunks_mut(per)
            .zip(act.chunks(per))
            .map(|(gc, ac)| move || relu_bwd_block(tier, gc, ac))
            .collect(),
    );
}

/// Scale `grad` by `1 - act^2` (tanh derivative from the post-activation).
/// BITWISE across tiers and thread counts (mul/sub/mul, no fma).
pub fn tanh_backward(pool: &Pool, grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::tanh_backward(grad, act);
        return;
    }
    let per = pool.rows_per_chunk(grad.len(), 3);
    if per >= grad.len() {
        tanh_bwd_block(tier, grad, act);
        return;
    }
    pool.run(
        grad.chunks_mut(per)
            .zip(act.chunks(per))
            .map(|(gc, ac)| move || tanh_bwd_block(tier, gc, ac))
            .collect(),
    );
}

/// Row-wise log-softmax of `logits[M,N]` into `logp` (may alias shapes, not
/// storage). Numerically stable (max-subtracted). Rows are the parallel
/// unit; within a row the log-sum-exp fold is sequential in every tier
/// (see `scalar::log_softmax`'s PARITY note), so all tier/thread
/// combinations agree BITWISE.
pub fn log_softmax(pool: &Pool, logits: &[f32], m: usize, n: usize, logp: &mut [f32]) {
    debug_assert_eq!(logits.len(), m * n);
    debug_assert_eq!(logp.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if pool.tier() == KernelTier::Scalar {
        scalar::log_softmax(logits, m, n, logp);
        return;
    }
    let per = pool.rows_per_chunk(m, LIBM_FLOPS * n);
    if per >= m {
        scalar::log_softmax(logits, m, n, logp);
        return;
    }
    pool.run(
        logits
            .chunks(per * n)
            .zip(logp.chunks_mut(per * n))
            .map(|(lc, oc)| move || scalar::log_softmax(lc, oc.len() / n, n, oc))
            .collect(),
    );
}

// --- pooled optimizer apply ----------------------------------------------

/// Tiled SGD-with-momentum over a parameter window. The update is
/// elementwise, so any disjoint chunk partition applies bit-identically
/// to the fused loop — callers on the replica and zero planes share this
/// entry point. BITWISE across tiers and thread counts.
pub fn sgd_apply(pool: &Pool, params: &mut [f32], mom: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    debug_assert_eq!(params.len(), g.len());
    debug_assert_eq!(mom.len(), g.len());
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::sgd_apply(params, mom, g, lr, mu);
        return;
    }
    let per = pool.rows_per_chunk(g.len(), 4);
    if per >= g.len() {
        sgd_block(tier, params, mom, g, lr, mu);
        return;
    }
    pool.run(
        params
            .chunks_mut(per)
            .zip(mom.chunks_mut(per))
            .zip(g.chunks(per))
            .map(|((pc, mc), gc)| move || sgd_block(tier, pc, mc, gc, lr, mu))
            .collect(),
    );
}

/// Tiled Adam over a parameter window. `c1`/`c2` are the bias corrections
/// computed ONCE per optimizer step by the caller (from the step count),
/// never per tile — that is what keeps sliced/tiled application bitwise
/// identical to the fused loop. BITWISE across tiers and thread counts.
#[allow(clippy::too_many_arguments)]
pub fn adam_apply(
    pool: &Pool,
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    c1: f32,
    c2: f32,
) {
    debug_assert_eq!(params.len(), g.len());
    debug_assert_eq!(m.len(), g.len());
    debug_assert_eq!(v.len(), g.len());
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::adam_apply(params, m, v, g, lr, b1, b2, eps, c1, c2);
        return;
    }
    let per = pool.rows_per_chunk(g.len(), 16);
    if per >= g.len() {
        adam_block(tier, params, m, v, g, lr, b1, b2, eps, c1, c2);
        return;
    }
    pool.run(
        params
            .chunks_mut(per)
            .zip(m.chunks_mut(per))
            .zip(v.chunks_mut(per))
            .zip(g.chunks(per))
            .map(|(((pc, mc), vc), gc)| {
                move || adam_block(tier, pc, mc, vc, gc, lr, b1, b2, eps, c1, c2)
            })
            .collect(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq() -> Pool {
        Pool::sequential()
    }

    #[test]
    fn matmul_small_golden() {
        // x = [[1,2],[3,4]], w = [[5,6],[7,8]] -> [[19,22],[43,50]]
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [5.0, 6.0, 7.0, 8.0];
        let mut y = [0.0f32; 4];
        matmul_acc(&seq(), &x, &w, 2, 2, 2, &mut y);
        assert_eq!(y, [19.0, 22.0, 43.0, 50.0]);

        // dy @ w^T and x^T @ dy consistency with hand values.
        let mut dx = [0.0f32; 4];
        matmul_bt(&seq(), &y, &w, 2, 2, 2, &mut dx);
        assert_eq!(dx, [19.0 * 5.0 + 22.0 * 6.0, 19.0 * 7.0 + 22.0 * 8.0,
                        43.0 * 5.0 + 50.0 * 6.0, 43.0 * 7.0 + 50.0 * 8.0]);
        let mut dw = [0.0f32; 4];
        matmul_at(&seq(), &x, &y, 2, 2, 2, &mut dw);
        assert_eq!(dw, [1.0 * 19.0 + 3.0 * 43.0, 1.0 * 22.0 + 3.0 * 50.0,
                        2.0 * 19.0 + 4.0 * 43.0, 2.0 * 22.0 + 4.0 * 50.0]);
    }

    #[test]
    fn every_tier_matches_scalar_reference() {
        // Awkward shape (odd n, n % LANE != 0, k % 4 != 0) on one thread,
        // all executable tiers.
        let (m, k, n) = (5usize, 7usize, 11usize);
        let mut rng = crate::util::rng::Rng::new(42);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; m * n];
        scalar::matmul_acc(&x, &w, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let pool = Pool::with_config(1, tier);
            let mut got = vec![0.0f32; m * n];
            matmul_acc(&pool, &x, &w, m, k, n, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                    "{tier:?}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn packed_panel_transposes_exactly() {
        let (k, n) = (5usize, 3usize);
        let w: Vec<f32> = (0..k * n).map(|v| v as f32).collect();
        let mut wt = Vec::new();
        pack_wt(&w, k, n, &mut wt);
        for kk in 0..k {
            for j in 0..n {
                assert_eq!(wt[j * k + kk], w[kk * n + j]);
            }
        }
    }

    #[test]
    fn packed_bt_matches_reference_and_reuses_panel() {
        let (m, k, n) = (6usize, 13usize, 9usize);
        let mut rng = crate::util::rng::Rng::new(9);
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; m * k];
        scalar::matmul_bt(&dy, &w, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let pool = Pool::with_config(1, tier);
            let mut panels = PanelCache::default();
            let mut got = vec![0.0f32; m * k];
            matmul_bt_ws(&pool, &mut panels, 1, 100, &dy, &w, m, k, n, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{tier:?}: {a} vs {b}");
            }
        }
        // A stale generation must repack: same key, new weights, new gen.
        let pool = Pool::with_config(1, KernelTier::Blocked);
        let mut panels = PanelCache::default();
        let mut first = vec![0.0f32; m * k];
        matmul_bt_ws(&pool, &mut panels, 1, 100, &dy, &w, m, k, n, &mut first);
        let w2: Vec<f32> = w.iter().map(|v| v + 1.0).collect();
        let mut second = vec![0.0f32; m * k];
        matmul_bt_ws(&pool, &mut panels, 2, 100, &dy, &w2, m, k, n, &mut second);
        let mut want2 = vec![0.0f32; m * k];
        scalar::matmul_bt(&dy, &w2, m, k, n, &mut want2);
        for (a, b) in second.iter().zip(&want2) {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "stale panel survived a generation bump: {a} vs {b}"
            );
        }
    }

    #[test]
    fn zero_rows_are_skipped_without_changing_results() {
        let (m, k, n) = (6usize, 9usize, 10usize);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        // Pad out the last two rows (mask-0 samples).
        for v in &mut x[4 * k..] {
            *v = 0.0;
        }
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; m * n];
        scalar::matmul_acc(&x, &w, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let mut got = vec![0.0f32; m * n];
            matmul_acc(&Pool::with_config(1, tier), &x, &w, m, k, n, &mut got);
            for r in 4..6 {
                assert!(got[r * n..(r + 1) * n].iter().all(|&v| v == 0.0));
            }
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{tier:?}");
            }
        }
    }

    #[test]
    fn threaded_matmul_is_bitwise_stable_across_thread_counts() {
        // Big enough that 2/3/7 threads genuinely partition the rows, for
        // every executable tier.
        let (m, k, n) = (256usize, 64usize, 48usize);
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        for tier in KernelTier::available() {
            let mut base = vec![0.0f32; m * n];
            matmul_acc(&Pool::with_config(1, tier), &x, &w, m, k, n, &mut base);
            for threads in [2usize, 3, 7] {
                let mut out = vec![0.0f32; m * n];
                matmul_acc(&Pool::with_config(threads, tier), &x, &w, m, k, n, &mut out);
                assert_eq!(out, base, "{tier:?} threads={threads} diverged");
            }
        }
    }

    #[test]
    fn matmul_at_is_bitwise_identical_across_tiers() {
        // The reduce-sensitive kernel: all tiers share one fold order and
        // one rounding sequence per output element.
        let (m, k, n) = (33usize, 17usize, 20usize);
        let mut rng = crate::util::rng::Rng::new(13);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; k * n];
        scalar::matmul_at(&x, &dy, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let mut got = vec![0.0f32; k * n];
            matmul_at(&Pool::with_config(1, tier), &x, &dy, m, k, n, &mut got);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{tier:?}: dw[{i}] {a} != scalar {b}"
                );
            }
        }
    }

    #[test]
    fn log_softmax_rows_normalize() {
        let logits = [1.0f32, 2.0, 3.0, -5.0, 0.0, 5.0];
        let mut lp = [0.0f32; 6];
        log_softmax(&seq(), &logits, 2, 3, &mut lp);
        for i in 0..2 {
            let total: f32 = lp[i * 3..(i + 1) * 3].iter().map(|l| l.exp()).sum();
            assert!((total - 1.0).abs() < 1e-5, "row {i}: {total}");
        }
        // Monotone with the logits.
        assert!(lp[0] < lp[1] && lp[1] < lp[2]);
    }

    #[test]
    fn activation_derivative_masks() {
        let mut g = [1.0f32, 1.0, 1.0];
        relu_backward(&seq(), &mut g, &[0.5, 0.0, 2.0]);
        assert_eq!(g, [1.0, 0.0, 1.0]);
        let mut g = [1.0f32, 1.0];
        tanh_backward(&seq(), &mut g, &[0.0, 0.5]);
        assert!((g[0] - 1.0).abs() < 1e-6 && (g[1] - 0.75).abs() < 1e-6);
    }
}
