//! Minimal dense linear algebra for the native backend.
//!
//! Shapes follow the JAX convention used by `python/compile`: activations
//! are `[M, K]` row-major, weights `[K, N]` row-major (`fan_in` rows). The
//! three multiply kernels cover forward (`x @ w`), input gradients
//! (`dy @ w^T`) and weight gradients (`x^T @ dy`); loop orders are chosen so
//! the innermost loop always streams contiguous rows (ikj / dot-of-rows),
//! which is enough to keep the mini models far below the simulator costs.

/// `out[M,N] += x[M,K] @ w[K,N]`. `out` must be pre-zeroed by the caller
/// (or hold a partial sum to accumulate into).
pub fn matmul_acc(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &a) in xrow.iter().enumerate() {
            if a == 0.0 {
                continue; // padded rows / ReLU-dead units cost nothing
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += a * wrow[j];
            }
        }
    }
}

/// `dx[M,K] = dy[M,N] @ w[K,N]^T` (input gradient; overwrites `dx`).
pub fn matmul_bt(dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        let dxrow = &mut dx[i * k..(i + 1) * k];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut s = 0.0f32;
            for j in 0..n {
                s += dyrow[j] * wrow[j];
            }
            dxrow[kk] = s;
        }
    }
}

/// `dw[K,N] += x[M,K]^T @ dy[M,N]` (weight gradient; accumulates).
pub fn matmul_at(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let dyrow = &dy[i * n..(i + 1) * n];
        for (kk, &a) in xrow.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let dwrow = &mut dw[kk * n..(kk + 1) * n];
            for j in 0..n {
                dwrow[j] += a * dyrow[j];
            }
        }
    }
}

/// `out[i*n..][j] += b[j]` — broadcast-add a bias row.
pub fn add_bias(out: &mut [f32], b: &[f32], m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(b.len(), n);
    for i in 0..m {
        let row = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            row[j] += b[j];
        }
    }
}

/// `db[j] += sum_i dy[i,j]` — bias gradient (column sums; accumulates).
pub fn col_sums(dy: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(db.len(), n);
    for i in 0..m {
        let row = &dy[i * n..(i + 1) * n];
        for j in 0..n {
            db[j] += row[j];
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place tanh.
pub fn tanh(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Zero `grad` wherever the post-activation `act` is <= 0 (ReLU derivative,
/// using the identity `relu(z) > 0 <=> z > 0`).
pub fn relu_backward(grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    for (g, &a) in grad.iter_mut().zip(act) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Scale `grad` by `1 - act^2` (tanh derivative from the post-activation).
pub fn tanh_backward(grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    for (g, &a) in grad.iter_mut().zip(act) {
        *g *= 1.0 - a * a;
    }
}

/// Row-wise log-softmax of `logits[M,N]` into `logp` (may alias shapes, not
/// storage). Numerically stable (max-subtracted).
pub fn log_softmax(logits: &[f32], m: usize, n: usize, logp: &mut [f32]) {
    debug_assert_eq!(logits.len(), m * n);
    debug_assert_eq!(logp.len(), m * n);
    for i in 0..m {
        let row = &logits[i * n..(i + 1) * n];
        let out = &mut logp[i * n..(i + 1) * n];
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            if v > mx {
                mx = v;
            }
        }
        let mut lse = 0.0f32;
        for &v in row {
            lse += (v - mx).exp();
        }
        let lse = lse.ln() + mx;
        for j in 0..n {
            out[j] = row[j] - lse;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_golden() {
        // x = [[1,2],[3,4]], w = [[5,6],[7,8]] -> [[19,22],[43,50]]
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [5.0, 6.0, 7.0, 8.0];
        let mut y = [0.0f32; 4];
        matmul_acc(&x, &w, 2, 2, 2, &mut y);
        assert_eq!(y, [19.0, 22.0, 43.0, 50.0]);

        // dy @ w^T and x^T @ dy consistency with hand values.
        let mut dx = [0.0f32; 4];
        matmul_bt(&y, &w, 2, 2, 2, &mut dx);
        assert_eq!(dx, [19.0 * 5.0 + 22.0 * 6.0, 19.0 * 7.0 + 22.0 * 8.0,
                        43.0 * 5.0 + 50.0 * 6.0, 43.0 * 7.0 + 50.0 * 8.0]);
        let mut dw = [0.0f32; 4];
        matmul_at(&x, &y, 2, 2, 2, &mut dw);
        assert_eq!(dw, [1.0 * 19.0 + 3.0 * 43.0, 1.0 * 22.0 + 3.0 * 50.0,
                        2.0 * 19.0 + 4.0 * 43.0, 2.0 * 22.0 + 4.0 * 50.0]);
    }

    #[test]
    fn log_softmax_rows_normalize() {
        let logits = [1.0f32, 2.0, 3.0, -5.0, 0.0, 5.0];
        let mut lp = [0.0f32; 6];
        log_softmax(&logits, 2, 3, &mut lp);
        for i in 0..2 {
            let total: f32 = lp[i * 3..(i + 1) * 3].iter().map(|l| l.exp()).sum();
            assert!((total - 1.0).abs() < 1e-5, "row {i}: {total}");
        }
        // Monotone with the logits.
        assert!(lp[0] < lp[1] && lp[1] < lp[2]);
    }

    #[test]
    fn activation_derivative_masks() {
        let mut g = [1.0f32, 1.0, 1.0];
        relu_backward(&mut g, &[0.5, 0.0, 2.0]);
        assert_eq!(g, [1.0, 0.0, 1.0]);
        let mut g = [1.0f32, 1.0];
        tanh_backward(&mut g, &[0.0, 0.5]);
        assert!((g[0] - 1.0).abs() < 1e-6 && (g[1] - 0.75).abs() < 1e-6);
    }
}
