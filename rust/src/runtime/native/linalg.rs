//! Dense linear algebra for the native backend: three kernel tiers behind
//! one dispatch seam, row-partitioned across the persistent worker pool.
//!
//! Shapes follow the JAX convention used by `python/compile`: activations
//! are `[M, K]` row-major, weights `[K, N]` row-major (`fan_in` rows). The
//! three multiply kernels cover forward (`x @ w`), input gradients
//! (`dy @ w^T`) and weight gradients (`x^T @ dy`).
//!
//! ## Tiers (see [`super::exec::KernelTier`])
//!
//! * [`scalar`] — the reference triple loops: no tiling, no unrolling, no
//!   threading, no sparsity skips. Numerical ground truth.
//! * `blocked` — cache-tiled ([`TILE_I`]/[`TILE_K`]), [`LANE`]-unrolled
//!   portable kernels with a row-level all-zero skip (padded/masked rows
//!   cost one O(len) scan instead of O(len*n) multiply-adds).
//! * `simd` — AVX2/FMA intrinsics with the same blocking structure,
//!   reached only through a [`KernelTier::resolved`] tier (so the
//!   `unsafe` feature-gated calls are sound by construction).
//!
//! ## Bit-parity rules
//!
//! The **reduce-sensitive** kernels fold the batch dimension sequentially
//! per output element in *every* tier:
//!
//! * [`matmul_at`] — each `dw[kk,j]` accumulates rows `i = 0..m` in order,
//!   one `mul`+`add` rounding pair per step; the simd tier deliberately
//!   avoids FMA here so all three tiers produce **identical bits**.
//! * [`col_sums`] — one shared implementation for every tier.
//!
//! This is what lets the sharded data plane chain shard backwards through
//! a traveling accumulator and reproduce the fused gradient bit for bit
//! under any `DYNAMIX_KERNEL` setting (`tests/sharded_parity.rs`).
//!
//! The forward/input-grad kernels ([`matmul_acc`], [`matmul_bt`]) are
//! per-row independent — a row's value never depends on the batch size or
//! the chunk plan — but *across* tiers they may differ at float tolerance
//! (the simd tier uses FMA; the packed-panel `bt` folds `j` in a different
//! association), which the parity suite pins to 1e-5 of scalar.
//!
//! ## Packed panels
//!
//! `matmul_bt`'s weight operand is walked row-by-row as a dot product; the
//! workspace-backed entry point [`matmul_bt_ws`] instead packs `w` into a
//! k-major `[N, K]` panel (cached per generation in
//! [`super::workspace::PanelCache`]) and streams it as an axpy
//! accumulation — contiguous loads, no horizontal reductions, and the
//! panel is reused for every use within a step and invalidated by the
//! next step's generation bump (optimizer updates change `w`).

use super::exec::{KernelTier, Pool};
use super::workspace::PanelCache;

/// Unroll width of the innermost (column) loops. 8 f32 lanes = one AVX2
/// register / two NEON registers; LLVM vectorizes the fixed-size bodies.
pub const LANE: usize = 8;

/// Row-block size of `matmul_acc` (output rows revisited per `w` slab).
pub const TILE_I: usize = 32;

/// Reduction-block size of `matmul_acc`: a `TILE_K x n` slab of `w` is
/// `64*n*4` bytes — L1-resident for every zoo width.
pub const TILE_K: usize = 64;

#[inline]
fn row_all_zero(row: &[f32]) -> bool {
    // Dense rows exit on the first element; padded rows cost one O(len)
    // scan in exchange for skipping O(len * n) multiply-adds.
    row.iter().all(|&v| v == 0.0)
}

/// Scalar reference kernels: the straightforward triple loops, kept as the
/// numerical ground truth for parity tests and for documenting intent.
/// No tiling, no unrolling, no threading, no sparsity skips.
pub mod scalar {
    /// `out[M,N] += x[M,K] @ w[K,N]`.
    pub fn matmul_acc(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in xrow.iter().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * wrow[j];
                }
            }
        }
    }

    /// `dx[M,K] = dy[M,N] @ w[K,N]^T` (overwrites `dx`).
    pub fn matmul_bt(dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
        for i in 0..m {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            for kk in 0..k {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut s = 0.0f32;
                for j in 0..n {
                    s += dyrow[j] * wrow[j];
                }
                dxrow[kk] = s;
            }
        }
    }

    /// `dw[K,N] += x[M,K]^T @ dy[M,N]` (accumulates).
    pub fn matmul_at(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let dyrow = &dy[i * n..(i + 1) * n];
            for (kk, &a) in xrow.iter().enumerate() {
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                for j in 0..n {
                    dwrow[j] += a * dyrow[j];
                }
            }
        }
    }
}

/// Cache-blocked, lane-unrolled portable kernels (the `blocked` tier; also
/// the portable fallback bodies the `simd` tier shadows with intrinsics).
mod blocked {
    use super::{row_all_zero, LANE, TILE_I, TILE_K};

    pub(super) fn matmul_acc_block(
        x: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        let mut i0 = 0;
        while i0 < rows {
            let i1 = (i0 + TILE_I).min(rows);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + TILE_K).min(k);
                for i in i0..i1 {
                    let xrow = &x[i * k + k0..i * k + k1];
                    if row_all_zero(xrow) {
                        continue; // padded row: whole k-slab contributes nothing
                    }
                    let orow = &mut out[i * n..(i + 1) * n];
                    let mut kk = 0;
                    let kt = k1 - k0;
                    while kk + 4 <= kt {
                        let a0 = xrow[kk];
                        let a1 = xrow[kk + 1];
                        let a2 = xrow[kk + 2];
                        let a3 = xrow[kk + 3];
                        let w0 = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let w1 = &w[(k0 + kk + 1) * n..(k0 + kk + 1) * n + n];
                        let w2 = &w[(k0 + kk + 2) * n..(k0 + kk + 2) * n + n];
                        let w3 = &w[(k0 + kk + 3) * n..(k0 + kk + 3) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let o = &mut orow[j..j + LANE];
                            let v0 = &w0[j..j + LANE];
                            let v1 = &w1[j..j + LANE];
                            let v2 = &w2[j..j + LANE];
                            let v3 = &w3[j..j + LANE];
                            for l in 0..LANE {
                                o[l] += a0 * v0[l] + a1 * v1[l] + a2 * v2[l] + a3 * v3[l];
                            }
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a0 * w0[j] + a1 * w1[j] + a2 * w2[j] + a3 * w3[j];
                            j += 1;
                        }
                        kk += 4;
                    }
                    while kk < kt {
                        let a = xrow[kk];
                        let wrow = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let o = &mut orow[j..j + LANE];
                            let v = &wrow[j..j + LANE];
                            for l in 0..LANE {
                                o[l] += a * v[l];
                            }
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a * wrow[j];
                            j += 1;
                        }
                        kk += 1;
                    }
                }
                k0 = k1;
            }
            i0 = i1;
        }
    }

    pub(super) fn matmul_bt_block(
        dy: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            if row_all_zero(dyrow) {
                dxrow.fill(0.0); // masked sample: gradient row is exactly zero
                continue;
            }
            for kk in 0..k {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = [0.0f32; LANE];
                let mut j = 0;
                while j + LANE <= n {
                    let d = &dyrow[j..j + LANE];
                    let v = &wrow[j..j + LANE];
                    for l in 0..LANE {
                        acc[l] += d[l] * v[l];
                    }
                    j += LANE;
                }
                let mut s = 0.0f32;
                while j < n {
                    s += dyrow[j] * wrow[j];
                    j += 1;
                }
                for &a in &acc {
                    s += a;
                }
                dxrow[kk] = s;
            }
        }
    }

    /// Packed-panel input gradient: `wt` is the k-major `[N, K]` transpose
    /// of `w` (`wt[j*k + kk] == w[kk*n + j]`), streamed as an axpy over
    /// `j` — contiguous loads, no horizontal reductions. Overwrites `dx`.
    pub(super) fn matmul_bt_packed_block(
        dy: &[f32],
        wt: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            dxrow.fill(0.0);
            if row_all_zero(dyrow) {
                continue; // masked sample: gradient row is exactly zero
            }
            for j in 0..n {
                let d = dyrow[j];
                let wtrow = &wt[j * k..(j + 1) * k];
                let mut kk = 0;
                while kk + LANE <= k {
                    let o = &mut dxrow[kk..kk + LANE];
                    let v = &wtrow[kk..kk + LANE];
                    for l in 0..LANE {
                        o[l] += d * v[l];
                    }
                    kk += LANE;
                }
                while kk < k {
                    dxrow[kk] += d * wtrow[kk];
                    kk += 1;
                }
            }
        }
    }

    pub(super) fn matmul_at_block(
        x: &[f32],
        dy: &[f32],
        m: usize,
        k: usize,
        n: usize,
        k0: usize,
        dw: &mut [f32],
    ) {
        let kr = dw.len() / n;
        for i in 0..m {
            let dyrow = &dy[i * n..(i + 1) * n];
            if row_all_zero(dyrow) {
                continue; // masked sample contributes no weight gradient
            }
            let xrow = &x[i * k + k0..i * k + k0 + kr];
            for kk in 0..kr {
                let a = xrow[kk];
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                let mut j = 0;
                while j + LANE <= n {
                    let o = &mut dwrow[j..j + LANE];
                    let d = &dyrow[j..j + LANE];
                    for l in 0..LANE {
                        o[l] += a * d[l];
                    }
                    j += LANE;
                }
                while j < n {
                    dwrow[j] += a * dyrow[j];
                    j += 1;
                }
            }
        }
    }
}

/// AVX2/FMA kernels (x86_64 only). Every function is `unsafe` with
/// `target_feature(enable = "avx2,fma")`; callers reach them exclusively
/// through the tier dispatch below, and a [`KernelTier::Simd`] pool can
/// only be constructed after `is_x86_feature_detected!` confirmed support
/// ([`KernelTier::resolved`]), which is what makes the calls sound.
///
/// `matmul_at` deliberately uses `mul`+`add` (NOT `fmadd`): one rounding
/// per operation, matching the scalar/blocked fold bit for bit. The
/// forward/input-grad kernels use FMA freely (cross-tier tolerance 1e-5).
#[cfg(target_arch = "x86_64")]
mod simd {
    use super::{row_all_zero, LANE, TILE_I, TILE_K};
    use core::arch::x86_64::*;

    /// Horizontal sum of 8 lanes (deterministic pairwise association).
    // SAFETY: unsafe solely because of `target_feature`; operates on a
    // register value, no memory access. Callers are themselves
    // avx2-gated kernels in this module.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    // SAFETY: unsafe solely because of `target_feature` — reached only
    // through the tier dispatch below, which holds `KernelTier::Simd`
    // only after runtime AVX2+FMA detection. All loads/stores are
    // unaligned (`loadu`/`storeu`, no alignment precondition) through
    // pointers derived from the argument slices, with every vector
    // access guarded by `j + LANE <= n` and scalar tails for the rest.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matmul_acc_block(
        x: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        let mut i0 = 0;
        while i0 < rows {
            let i1 = (i0 + TILE_I).min(rows);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + TILE_K).min(k);
                for i in i0..i1 {
                    let xrow = &x[i * k + k0..i * k + k1];
                    if row_all_zero(xrow) {
                        continue; // padded row: whole k-slab contributes nothing
                    }
                    let orow = &mut out[i * n..(i + 1) * n];
                    let kt = k1 - k0;
                    let mut kk = 0;
                    while kk + 4 <= kt {
                        let (a0, a1, a2, a3) =
                            (xrow[kk], xrow[kk + 1], xrow[kk + 2], xrow[kk + 3]);
                        let va0 = _mm256_set1_ps(a0);
                        let va1 = _mm256_set1_ps(a1);
                        let va2 = _mm256_set1_ps(a2);
                        let va3 = _mm256_set1_ps(a3);
                        let w0 = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let w1 = &w[(k0 + kk + 1) * n..(k0 + kk + 1) * n + n];
                        let w2 = &w[(k0 + kk + 2) * n..(k0 + kk + 2) * n + n];
                        let w3 = &w[(k0 + kk + 3) * n..(k0 + kk + 3) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let mut o = _mm256_loadu_ps(orow.as_ptr().add(j));
                            o = _mm256_fmadd_ps(va0, _mm256_loadu_ps(w0.as_ptr().add(j)), o);
                            o = _mm256_fmadd_ps(va1, _mm256_loadu_ps(w1.as_ptr().add(j)), o);
                            o = _mm256_fmadd_ps(va2, _mm256_loadu_ps(w2.as_ptr().add(j)), o);
                            o = _mm256_fmadd_ps(va3, _mm256_loadu_ps(w3.as_ptr().add(j)), o);
                            _mm256_storeu_ps(orow.as_mut_ptr().add(j), o);
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a0 * w0[j] + a1 * w1[j] + a2 * w2[j] + a3 * w3[j];
                            j += 1;
                        }
                        kk += 4;
                    }
                    while kk < kt {
                        let a = xrow[kk];
                        let va = _mm256_set1_ps(a);
                        let wrow = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                        let mut j = 0;
                        while j + LANE <= n {
                            let mut o = _mm256_loadu_ps(orow.as_ptr().add(j));
                            o = _mm256_fmadd_ps(va, _mm256_loadu_ps(wrow.as_ptr().add(j)), o);
                            _mm256_storeu_ps(orow.as_mut_ptr().add(j), o);
                            j += LANE;
                        }
                        while j < n {
                            orow[j] += a * wrow[j];
                            j += 1;
                        }
                        kk += 1;
                    }
                }
                k0 = k1;
            }
            i0 = i1;
        }
    }

    // SAFETY: same contract as `matmul_acc_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived loads bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matmul_bt_block(
        dy: &[f32],
        w: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            if row_all_zero(dyrow) {
                dxrow.fill(0.0);
                continue;
            }
            for kk in 0..k {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = _mm256_setzero_ps();
                let mut j = 0;
                while j + LANE <= n {
                    acc = _mm256_fmadd_ps(
                        _mm256_loadu_ps(dyrow.as_ptr().add(j)),
                        _mm256_loadu_ps(wrow.as_ptr().add(j)),
                        acc,
                    );
                    j += LANE;
                }
                let mut s = hsum256(acc);
                while j < n {
                    s += dyrow[j] * wrow[j];
                    j += 1;
                }
                dxrow[kk] = s;
            }
        }
    }

    // SAFETY: same contract as `matmul_acc_block` — unsafe only for
    // `target_feature`, dispatch-gated on detected AVX2+FMA, unaligned
    // slice-derived loads bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn matmul_bt_packed_block(
        dy: &[f32],
        wt: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        dx: &mut [f32],
    ) {
        for i in 0..rows {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            dxrow.fill(0.0);
            if row_all_zero(dyrow) {
                continue;
            }
            for j in 0..n {
                let d = dyrow[j];
                let vd = _mm256_set1_ps(d);
                let wtrow = &wt[j * k..(j + 1) * k];
                let mut kk = 0;
                while kk + LANE <= k {
                    let mut o = _mm256_loadu_ps(dxrow.as_ptr().add(kk));
                    o = _mm256_fmadd_ps(vd, _mm256_loadu_ps(wtrow.as_ptr().add(kk)), o);
                    _mm256_storeu_ps(dxrow.as_mut_ptr().add(kk), o);
                    kk += LANE;
                }
                while kk < k {
                    dxrow[kk] += d * wtrow[kk];
                    kk += 1;
                }
            }
        }
    }

    /// Bitwise-parity-critical: `mul`+`add` only (no FMA), same rounding
    /// sequence per output element as the scalar and blocked folds.
    // SAFETY: unsafe only for `target_feature` (avx2 alone — no FMA, see
    // the parity note above), dispatch-gated on detected AVX2, unaligned
    // slice-derived loads bounded by `j + LANE <= n` with scalar tails.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_at_block(
        x: &[f32],
        dy: &[f32],
        m: usize,
        k: usize,
        n: usize,
        k0: usize,
        dw: &mut [f32],
    ) {
        let kr = dw.len() / n;
        for i in 0..m {
            let dyrow = &dy[i * n..(i + 1) * n];
            if row_all_zero(dyrow) {
                continue;
            }
            let xrow = &x[i * k + k0..i * k + k0 + kr];
            for kk in 0..kr {
                let a = xrow[kk];
                let va = _mm256_set1_ps(a);
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                let mut j = 0;
                while j + LANE <= n {
                    let o = _mm256_add_ps(
                        _mm256_loadu_ps(dwrow.as_ptr().add(j)),
                        _mm256_mul_ps(va, _mm256_loadu_ps(dyrow.as_ptr().add(j))),
                    );
                    _mm256_storeu_ps(dwrow.as_mut_ptr().add(j), o);
                    j += LANE;
                }
                while j < n {
                    dwrow[j] += a * dyrow[j];
                    j += 1;
                }
            }
        }
    }
}

// --- tier dispatch (one leaf call per chunk; `Simd` is only reachable
// through a resolved tier, which guarantees AVX2+FMA support) ---

fn acc_block(tier: KernelTier, x: &[f32], w: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_acc_block(x, w, rows, k, n, out) },
        _ => blocked::matmul_acc_block(x, w, rows, k, n, out),
    }
}

fn bt_block(tier: KernelTier, dy: &[f32], w: &[f32], rows: usize, k: usize, n: usize, dx: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_bt_block(dy, w, rows, k, n, dx) },
        _ => blocked::matmul_bt_block(dy, w, rows, k, n, dx),
    }
}

fn bt_packed_block(tier: KernelTier, dy: &[f32], wt: &[f32], rows: usize, k: usize, n: usize, dx: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_bt_packed_block(dy, wt, rows, k, n, dx) },
        _ => blocked::matmul_bt_packed_block(dy, wt, rows, k, n, dx),
    }
}

fn at_block(tier: KernelTier, x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, k0: usize, dw: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: resolved tiers hold Simd only when avx2+fma are present.
        KernelTier::Simd => unsafe { simd::matmul_at_block(x, dy, m, k, n, k0, dw) },
        _ => blocked::matmul_at_block(x, dy, m, k, n, k0, dw),
    }
}

/// Pack `w[K,N]` into its k-major transpose `wt[N,K]` (row `j` of `wt` is
/// column `j` of `w`), reusing `wt`'s capacity.
pub fn pack_wt(w: &[f32], k: usize, n: usize, wt: &mut Vec<f32>) {
    debug_assert_eq!(w.len(), k * n);
    // The loop below writes every slot, so a warm recycled buffer of the
    // right length skips the resize's redundant zero-fill entirely.
    if wt.len() != k * n {
        wt.clear();
        wt.resize(k * n, 0.0);
    }
    for kk in 0..k {
        let wrow = &w[kk * n..(kk + 1) * n];
        for (j, &v) in wrow.iter().enumerate() {
            wt[j * k + kk] = v;
        }
    }
}

/// `out[M,N] += x[M,K] @ w[K,N]`. `out` must be pre-zeroed by the caller
/// (or hold a partial sum to accumulate into).
pub fn matmul_acc(pool: &Pool, x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_acc(x, w, m, k, n, out);
        return;
    }
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        acc_block(tier, x, w, m, k, n, out);
        return;
    }
    pool.run(
        x.chunks(per * k)
            .zip(out.chunks_mut(per * n))
            .map(|(xc, oc)| move || acc_block(tier, xc, w, xc.len() / k, k, n, oc))
            .collect(),
    );
}

/// `dx[M,K] = dy[M,N] @ w[K,N]^T` (input gradient; overwrites `dx`).
/// Unpacked entry point (dot-product walk over `w` rows); hot paths with a
/// workspace use [`matmul_bt_ws`] instead.
pub fn matmul_bt(pool: &Pool, dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_bt(dy, w, m, k, n, dx);
        return;
    }
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        bt_block(tier, dy, w, m, k, n, dx);
        return;
    }
    pool.run(
        dy.chunks(per * n)
            .zip(dx.chunks_mut(per * k))
            .map(|(dyc, dxc)| move || bt_block(tier, dyc, w, dxc.len() / k, k, n, dxc))
            .collect(),
    );
}

/// [`matmul_bt`] through a generation-tagged packed panel of `w`: the
/// k-major `[N,K]` transpose is built at most once per (layer, step) in
/// `panels` (keyed by `key` — the layer's weight offset — and `gen` — the
/// workspace's step generation, bumped by every optimizer update) and then
/// streamed as a contiguous axpy read. Scalar tier bypasses the panel and
/// runs the reference loops.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt_ws(
    pool: &Pool,
    panels: &mut PanelCache,
    gen: u64,
    key: usize,
    dy: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_bt(dy, w, m, k, n, dx);
        return;
    }
    let (wt, fresh) = panels.slot(key, gen, k, n);
    if fresh {
        pack_wt(w, k, n, wt);
    }
    let wt: &[f32] = wt;
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        bt_packed_block(tier, dy, wt, m, k, n, dx);
        return;
    }
    pool.run(
        dy.chunks(per * n)
            .zip(dx.chunks_mut(per * k))
            .map(|(dyc, dxc)| move || bt_packed_block(tier, dyc, wt, dxc.len() / k, k, n, dxc))
            .collect(),
    );
}

/// `dw[K,N] += x[M,K]^T @ dy[M,N]` (weight gradient; accumulates).
/// Reduce-sensitive: every tier folds rows `i = 0..m` sequentially per
/// output element with one mul+add rounding pair per step, so the three
/// tiers agree **bitwise** and shard-chained folds replay exactly.
pub fn matmul_at(pool: &Pool, x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let tier = pool.tier();
    if tier == KernelTier::Scalar {
        scalar::matmul_at(x, dy, m, k, n, dw);
        return;
    }
    // Partition the K (output) rows: every chunk scans all M samples but
    // owns a disjoint dw row range, so the i-summation order per output
    // row is identical to the sequential kernel.
    let per = pool.rows_per_chunk(k, 2 * m * n);
    if per >= k {
        at_block(tier, x, dy, m, k, n, 0, dw);
        return;
    }
    pool.run(
        dw.chunks_mut(per * n)
            .enumerate()
            .map(|(ci, dwc)| move || at_block(tier, x, dy, m, k, n, ci * per, dwc))
            .collect(),
    );
}

/// `out[i*n..][j] += b[j]` — broadcast-add a bias row.
pub fn add_bias(out: &mut [f32], b: &[f32], m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(b.len(), n);
    for i in 0..m {
        let row = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            row[j] += b[j];
        }
    }
}

/// `db[j] += sum_i dy[i,j]` — bias gradient (column sums; accumulates).
/// One shared implementation for every kernel tier: the row fold per
/// output element is sequential, so shard-chained folds replay it exactly
/// and cross-tier results are identical by construction.
pub fn col_sums(dy: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(db.len(), n);
    for i in 0..m {
        let row = &dy[i * n..(i + 1) * n];
        for j in 0..n {
            db[j] += row[j];
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place tanh.
pub fn tanh(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Zero `grad` wherever the post-activation `act` is <= 0 (ReLU derivative,
/// using the identity `relu(z) > 0 <=> z > 0`).
pub fn relu_backward(grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    for (g, &a) in grad.iter_mut().zip(act) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Scale `grad` by `1 - act^2` (tanh derivative from the post-activation).
pub fn tanh_backward(grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    for (g, &a) in grad.iter_mut().zip(act) {
        *g *= 1.0 - a * a;
    }
}

/// Row-wise log-softmax of `logits[M,N]` into `logp` (may alias shapes, not
/// storage). Numerically stable (max-subtracted).
pub fn log_softmax(logits: &[f32], m: usize, n: usize, logp: &mut [f32]) {
    debug_assert_eq!(logits.len(), m * n);
    debug_assert_eq!(logp.len(), m * n);
    for i in 0..m {
        let row = &logits[i * n..(i + 1) * n];
        let out = &mut logp[i * n..(i + 1) * n];
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            if v > mx {
                mx = v;
            }
        }
        let mut lse = 0.0f32;
        for &v in row {
            lse += (v - mx).exp();
        }
        let lse = lse.ln() + mx;
        for j in 0..n {
            out[j] = row[j] - lse;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq() -> Pool {
        Pool::sequential()
    }

    #[test]
    fn matmul_small_golden() {
        // x = [[1,2],[3,4]], w = [[5,6],[7,8]] -> [[19,22],[43,50]]
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [5.0, 6.0, 7.0, 8.0];
        let mut y = [0.0f32; 4];
        matmul_acc(&seq(), &x, &w, 2, 2, 2, &mut y);
        assert_eq!(y, [19.0, 22.0, 43.0, 50.0]);

        // dy @ w^T and x^T @ dy consistency with hand values.
        let mut dx = [0.0f32; 4];
        matmul_bt(&seq(), &y, &w, 2, 2, 2, &mut dx);
        assert_eq!(dx, [19.0 * 5.0 + 22.0 * 6.0, 19.0 * 7.0 + 22.0 * 8.0,
                        43.0 * 5.0 + 50.0 * 6.0, 43.0 * 7.0 + 50.0 * 8.0]);
        let mut dw = [0.0f32; 4];
        matmul_at(&seq(), &x, &y, 2, 2, 2, &mut dw);
        assert_eq!(dw, [1.0 * 19.0 + 3.0 * 43.0, 1.0 * 22.0 + 3.0 * 50.0,
                        2.0 * 19.0 + 4.0 * 43.0, 2.0 * 22.0 + 4.0 * 50.0]);
    }

    #[test]
    fn every_tier_matches_scalar_reference() {
        // Awkward shape (odd n, n % LANE != 0, k % 4 != 0) on one thread,
        // all executable tiers.
        let (m, k, n) = (5usize, 7usize, 11usize);
        let mut rng = crate::util::rng::Rng::new(42);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; m * n];
        scalar::matmul_acc(&x, &w, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let pool = Pool::with_config(1, tier);
            let mut got = vec![0.0f32; m * n];
            matmul_acc(&pool, &x, &w, m, k, n, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                    "{tier:?}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn packed_panel_transposes_exactly() {
        let (k, n) = (5usize, 3usize);
        let w: Vec<f32> = (0..k * n).map(|v| v as f32).collect();
        let mut wt = Vec::new();
        pack_wt(&w, k, n, &mut wt);
        for kk in 0..k {
            for j in 0..n {
                assert_eq!(wt[j * k + kk], w[kk * n + j]);
            }
        }
    }

    #[test]
    fn packed_bt_matches_reference_and_reuses_panel() {
        let (m, k, n) = (6usize, 13usize, 9usize);
        let mut rng = crate::util::rng::Rng::new(9);
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; m * k];
        scalar::matmul_bt(&dy, &w, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let pool = Pool::with_config(1, tier);
            let mut panels = PanelCache::default();
            let mut got = vec![0.0f32; m * k];
            matmul_bt_ws(&pool, &mut panels, 1, 100, &dy, &w, m, k, n, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{tier:?}: {a} vs {b}");
            }
        }
        // A stale generation must repack: same key, new weights, new gen.
        let pool = Pool::with_config(1, KernelTier::Blocked);
        let mut panels = PanelCache::default();
        let mut first = vec![0.0f32; m * k];
        matmul_bt_ws(&pool, &mut panels, 1, 100, &dy, &w, m, k, n, &mut first);
        let w2: Vec<f32> = w.iter().map(|v| v + 1.0).collect();
        let mut second = vec![0.0f32; m * k];
        matmul_bt_ws(&pool, &mut panels, 2, 100, &dy, &w2, m, k, n, &mut second);
        let mut want2 = vec![0.0f32; m * k];
        scalar::matmul_bt(&dy, &w2, m, k, n, &mut want2);
        for (a, b) in second.iter().zip(&want2) {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "stale panel survived a generation bump: {a} vs {b}"
            );
        }
    }

    #[test]
    fn zero_rows_are_skipped_without_changing_results() {
        let (m, k, n) = (6usize, 9usize, 10usize);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        // Pad out the last two rows (mask-0 samples).
        for v in &mut x[4 * k..] {
            *v = 0.0;
        }
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; m * n];
        scalar::matmul_acc(&x, &w, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let mut got = vec![0.0f32; m * n];
            matmul_acc(&Pool::with_config(1, tier), &x, &w, m, k, n, &mut got);
            for r in 4..6 {
                assert!(got[r * n..(r + 1) * n].iter().all(|&v| v == 0.0));
            }
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{tier:?}");
            }
        }
    }

    #[test]
    fn threaded_matmul_is_bitwise_stable_across_thread_counts() {
        // Big enough that 2/3/7 threads genuinely partition the rows, for
        // every executable tier.
        let (m, k, n) = (256usize, 64usize, 48usize);
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        for tier in KernelTier::available() {
            let mut base = vec![0.0f32; m * n];
            matmul_acc(&Pool::with_config(1, tier), &x, &w, m, k, n, &mut base);
            for threads in [2usize, 3, 7] {
                let mut out = vec![0.0f32; m * n];
                matmul_acc(&Pool::with_config(threads, tier), &x, &w, m, k, n, &mut out);
                assert_eq!(out, base, "{tier:?} threads={threads} diverged");
            }
        }
    }

    #[test]
    fn matmul_at_is_bitwise_identical_across_tiers() {
        // The reduce-sensitive kernel: all tiers share one fold order and
        // one rounding sequence per output element.
        let (m, k, n) = (33usize, 17usize, 20usize);
        let mut rng = crate::util::rng::Rng::new(13);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let dy: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; k * n];
        scalar::matmul_at(&x, &dy, m, k, n, &mut want);
        for tier in KernelTier::available() {
            let mut got = vec![0.0f32; k * n];
            matmul_at(&Pool::with_config(1, tier), &x, &dy, m, k, n, &mut got);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{tier:?}: dw[{i}] {a} != scalar {b}"
                );
            }
        }
    }

    #[test]
    fn log_softmax_rows_normalize() {
        let logits = [1.0f32, 2.0, 3.0, -5.0, 0.0, 5.0];
        let mut lp = [0.0f32; 6];
        log_softmax(&logits, 2, 3, &mut lp);
        for i in 0..2 {
            let total: f32 = lp[i * 3..(i + 1) * 3].iter().map(|l| l.exp()).sum();
            assert!((total - 1.0).abs() < 1e-5, "row {i}: {total}");
        }
        // Monotone with the logits.
        assert!(lp[0] < lp[1] && lp[1] < lp[2]);
    }

    #[test]
    fn activation_derivative_masks() {
        let mut g = [1.0f32, 1.0, 1.0];
        relu_backward(&mut g, &[0.5, 0.0, 2.0]);
        assert_eq!(g, [1.0, 0.0, 1.0]);
        let mut g = [1.0f32, 1.0];
        tanh_backward(&mut g, &[0.0, 0.5]);
        assert!((g[0] - 1.0).abs() < 1e-6 && (g[1] - 0.75).abs() < 1e-6);
    }
}
