//! Dense linear algebra for the native backend: cache-blocked, lane-unrolled
//! and row-partitioned across a scoped thread pool.
//!
//! Shapes follow the JAX convention used by `python/compile`: activations
//! are `[M, K]` row-major, weights `[K, N]` row-major (`fan_in` rows). The
//! three multiply kernels cover forward (`x @ w`), input gradients
//! (`dy @ w^T`) and weight gradients (`x^T @ dy`).
//!
//! Kernel structure (see [`scalar`] for the plain reference loops):
//!
//! * **Tiling** — `matmul_acc` blocks rows by [`TILE_I`] and the reduction
//!   dimension by [`TILE_K`], so one `TILE_K x n` slab of `w` stays hot in
//!   L1 across a row block; the other kernels stream contiguously by
//!   construction (their operands at zoo sizes are L1/L2-resident).
//! * **Unrolling** — inner loops run over fixed [`LANE`]-wide sub-slices
//!   with the bounds hoisted, which LLVM turns into SIMD; `matmul_acc`
//!   additionally unrolls 4 reduction steps so each pass over the output
//!   row performs 4 fused multiply-adds per element.
//! * **Row-level sparsity skip** — an all-zero input/gradient *row* (a
//!   padded sample, or a masked sample whose loss gradient is exactly zero)
//!   skips that row's whole O(k*n) contribution. This replaces the old
//!   per-element `a == 0.0` branch, which pessimized dense inputs by
//!   putting a compare+branch inside the hot loop.
//! * **Threading** — `matmul_acc`/`matmul_bt` partition the M (batch) rows
//!   and `matmul_at` the K (output) rows across `pool.threads()` scoped
//!   threads. Each output row is written by exactly one thread and no
//!   per-row summation order changes, so results are bitwise identical for
//!   every `DYNAMIX_THREADS` value; small problems run inline (see
//!   [`super::exec::Pool::rows_per_chunk`]).

use super::exec::Pool;

/// Unroll width of the innermost (column) loops. 8 f32 lanes = one AVX2
/// register / two NEON registers; LLVM vectorizes the fixed-size bodies.
pub const LANE: usize = 8;

/// Row-block size of `matmul_acc` (output rows revisited per `w` slab).
pub const TILE_I: usize = 32;

/// Reduction-block size of `matmul_acc`: a `TILE_K x n` slab of `w` is
/// `64*n*4` bytes — L1-resident for every zoo width.
pub const TILE_K: usize = 64;

#[inline]
fn row_all_zero(row: &[f32]) -> bool {
    // Dense rows exit on the first element; padded rows cost one O(len)
    // scan in exchange for skipping O(len * n) multiply-adds.
    row.iter().all(|&v| v == 0.0)
}

/// Scalar reference kernels: the straightforward triple loops, kept as the
/// numerical ground truth for parity tests and for documenting intent.
/// No tiling, no unrolling, no threading, no sparsity skips.
pub mod scalar {
    /// `out[M,N] += x[M,K] @ w[K,N]`.
    pub fn matmul_acc(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in xrow.iter().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += a * wrow[j];
                }
            }
        }
    }

    /// `dx[M,K] = dy[M,N] @ w[K,N]^T` (overwrites `dx`).
    pub fn matmul_bt(dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
        for i in 0..m {
            let dyrow = &dy[i * n..(i + 1) * n];
            let dxrow = &mut dx[i * k..(i + 1) * k];
            for kk in 0..k {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut s = 0.0f32;
                for j in 0..n {
                    s += dyrow[j] * wrow[j];
                }
                dxrow[kk] = s;
            }
        }
    }

    /// `dw[K,N] += x[M,K]^T @ dy[M,N]` (accumulates).
    pub fn matmul_at(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let dyrow = &dy[i * n..(i + 1) * n];
            for (kk, &a) in xrow.iter().enumerate() {
                let dwrow = &mut dw[kk * n..(kk + 1) * n];
                for j in 0..n {
                    dwrow[j] += a * dyrow[j];
                }
            }
        }
    }
}

/// `out[M,N] += x[M,K] @ w[K,N]`. `out` must be pre-zeroed by the caller
/// (or hold a partial sum to accumulate into).
pub fn matmul_acc(pool: &Pool, x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        matmul_acc_block(x, w, m, k, n, out);
        return;
    }
    std::thread::scope(|s| {
        for (xc, oc) in x.chunks(per * k).zip(out.chunks_mut(per * n)) {
            s.spawn(move || matmul_acc_block(xc, w, xc.len() / k, k, n, oc));
        }
    });
}

fn matmul_acc_block(x: &[f32], w: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    let mut i0 = 0;
    while i0 < rows {
        let i1 = (i0 + TILE_I).min(rows);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + TILE_K).min(k);
            for i in i0..i1 {
                let xrow = &x[i * k + k0..i * k + k1];
                if row_all_zero(xrow) {
                    continue; // padded row: whole k-slab contributes nothing
                }
                let orow = &mut out[i * n..(i + 1) * n];
                let mut kk = 0;
                let kt = k1 - k0;
                while kk + 4 <= kt {
                    let a0 = xrow[kk];
                    let a1 = xrow[kk + 1];
                    let a2 = xrow[kk + 2];
                    let a3 = xrow[kk + 3];
                    let w0 = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                    let w1 = &w[(k0 + kk + 1) * n..(k0 + kk + 1) * n + n];
                    let w2 = &w[(k0 + kk + 2) * n..(k0 + kk + 2) * n + n];
                    let w3 = &w[(k0 + kk + 3) * n..(k0 + kk + 3) * n + n];
                    let mut j = 0;
                    while j + LANE <= n {
                        let o = &mut orow[j..j + LANE];
                        let v0 = &w0[j..j + LANE];
                        let v1 = &w1[j..j + LANE];
                        let v2 = &w2[j..j + LANE];
                        let v3 = &w3[j..j + LANE];
                        for l in 0..LANE {
                            o[l] += a0 * v0[l] + a1 * v1[l] + a2 * v2[l] + a3 * v3[l];
                        }
                        j += LANE;
                    }
                    while j < n {
                        orow[j] += a0 * w0[j] + a1 * w1[j] + a2 * w2[j] + a3 * w3[j];
                        j += 1;
                    }
                    kk += 4;
                }
                while kk < kt {
                    let a = xrow[kk];
                    let wrow = &w[(k0 + kk) * n..(k0 + kk) * n + n];
                    let mut j = 0;
                    while j + LANE <= n {
                        let o = &mut orow[j..j + LANE];
                        let v = &wrow[j..j + LANE];
                        for l in 0..LANE {
                            o[l] += a * v[l];
                        }
                        j += LANE;
                    }
                    while j < n {
                        orow[j] += a * wrow[j];
                        j += 1;
                    }
                    kk += 1;
                }
            }
            k0 = k1;
        }
        i0 = i1;
    }
}

/// `dx[M,K] = dy[M,N] @ w[K,N]^T` (input gradient; overwrites `dx`).
pub fn matmul_bt(pool: &Pool, dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    let per = pool.rows_per_chunk(m, 2 * k * n);
    if per >= m {
        matmul_bt_block(dy, w, m, k, n, dx);
        return;
    }
    std::thread::scope(|s| {
        for (dyc, dxc) in dy.chunks(per * n).zip(dx.chunks_mut(per * k)) {
            s.spawn(move || matmul_bt_block(dyc, w, dxc.len() / k, k, n, dxc));
        }
    });
}

fn matmul_bt_block(dy: &[f32], w: &[f32], rows: usize, k: usize, n: usize, dx: &mut [f32]) {
    for i in 0..rows {
        let dyrow = &dy[i * n..(i + 1) * n];
        let dxrow = &mut dx[i * k..(i + 1) * k];
        if row_all_zero(dyrow) {
            dxrow.fill(0.0); // masked sample: gradient row is exactly zero
            continue;
        }
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = [0.0f32; LANE];
            let mut j = 0;
            while j + LANE <= n {
                let d = &dyrow[j..j + LANE];
                let v = &wrow[j..j + LANE];
                for l in 0..LANE {
                    acc[l] += d[l] * v[l];
                }
                j += LANE;
            }
            let mut s = 0.0f32;
            while j < n {
                s += dyrow[j] * wrow[j];
                j += 1;
            }
            for &a in &acc {
                s += a;
            }
            dxrow[kk] = s;
        }
    }
}

/// `dw[K,N] += x[M,K]^T @ dy[M,N]` (weight gradient; accumulates).
pub fn matmul_at(pool: &Pool, x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    // Partition the K (output) rows: every thread scans all M samples but
    // owns a disjoint dw row range, so the i-summation order per output
    // row is identical to the sequential kernel.
    let per = pool.rows_per_chunk(k, 2 * m * n);
    if per >= k {
        matmul_at_block(x, dy, m, k, n, 0, dw);
        return;
    }
    std::thread::scope(|s| {
        for (ci, dwc) in dw.chunks_mut(per * n).enumerate() {
            s.spawn(move || matmul_at_block(x, dy, m, k, n, ci * per, dwc));
        }
    });
}

fn matmul_at_block(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize, k0: usize, dw: &mut [f32]) {
    let kr = dw.len() / n;
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        if row_all_zero(dyrow) {
            continue; // masked sample contributes no weight gradient
        }
        let xrow = &x[i * k + k0..i * k + k0 + kr];
        for kk in 0..kr {
            let a = xrow[kk];
            let dwrow = &mut dw[kk * n..(kk + 1) * n];
            let mut j = 0;
            while j + LANE <= n {
                let o = &mut dwrow[j..j + LANE];
                let d = &dyrow[j..j + LANE];
                for l in 0..LANE {
                    o[l] += a * d[l];
                }
                j += LANE;
            }
            while j < n {
                dwrow[j] += a * dyrow[j];
                j += 1;
            }
        }
    }
}

/// `out[i*n..][j] += b[j]` — broadcast-add a bias row.
pub fn add_bias(out: &mut [f32], b: &[f32], m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(b.len(), n);
    for i in 0..m {
        let row = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            row[j] += b[j];
        }
    }
}

/// `db[j] += sum_i dy[i,j]` — bias gradient (column sums; accumulates).
pub fn col_sums(dy: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(db.len(), n);
    for i in 0..m {
        let row = &dy[i * n..(i + 1) * n];
        for j in 0..n {
            db[j] += row[j];
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place tanh.
pub fn tanh(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Zero `grad` wherever the post-activation `act` is <= 0 (ReLU derivative,
/// using the identity `relu(z) > 0 <=> z > 0`).
pub fn relu_backward(grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    for (g, &a) in grad.iter_mut().zip(act) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Scale `grad` by `1 - act^2` (tanh derivative from the post-activation).
pub fn tanh_backward(grad: &mut [f32], act: &[f32]) {
    debug_assert_eq!(grad.len(), act.len());
    for (g, &a) in grad.iter_mut().zip(act) {
        *g *= 1.0 - a * a;
    }
}

/// Row-wise log-softmax of `logits[M,N]` into `logp` (may alias shapes, not
/// storage). Numerically stable (max-subtracted).
pub fn log_softmax(logits: &[f32], m: usize, n: usize, logp: &mut [f32]) {
    debug_assert_eq!(logits.len(), m * n);
    debug_assert_eq!(logp.len(), m * n);
    for i in 0..m {
        let row = &logits[i * n..(i + 1) * n];
        let out = &mut logp[i * n..(i + 1) * n];
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            if v > mx {
                mx = v;
            }
        }
        let mut lse = 0.0f32;
        for &v in row {
            lse += (v - mx).exp();
        }
        let lse = lse.ln() + mx;
        for j in 0..n {
            out[j] = row[j] - lse;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq() -> Pool {
        Pool::sequential()
    }

    #[test]
    fn matmul_small_golden() {
        // x = [[1,2],[3,4]], w = [[5,6],[7,8]] -> [[19,22],[43,50]]
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [5.0, 6.0, 7.0, 8.0];
        let mut y = [0.0f32; 4];
        matmul_acc(&seq(), &x, &w, 2, 2, 2, &mut y);
        assert_eq!(y, [19.0, 22.0, 43.0, 50.0]);

        // dy @ w^T and x^T @ dy consistency with hand values.
        let mut dx = [0.0f32; 4];
        matmul_bt(&seq(), &y, &w, 2, 2, 2, &mut dx);
        assert_eq!(dx, [19.0 * 5.0 + 22.0 * 6.0, 19.0 * 7.0 + 22.0 * 8.0,
                        43.0 * 5.0 + 50.0 * 6.0, 43.0 * 7.0 + 50.0 * 8.0]);
        let mut dw = [0.0f32; 4];
        matmul_at(&seq(), &x, &y, 2, 2, 2, &mut dw);
        assert_eq!(dw, [1.0 * 19.0 + 3.0 * 43.0, 1.0 * 22.0 + 3.0 * 50.0,
                        2.0 * 19.0 + 4.0 * 43.0, 2.0 * 22.0 + 4.0 * 50.0]);
    }

    #[test]
    fn blocked_kernels_match_scalar_reference() {
        // Awkward shape (odd n, n % LANE != 0, k % 4 != 0) on one thread.
        let (m, k, n) = (5usize, 7usize, 11usize);
        let mut rng = crate::util::rng::Rng::new(42);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        matmul_acc(&seq(), &x, &w, m, k, n, &mut got);
        scalar::matmul_acc(&x, &w, m, k, n, &mut want);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn zero_rows_are_skipped_without_changing_results() {
        let (m, k, n) = (6usize, 9usize, 10usize);
        let mut rng = crate::util::rng::Rng::new(7);
        let mut x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        // Pad out the last two rows (mask-0 samples).
        for v in &mut x[4 * k..] {
            *v = 0.0;
        }
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        matmul_acc(&seq(), &x, &w, m, k, n, &mut got);
        scalar::matmul_acc(&x, &w, m, k, n, &mut want);
        for r in 4..6 {
            assert!(got[r * n..(r + 1) * n].iter().all(|&v| v == 0.0));
        }
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn threaded_matmul_is_bitwise_stable_across_thread_counts() {
        // Big enough that 2/3/7 threads genuinely partition the rows.
        let (m, k, n) = (256usize, 64usize, 48usize);
        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut base = vec![0.0f32; m * n];
        matmul_acc(&Pool::with_threads(1), &x, &w, m, k, n, &mut base);
        for threads in [2usize, 3, 7] {
            let mut out = vec![0.0f32; m * n];
            matmul_acc(&Pool::with_threads(threads), &x, &w, m, k, n, &mut out);
            assert_eq!(out, base, "threads={threads} diverged");
        }
    }

    #[test]
    fn log_softmax_rows_normalize() {
        let logits = [1.0f32, 2.0, 3.0, -5.0, 0.0, 5.0];
        let mut lp = [0.0f32; 6];
        log_softmax(&logits, 2, 3, &mut lp);
        for i in 0..2 {
            let total: f32 = lp[i * 3..(i + 1) * 3].iter().map(|l| l.exp()).sum();
            assert!((total - 1.0).abs() < 1e-5, "row {i}: {total}");
        }
        // Monotone with the logits.
        assert!(lp[0] < lp[1] && lp[1] < lp[2]);
    }

    #[test]
    fn activation_derivative_masks() {
        let mut g = [1.0f32, 1.0, 1.0];
        relu_backward(&mut g, &[0.5, 0.0, 2.0]);
        assert_eq!(g, [1.0, 0.0, 1.0]);
        let mut g = [1.0f32, 1.0];
        tanh_backward(&mut g, &[0.0, 0.5]);
        assert!((g[0] - 1.0).abs() < 1e-6 && (g[1] - 0.75).abs() < 1e-6);
    }
}
