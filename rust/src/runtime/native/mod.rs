//! Pure-Rust compute backend (the default).
//!
//! Implements the full artifact contract — policy forward/update, the
//! train-step bucket ladder, eval, grad stats, seeded inits — with no
//! Python, no artifacts and no external dependencies, so `cargo test`
//! works from a fresh clone on any machine. Numerical semantics mirror
//! `python/compile` (see [`model`] and [`policy`]); parameter layouts are
//! `ravel_pytree`-compatible so policy/model snapshots interchange with the
//! XLA backend.

pub mod exec;
pub mod linalg;
pub mod model;
pub mod policy;
pub mod workspace;

use crate::config::{Optimizer, PpoVariant};
use crate::runtime::backend::{
    ComputeBackend, OptState, PolicyOut, PpoHyper, PpoMinibatch, PpoStats, Schema, TrainOut,
};
use crate::runtime::manifest::ModelInfo;
use exec::Pool;
pub use exec::{CommLane, KernelTier};
use model::{apply_adam, apply_sgd, masked_ce_loss_ws, masked_ce_rows, normalized_grad_stats, ModelDef};
use std::collections::BTreeMap;
use workspace::{Workspace, WorkspacePool};

/// Batch-bucket ladder, mirroring `compile/aot.py::BUCKETS`.
pub const BUCKETS: [usize; 19] = [
    32, 64, 128, 192, 256, 384, 512, 768, 1024, 1536, 2048, 3072, 4096, 6144, 8192, 12288, 16384,
    24576, 32768,
];
pub const EVAL_BATCH: usize = 1024;

pub struct NativeBackend {
    schema: Schema,
    defs: BTreeMap<String, ModelDef>,
    /// Execution policy: kernel tier (`DYNAMIX_KERNEL`) + partition width
    /// (`DYNAMIX_THREADS`), backed by the process-shared persistent
    /// worker pool.
    pool: Pool,
    /// Recycled scratch buffers: steady-state steps allocate nothing.
    ws: WorkspacePool,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    /// Backend on the process-global pool: `DYNAMIX_THREADS` and
    /// `DYNAMIX_KERNEL` are read once per process and every backend —
    /// native or sharded — shares one persistent worker set.
    pub fn new() -> Self {
        Self::with_pool(Pool::global())
    }

    /// Backend with a pinned kernel thread count (global kernel tier).
    /// Never reads the environment, so tests that pin thread counts don't
    /// race with tests that mutate the process environment.
    pub fn with_threads(threads: usize) -> Self {
        Self::with_pool(Pool::with_threads(threads))
    }

    /// Backend with a pinned thread count *and* kernel tier (parity
    /// suites and per-tier benches). The tier is resolved, so requesting
    /// `Simd` on unsupported hardware falls back to `Blocked`.
    pub fn with_kernel(threads: usize, tier: KernelTier) -> Self {
        Self::with_pool(Pool::with_config(threads, tier))
    }

    fn with_pool(pool: Pool) -> Self {
        let defs: BTreeMap<String, ModelDef> = ModelDef::zoo()
            .into_iter()
            .map(|d| (d.name.to_string(), d))
            .collect();
        let models: BTreeMap<String, ModelInfo> = defs
            .iter()
            .map(|(name, d)| {
                (
                    name.clone(),
                    ModelInfo {
                        family: match d.family {
                            model::Family::Vgg => "vgg".into(),
                            model::Family::Resnet => "resnet".into(),
                        },
                        depth: d.depth,
                        width: d.width,
                        num_classes: d.classes,
                        feature_dim: d.feature_dim,
                        param_count: d.param_count(),
                        dataset: d.dataset().into(),
                    },
                )
            })
            .collect();
        NativeBackend {
            schema: Schema {
                buckets: BUCKETS.to_vec(),
                eval_batch: EVAL_BATCH,
                state_dim: policy::STATE_DIM,
                n_actions: policy::N_ACTIONS,
                max_workers: policy::MAX_WORKERS,
                ppo_minibatch: policy::MINIBATCH,
                feature_dim: 128,
                policy_param_count: policy::PARAM_COUNT,
                models,
            },
            defs,
            pool,
            ws: WorkspacePool::default(),
        }
    }

    /// Kernel thread count this backend fans out over.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Kernel tier this backend dispatches to (always resolved — `Simd`
    /// only on hardware that supports it).
    pub fn kernel_tier(&self) -> KernelTier {
        self.pool.tier()
    }

    /// (pooled workspace count, reserved scratch bytes) — flat across
    /// steady-state steps; the allocation regression test asserts on it.
    pub fn workspace_stats(&self) -> (usize, usize) {
        self.ws.stats()
    }

    /// The backend's worker pool — shared with the sharded/zero planes so
    /// their sliced optimizer applies fan out over the same threads.
    pub(crate) fn pool(&self) -> &Pool {
        &self.pool
    }

    fn def(&self, model: &str) -> anyhow::Result<&ModelDef> {
        self.defs
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))
    }

    /// The deterministic bucket plan the overlapped ring drives `model`'s
    /// backward with (see [`ModelDef::bucket_plan`]): completion-ordered
    /// stages coalesced toward `target_bytes` per bucket. Pure layout
    /// arithmetic — every caller with the same model and target derives
    /// the identical plan, so it is never transmitted.
    pub fn bucket_plan(
        &self,
        model: &str,
        target_bytes: usize,
    ) -> anyhow::Result<Vec<model::GradBucket>> {
        Ok(self.def(model)?.bucket_plan(target_bytes))
    }

    /// ZeRO-plane parameter ownership map (see
    /// [`ModelDef::param_partition`]): one contiguous bucket-aligned slice
    /// of the flat parameter vector per shard, empty for inactive shards.
    /// Pure layout arithmetic, like the bucket plan.
    pub fn param_partition(
        &self,
        model: &str,
        active: &[bool],
        target_bytes: usize,
    ) -> anyhow::Result<Vec<std::ops::Range<usize>>> {
        Ok(self.def(model)?.param_partition(active, target_bytes))
    }

    /// Forward half of one shard step: forward + per-row loss pieces for
    /// `m = mask.len()` rows that form a contiguous slice of a fused batch
    /// whose global mask sum is `denom`. Row counts are unconstrained (no
    /// bucket ladder) — a shard may hold a single sample, or none. The
    /// returned [`ShardCtx`] retains the activations and loss gradient for
    /// [`NativeBackend::shard_backward_acc`].
    pub fn shard_forward(
        &self,
        model: &str,
        params: &[f32],
        x: Vec<f32>,
        y: &[i32],
        mask: &[f32],
        denom: f32,
    ) -> anyhow::Result<(ShardCtx, ShardFwdOut)> {
        let def = self.def(model)?;
        let m = mask.len();
        anyhow::ensure!(
            params.len() == def.param_count(),
            "params len {} != {}",
            params.len(),
            def.param_count()
        );
        anyhow::ensure!(
            x.len() == m * def.feature_dim && y.len() == m,
            "shard rows mismatch: x {} y {} for m {m}",
            x.len(),
            y.len()
        );
        anyhow::ensure!(denom >= 1.0, "denom {denom} must be >= 1");
        ensure_labels_in_range(model, y, def.classes)?;
        let mut ws = self.ws.take();
        // One generation covers the fwd/bwd pair of this shard step — the
        // retained workspace carries it into `shard_backward_acc`, where
        // the packed panels of this step's params are (re)built under it.
        ws.begin_step();
        def.forward_ws(&self.pool, params, &x, m, &mut ws);
        let mut out = ShardFwdOut { loss_terms: Vec::new(), correct: Vec::new() };
        masked_ce_rows(
            &self.pool,
            &ws.logits,
            y,
            mask,
            m,
            def.classes,
            denom,
            &mut ws.logp,
            &mut out.loss_terms,
            &mut out.correct,
            &mut ws.dlogits,
        );
        Ok((
            ShardCtx { ws, x, m, model: model.to_string(), folded: 0, prepped: 0 },
            out,
        ))
    }

    /// Backward half of a shard step: folds this shard's rows into `grad`
    /// — the traveling accumulator of the chained reduction — strictly in
    /// row order. When `grad` is the running partial of all earlier rows,
    /// the result is bit-identical to the fused backward over those rows
    /// plus this shard's (see [`ModelDef::backward_acc_ws`]).
    pub fn shard_backward_acc(
        &self,
        params: &[f32],
        mut ctx: ShardCtx,
        grad: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let def = self.def(&ctx.model)?;
        anyhow::ensure!(
            grad.len() == def.param_count(),
            "grad len {} != {}",
            grad.len(),
            def.param_count()
        );
        anyhow::ensure!(params.len() == def.param_count(), "params len mismatch");
        std::mem::swap(&mut ctx.ws.grad, grad);
        def.backward_acc_ws(&self.pool, params, &ctx.x, ctx.m, &mut ctx.ws);
        std::mem::swap(&mut ctx.ws.grad, grad);
        self.ws.put(ctx.ws);
        Ok(())
    }

    /// Fold one gradient **bucket** into this shard's backward, resuming
    /// from the upstream shard's accumulator. `seed` is the traveling
    /// accumulator for the bucket window `[offset, offset + seed.len())`
    /// (all zeros on the first ring position); the window must be exactly
    /// the stage run starting at this shard's fold cursor — derived locally
    /// from the model layout, never trusted from the wire. On return `out`
    /// holds the folded window, ready for the next hop.
    ///
    /// PARITY: the seed is copied into `ws.grad` *before* the stage folds
    /// run, so each per-element row fold continues the upstream shard's
    /// sequential sum — bit-identical to the fused backward over all rows.
    pub fn shard_backward_bucket(
        &self,
        params: &[f32],
        ctx: &mut ShardCtx,
        offset: usize,
        seed: &[f32],
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let def = self.def(&ctx.model)?;
        let pc = def.param_count();
        anyhow::ensure!(params.len() == pc, "params len mismatch");
        let stages = def.stages_for_range(ctx.folded, offset, seed.len()).ok_or_else(|| {
            anyhow::anyhow!(
                "bucket [{offset}, {}) does not match a stage run at fold cursor {} of {}",
                offset + seed.len(),
                ctx.folded,
                ctx.model
            )
        })?;
        if ctx.folded == 0 {
            // First bucket of this step: size the accumulator. Every
            // element is seeded exactly once (the plan tiles the vector),
            // so the zero fill is shape-only, never part of a sum.
            ctx.ws.grad.clear();
            ctx.ws.grad.resize(pc, 0.0);
        }
        ctx.ws.grad[offset..offset + seed.len()].copy_from_slice(seed);
        for k in stages.clone() {
            if ctx.prepped == k {
                def.backward_stage_prep(&self.pool, params, ctx.m, &mut ctx.ws, k);
                ctx.prepped = k + 1;
            }
            debug_assert!(ctx.prepped > k, "stage {k} folding before its prep");
            def.backward_stage_fold(&self.pool, params, &ctx.x, ctx.m, &mut ctx.ws, k);
        }
        ctx.folded = stages.end;
        out.clear();
        out.extend_from_slice(&ctx.ws.grad[offset..offset + seed.len()]);
        Ok(())
    }

    /// Run the *next* stage's dx-propagation ahead of its bucket seed —
    /// the compute that overlaps the previous bucket's wire hop. Safe to
    /// call any time: it is a no-op when the next stage is already prepped
    /// or the backward is complete, and it never touches `ws.grad`.
    pub fn shard_backward_prep_ahead(
        &self,
        params: &[f32],
        ctx: &mut ShardCtx,
    ) -> anyhow::Result<()> {
        let def = self.def(&ctx.model)?;
        anyhow::ensure!(params.len() == def.param_count(), "params len mismatch");
        if ctx.prepped == ctx.folded && ctx.folded < def.n_stages() {
            def.backward_stage_prep(&self.pool, params, ctx.m, &mut ctx.ws, ctx.folded);
            ctx.prepped = ctx.folded + 1;
        }
        Ok(())
    }

    /// Whether every completion stage of this shard's backward has folded.
    pub fn shard_backward_done(&self, ctx: &ShardCtx) -> anyhow::Result<bool> {
        Ok(ctx.folded == self.def(&ctx.model)?.n_stages())
    }

    /// Retire a fully-folded bucketed backward, returning its workspace to
    /// the pool. Errors (without leaking the workspace) if the bucket plan
    /// never covered every stage — a leader/worker plan disagreement.
    pub fn shard_finish(&self, ctx: ShardCtx) -> anyhow::Result<()> {
        let n = self.def(&ctx.model)?.n_stages();
        let folded = ctx.folded;
        self.ws.put(ctx.ws);
        anyhow::ensure!(
            folded == n,
            "bucketed backward retired after {folded}/{n} stages"
        );
        Ok(())
    }

    /// Return a forward-only shard step's workspace to the pool (eval
    /// steps have no backward half).
    pub fn shard_discard(&self, ctx: ShardCtx) {
        self.ws.put(ctx.ws);
    }
}

/// One shard's in-flight train step: forward activations, loss gradient
/// and input rows retained between [`NativeBackend::shard_forward`] and
/// the backward half ([`NativeBackend::shard_backward_acc`] bulk, or a
/// [`NativeBackend::shard_backward_bucket`] sequence when overlapping).
/// `folded`/`prepped` are the bucketed backward's stage cursors, with
/// `folded <= prepped <= folded + 1` as the standing invariant.
pub struct ShardCtx {
    ws: Workspace,
    x: Vec<f32>,
    m: usize,
    model: String,
    folded: usize,
    prepped: usize,
}

/// Per-row outputs of one shard's forward half: loss terms and masked
/// correctness for this shard's rows, in row order.
pub struct ShardFwdOut {
    pub loss_terms: Vec<f32>,
    pub correct: Vec<f32>,
}

/// Fail loudly (with model + offending value) on out-of-range labels
/// instead of panicking mid-loop; the XLA one_hot path would silently
/// zero such rows, which hides dataset/config mismatches.
fn ensure_labels_in_range(model: &str, y: &[i32], classes: usize) -> anyhow::Result<()> {
    if let Some(&bad) = y.iter().find(|&&yi| yi < 0 || yi as usize >= classes) {
        anyhow::bail!("{model}: label {bad} outside [0, {classes})");
    }
    Ok(())
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn schema(&self) -> &Schema {
        &self.schema
    }

    fn init_params(&self, model: &str, seed: u64) -> anyhow::Result<Vec<f32>> {
        Ok(self.def(model)?.init(seed))
    }

    fn init_policy(&self, seed: u64) -> anyhow::Result<Vec<f32>> {
        Ok(policy::init_policy(seed))
    }

    fn policy_forward(&self, theta: &[f32], states: &[f32]) -> anyhow::Result<PolicyOut> {
        // Enforce the trait contract ([max_workers, state_dim]) even though
        // the underlying kernel is row-count-flexible, so native and xla
        // backends accept exactly the same inputs.
        let want = self.schema.max_workers * self.schema.state_dim;
        anyhow::ensure!(
            states.len() == want,
            "states len {} != max_workers*state_dim {want}",
            states.len()
        );
        policy::policy_forward(theta, states)
    }

    fn policy_update(
        &self,
        variant: PpoVariant,
        opt: &mut OptState,
        mb: &PpoMinibatch,
        hp: PpoHyper,
    ) -> anyhow::Result<PpoStats> {
        // Same backend-parity rule as policy_forward: the xla artifact is
        // compiled for exactly ppo_minibatch rows, so native enforces it.
        anyhow::ensure!(
            mb.mask.len() == self.schema.ppo_minibatch,
            "minibatch rows {} != ppo_minibatch {}",
            mb.mask.len(),
            self.schema.ppo_minibatch
        );
        let mut ws = self.ws.take();
        let r = policy::policy_update_ws(&self.pool, &mut ws, variant, opt, mb, hp);
        self.ws.put(ws);
        r
    }

    fn train_step(
        &self,
        model: &str,
        optimizer: Optimizer,
        bucket: usize,
        state: &mut OptState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> anyhow::Result<TrainOut> {
        let mut out = TrainOut::default();
        self.train_step_into(model, optimizer, bucket, state, x, y, mask, lr, &mut out)?;
        Ok(out)
    }

    fn train_step_into(
        &self,
        model: &str,
        optimizer: Optimizer,
        bucket: usize,
        state: &mut OptState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
        out: &mut TrainOut,
    ) -> anyhow::Result<()> {
        let def = self.def(model)?;
        let pc = def.param_count();
        anyhow::ensure!(state.params.len() == pc, "params len {} != {pc}", state.params.len());
        anyhow::ensure!(
            self.schema.buckets.contains(&bucket),
            "bucket {bucket} not on the ladder"
        );
        anyhow::ensure!(x.len() == bucket * def.feature_dim, "x wrong size");
        anyhow::ensure!(y.len() == bucket && mask.len() == bucket, "y/mask wrong size");
        ensure_labels_in_range(model, y, def.classes)?;

        let mut ws = self.ws.take();
        // New step generation: invalidates packed weight panels from the
        // previous step (whose optimizer update changed the params).
        ws.begin_step();
        def.forward_ws(&self.pool, &state.params, x, bucket, &mut ws);
        let (loss, acc) = masked_ce_loss_ws(
            &self.pool,
            &ws.logits,
            y,
            mask,
            bucket,
            def.classes,
            &mut ws.logp,
            &mut ws.loss_terms,
            &mut ws.correct,
            &mut ws.dlogits,
        );
        def.backward_ws(&self.pool, &state.params, x, bucket, &mut ws);
        let (sigma_norm, sigma_norm2, grad_l2) = normalized_grad_stats(&ws.grad);
        match optimizer {
            Optimizer::Sgd => apply_sgd(&self.pool, state, &ws.grad, lr),
            Optimizer::Adam => apply_adam(&self.pool, state, &ws.grad, lr),
        }
        out.loss = loss;
        out.acc = acc;
        out.correct.clear();
        out.correct.extend_from_slice(&ws.correct);
        out.sigma_norm = sigma_norm;
        out.sigma_norm2 = sigma_norm2;
        out.grad_l2 = grad_l2;
        self.ws.put(ws);
        Ok(())
    }

    fn eval_step(
        &self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> anyhow::Result<(f32, f32)> {
        let def = self.def(model)?;
        anyhow::ensure!(params.len() == def.param_count(), "params len mismatch");
        let m = mask.len();
        anyhow::ensure!(x.len() == m * def.feature_dim && y.len() == m, "eval batch mismatch");
        ensure_labels_in_range(model, y, def.classes)?;
        let mut ws = self.ws.take();
        ws.begin_step();
        def.forward_ws(&self.pool, params, x, m, &mut ws);
        let (loss, acc) = masked_ce_loss_ws(
            &self.pool,
            &ws.logits,
            y,
            mask,
            m,
            def.classes,
            &mut ws.logp,
            &mut ws.loss_terms,
            &mut ws.correct,
            &mut ws.dlogits,
        );
        self.ws.put(ws);
        Ok((loss, acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    /// Deterministic learnable batch: y = argmax over 10 fixed projections
    /// (the same construction as the historical XLA store test, pinning
    /// train-step loss-decrease behaviour to the ref.py contract).
    fn learnable_batch(n: usize, fd: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..n * fd).map(|_| rng.normal() as f32).collect();
        let proto: Vec<f32> = (0..10 * fd).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..n)
            .map(|i| {
                (0..10)
                    .max_by(|&a, &b| {
                        let da: f32 = (0..fd).map(|j| x[i * fd + j] * proto[a * fd + j]).sum();
                        let db: f32 = (0..fd).map(|j| x[i * fd + j] * proto[b * fd + j]).sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap() as i32
            })
            .collect();
        (x, y)
    }

    #[test]
    fn schema_matches_manifest_constants() {
        let b = backend();
        let s = b.schema();
        assert_eq!(s.state_dim, 16);
        assert_eq!(s.n_actions, 5);
        assert_eq!(s.max_workers, 32);
        assert_eq!(s.ppo_minibatch, 256);
        assert_eq!(s.feature_dim, 128);
        assert_eq!(s.policy_param_count, 5638);
        assert!(s.buckets.windows(2).all(|w| w[0] < w[1]));
        assert!(s.models.contains_key("vgg11_mini"));
        assert_eq!(s.models.len(), 5);
        for (name, info) in &s.models {
            assert_eq!(info.param_count, b.def(name).unwrap().param_count());
        }
    }

    #[test]
    fn train_step_decreases_loss_on_fixed_batch() {
        let b = backend();
        let fd = b.schema().feature_dim;
        let (x, y) = learnable_batch(32, fd);
        let mask = vec![1.0f32; 32];
        let mut state = OptState::new(
            b.init_params("vgg11_mini", 0).unwrap(),
            Optimizer::Sgd,
        );
        let mut losses = Vec::new();
        for _ in 0..25 {
            let out = b
                .train_step("vgg11_mini", Optimizer::Sgd, 32, &mut state, &x, &y, &mask, 0.05)
                .unwrap();
            losses.push(out.loss);
            assert!(out.sigma_norm >= 0.0 && out.grad_l2 >= 0.0);
            assert_eq!(out.correct.len(), 32);
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        assert!(
            losses[24] < losses[0] * 0.8,
            "loss did not decrease: {losses:?}"
        );
    }

    #[test]
    fn adam_train_step_also_learns() {
        let b = backend();
        let fd = b.schema().feature_dim;
        let (x, y) = learnable_batch(32, fd);
        let mask = vec![1.0f32; 32];
        let mut state = OptState::new(
            b.init_params("vgg11_mini", 0).unwrap(),
            Optimizer::Adam,
        );
        let mut losses = Vec::new();
        for _ in 0..25 {
            let out = b
                .train_step("vgg11_mini", Optimizer::Adam, 32, &mut state, &x, &y, &mask, 0.002)
                .unwrap();
            losses.push(out.loss);
        }
        assert!(losses[24] < losses[0], "adam did not learn: {losses:?}");
    }

    #[test]
    fn train_step_validates_shapes() {
        let b = backend();
        let mut state = OptState::new(b.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
        let fd = b.schema().feature_dim;
        // Off-ladder bucket.
        let err = b
            .train_step("vgg11_mini", Optimizer::Sgd, 33, &mut state,
                        &vec![0.0; 33 * fd], &vec![0; 33], &vec![1.0; 33], 0.05)
            .unwrap_err()
            .to_string();
        assert!(err.contains("ladder"), "{err}");
        // Wrong x size.
        assert!(b
            .train_step("vgg11_mini", Optimizer::Sgd, 32, &mut state,
                        &vec![0.0; 31 * fd], &vec![0; 32], &vec![1.0; 32], 0.05)
            .is_err());
        // Out-of-range label errors with the offending value, no panic.
        let err = b
            .train_step("vgg11_mini", Optimizer::Sgd, 32, &mut state,
                        &vec![0.0; 32 * fd], &vec![37; 32], &vec![1.0; 32], 0.05)
            .unwrap_err()
            .to_string();
        assert!(err.contains("37"), "{err}");
        // Unknown model.
        assert!(b.init_params("nope", 0).is_err());
    }

    #[test]
    fn eval_step_tracks_training() {
        let b = backend();
        let fd = b.schema().feature_dim;
        let (x, y) = learnable_batch(128, fd);
        let mask = vec![1.0f32; 128];
        let mut state = OptState::new(b.init_params("vgg11_mini", 1).unwrap(), Optimizer::Sgd);
        let (l0, _) = b.eval_step("vgg11_mini", &state.params, &x, &y, &mask).unwrap();
        for _ in 0..40 {
            b.train_step("vgg11_mini", Optimizer::Sgd, 128, &mut state, &x, &y, &mask, 0.05)
                .unwrap();
        }
        let (l1, a1) = b.eval_step("vgg11_mini", &state.params, &x, &y, &mask).unwrap();
        assert!(l1 < l0, "eval loss did not drop: {l0} -> {l1}");
        assert!(a1 > 0.5, "train-set accuracy too low after fitting: {a1}");
    }

    #[test]
    fn train_step_steady_state_does_not_allocate() {
        let b = NativeBackend::with_threads(2);
        let fd = b.schema().feature_dim;
        let (x, y) = learnable_batch(128, fd);
        let mask = vec![1.0f32; 128];
        let mut state = OptState::new(b.init_params("vgg11_mini", 0).unwrap(), Optimizer::Sgd);
        // Warmup: grows the pooled workspace to its steady shape.
        for _ in 0..3 {
            b.train_step("vgg11_mini", Optimizer::Sgd, 128, &mut state, &x, &y, &mask, 0.05)
                .unwrap();
            b.eval_step("vgg11_mini", &state.params, &x, &y, &mask).unwrap();
        }
        let warm = b.workspace_stats();
        assert_eq!(warm.0, 1, "sequential steps should share one pooled workspace");
        assert!(warm.1 > 0);
        for _ in 0..10 {
            b.train_step("vgg11_mini", Optimizer::Sgd, 128, &mut state, &x, &y, &mask, 0.05)
                .unwrap();
            b.eval_step("vgg11_mini", &state.params, &x, &y, &mask).unwrap();
        }
        assert_eq!(
            b.workspace_stats(),
            warm,
            "steady-state train/eval steps must not grow scratch capacity"
        );
    }

    #[test]
    fn policy_update_steady_state_does_not_allocate() {
        let b = NativeBackend::with_threads(1);
        let s = b.schema();
        let (mbsize, sd) = (s.ppo_minibatch, s.state_dim);
        let mut opt = OptState::adam(b.init_policy(0).unwrap());
        let states = vec![0.1f32; mbsize * sd];
        let actions: Vec<i32> = (0..mbsize).map(|i| (i % 5) as i32).collect();
        let old_logp = vec![-1.6f32; mbsize];
        let adv = vec![0.5f32; mbsize];
        let ret = vec![0.5f32; mbsize];
        let mask = vec![1.0f32; mbsize];
        let mb = PpoMinibatch {
            states: &states,
            actions: &actions,
            old_logp: &old_logp,
            advantages: &adv,
            returns: &ret,
            mask: &mask,
        };
        let hp = PpoHyper { lr: 1e-3, clip_eps: 0.2, ent_coef: 0.01, vf_coef: 0.5 };
        for _ in 0..2 {
            b.policy_update(PpoVariant::Clipped, &mut opt, &mb, hp).unwrap();
        }
        let warm = b.workspace_stats();
        for _ in 0..8 {
            b.policy_update(PpoVariant::Clipped, &mut opt, &mb, hp).unwrap();
        }
        assert_eq!(b.workspace_stats(), warm, "policy_update must reuse its workspace");
    }

    #[test]
    fn bucketed_backward_chain_matches_bulk_bitwise() {
        // Two shards, every bucket plan: chaining per-bucket seeds through
        // shard_backward_bucket (with prep-ahead interleaved, as the worker
        // loop does) must reproduce the bulk chained backward bit for bit.
        let b = NativeBackend::with_threads(1);
        let fd = b.schema().feature_dim;
        for model in ["vgg11_mini", "resnet34_mini"] {
            let def = b.def(model).unwrap().clone();
            let pc = def.param_count();
            let params = b.init_params(model, 0).unwrap();
            let mut rng = Rng::new(31);
            let rows = 9usize;
            let x: Vec<f32> = (0..rows * fd).map(|_| rng.normal() as f32).collect();
            let y: Vec<i32> = (0..rows).map(|_| rng.below(def.classes) as i32).collect();
            let mask = vec![1.0f32; rows];
            let denom = rows as f32;
            let split = 4usize; // shard 0: rows [0,4), shard 1: rows [4,9)

            let shard_fwd = |lo: usize, hi: usize| {
                b.shard_forward(
                    model,
                    &params,
                    x[lo * fd..hi * fd].to_vec(),
                    &y[lo..hi],
                    &mask[lo..hi],
                    denom,
                )
                .unwrap()
                .0
            };

            // Bulk reference: the PR-4 chained reduction.
            let mut bulk = vec![0.0f32; pc];
            for (lo, hi) in [(0, split), (split, rows)] {
                let ctx = shard_fwd(lo, hi);
                b.shard_backward_acc(&params, ctx, &mut bulk).unwrap();
            }

            for target_bytes in [0usize, 40 << 10, 4 * pc] {
                let plan = def.bucket_plan(target_bytes);
                let mut ctx0 = shard_fwd(0, split);
                let mut ctx1 = shard_fwd(split, rows);
                let mut grad = vec![0.0f32; pc];
                let (mut hop, mut out) = (Vec::new(), Vec::new());
                for bu in &plan {
                    let seed = vec![0.0f32; bu.len];
                    b.shard_backward_bucket(&params, &mut ctx0, bu.offset, &seed, &mut hop)
                        .unwrap();
                    b.shard_backward_prep_ahead(&params, &mut ctx0).unwrap();
                    b.shard_backward_bucket(&params, &mut ctx1, bu.offset, &hop, &mut out)
                        .unwrap();
                    b.shard_backward_prep_ahead(&params, &mut ctx1).unwrap();
                    grad[bu.offset..bu.offset + bu.len].copy_from_slice(&out);
                }
                assert!(b.shard_backward_done(&ctx0).unwrap());
                b.shard_finish(ctx0).unwrap();
                b.shard_finish(ctx1).unwrap();
                for (i, (a, r)) in grad.iter().zip(&bulk).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        r.to_bits(),
                        "{model} target {target_bytes}: grad[{i}] {a} != bulk {r}"
                    );
                }
            }

            // A bucket that skips the fold cursor fails loudly.
            let mut ctx = shard_fwd(0, rows);
            let stages = def.grad_stages();
            let s1 = stages[1];
            let err = b
                .shard_backward_bucket(&params, &mut ctx, s1.offset, &vec![0.0; s1.len], &mut Vec::new())
                .unwrap_err()
                .to_string();
            assert!(err.contains("fold cursor"), "{err}");
            // Retiring an incomplete backward is an error (not a leak).
            assert!(b.shard_finish(ctx).unwrap_err().to_string().contains("stages"));
        }
    }

    #[test]
    fn all_zoo_models_run_one_step() {
        let b = backend();
        let fd = b.schema().feature_dim;
        let mut rng = Rng::new(3);
        for (name, info) in b.schema().models.clone() {
            let x: Vec<f32> = (0..32 * fd).map(|_| rng.normal() as f32).collect();
            let y: Vec<i32> = (0..32).map(|_| rng.below(info.num_classes) as i32).collect();
            let mask = vec![1.0f32; 32];
            let mut state =
                OptState::new(b.init_params(&name, 0).unwrap(), Optimizer::Sgd);
            let out = b
                .train_step(&name, Optimizer::Sgd, 32, &mut state, &x, &y, &mask, 0.01)
                .unwrap();
            assert!(out.loss.is_finite(), "{name}: loss {}", out.loss);
            // Untrained loss sits in the chance band: above ~ln(C)/2 (not
            // already solved) and below a few multiples of ln(C) (He init
            // keeps logit scale O(1); a blown-up init would exceed this).
            let chance = (info.num_classes as f32).ln();
            assert!(
                out.loss > chance * 0.5 && out.loss < chance * 2.5,
                "{name}: initial loss {} outside chance band of ln(C)={chance}",
                out.loss
            );
        }
    }
}
