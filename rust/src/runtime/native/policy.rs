//! Native PPO policy network + update steps, mirroring `python/compile/policy.py`.
//!
//! 2x64 tanh trunk with separate logit/value heads over the 16-feature
//! state; clipped-surrogate PPO (Eq. 1) and the paper's §IV-A simplified
//! cumulative-return variant, both with entropy bonus, masked minibatches
//! and Adam. Parameter layout is the `ravel_pytree` order of
//! `init_policy_params`: `fc0 < fc1 < pi < vf`, `b < w` within each dense.

use super::exec::Pool;
use super::linalg::*;
use super::model::{apply_adam, fnv1a, DenseRef};
use super::workspace::Workspace;
use crate::config::PpoVariant;
use crate::runtime::backend::{OptState, PolicyOut, PpoHyper, PpoMinibatch, PpoStats};
use crate::util::rng::Rng;

pub const STATE_DIM: usize = 16;
pub const N_ACTIONS: usize = 5;
pub const HIDDEN: usize = 64;
pub const MAX_WORKERS: usize = 32;
pub const MINIBATCH: usize = 256;

/// fc0.b | fc0.w | fc1.b | fc1.w | pi.b | pi.w | vf.b | vf.w
const FC0: DenseRef = DenseRef { b: 0, w: HIDDEN, k: STATE_DIM, n: HIDDEN };
const FC0_END: usize = HIDDEN + STATE_DIM * HIDDEN;
const FC1: DenseRef = DenseRef { b: FC0_END, w: FC0_END + HIDDEN, k: HIDDEN, n: HIDDEN };
const FC1_END: usize = FC0_END + HIDDEN + HIDDEN * HIDDEN;
const PI: DenseRef = DenseRef { b: FC1_END, w: FC1_END + N_ACTIONS, k: HIDDEN, n: N_ACTIONS };
const PI_END: usize = FC1_END + N_ACTIONS + HIDDEN * N_ACTIONS;
const VF: DenseRef = DenseRef { b: PI_END, w: PI_END + 1, k: HIDDEN, n: 1 };
pub const PARAM_COUNT: usize = PI_END + 1 + HIDDEN;

/// Seeded policy init (`init_policy_params` distributions: 1/sqrt(fan_in)
/// trunk, near-zero heads so the initial policy is ~uniform and the initial
/// value ~0).
pub fn init_policy(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ fnv1a(b"dynamix-policy"));
    let mut p = vec![0.0f32; PARAM_COUNT];
    let mut fill = |p: &mut [f32], r: &DenseRef, scale: f64| {
        for v in &mut p[r.w..r.w + r.k * r.n] {
            *v = (rng.normal() * scale) as f32;
        }
    };
    fill(&mut p, &FC0, (1.0 / STATE_DIM as f64).sqrt());
    fill(&mut p, &FC1, (1.0 / HIDDEN as f64).sqrt());
    fill(&mut p, &PI, 0.01);
    fill(&mut p, &VF, 0.01);
    p
}

/// Trunk forward over `m` state rows into reused buffers.
fn trunk_into(
    pool: &Pool,
    theta: &[f32],
    states: &[f32],
    m: usize,
    h1: &mut Vec<f32>,
    h2: &mut Vec<f32>,
    logits: &mut Vec<f32>,
    values: &mut Vec<f32>,
) {
    h1.clear();
    h1.resize(m * HIDDEN, 0.0);
    matmul_acc(pool, states, &theta[FC0.w..FC0.w + FC0.k * FC0.n], m, STATE_DIM, HIDDEN, h1);
    add_bias(pool, h1, &theta[FC0.b..FC0.b + HIDDEN], m, HIDDEN);
    tanh(pool, h1);

    h2.clear();
    h2.resize(m * HIDDEN, 0.0);
    matmul_acc(pool, h1, &theta[FC1.w..FC1.w + FC1.k * FC1.n], m, HIDDEN, HIDDEN, h2);
    add_bias(pool, h2, &theta[FC1.b..FC1.b + HIDDEN], m, HIDDEN);
    tanh(pool, h2);

    logits.clear();
    logits.resize(m * N_ACTIONS, 0.0);
    matmul_acc(pool, h2, &theta[PI.w..PI.w + PI.k * PI.n], m, HIDDEN, N_ACTIONS, logits);
    add_bias(pool, logits, &theta[PI.b..PI.b + N_ACTIONS], m, N_ACTIONS);

    values.clear();
    values.resize(m, 0.0);
    matmul_acc(pool, h2, &theta[VF.w..VF.w + HIDDEN], m, HIDDEN, 1, values);
    let vb = theta[VF.b];
    for v in values.iter_mut() {
        *v += vb;
    }
}

/// Trunk forward over `m` state rows: returns (h1, h2, logits, values).
/// Owned-buffer wrapper over [`trunk_into`] (tests / one-off callers).
fn trunk(theta: &[f32], states: &[f32], m: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mut h1, mut h2, mut logits, mut values) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    trunk_into(
        &Pool::sequential(), theta, states, m, &mut h1, &mut h2, &mut logits, &mut values,
    );
    (h1, h2, logits, values)
}

/// `policy_forward`: log-softmax action scores + values for `m` rows.
pub fn policy_forward(theta: &[f32], states: &[f32]) -> anyhow::Result<PolicyOut> {
    anyhow::ensure!(theta.len() == PARAM_COUNT, "theta len {} != {PARAM_COUNT}", theta.len());
    anyhow::ensure!(
        states.len() % STATE_DIM == 0,
        "states len {} not a multiple of {STATE_DIM}",
        states.len()
    );
    let m = states.len() / STATE_DIM;
    let (_h1, _h2, logits, values) = trunk(theta, states, m);
    let mut logp = vec![0.0f32; m * N_ACTIONS];
    log_softmax(&Pool::sequential(), &logits, m, N_ACTIONS, &mut logp);
    Ok(PolicyOut { logp, values })
}

/// One PPO minibatch step (clipped or simplified), updating `opt` in place.
/// Owned-buffer wrapper over [`policy_update_ws`].
pub fn policy_update(
    variant: PpoVariant,
    opt: &mut OptState,
    mb: &PpoMinibatch,
    hp: PpoHyper,
) -> anyhow::Result<PpoStats> {
    let mut ws = Workspace::default();
    policy_update_ws(&Pool::sequential(), &mut ws, variant, opt, mb, hp)
}

/// One PPO minibatch step into workspace buffers; allocation-free once the
/// workspace is warm.
pub fn policy_update_ws(
    pool: &Pool,
    ws: &mut Workspace,
    variant: PpoVariant,
    opt: &mut OptState,
    mb: &PpoMinibatch,
    hp: PpoHyper,
) -> anyhow::Result<PpoStats> {
    let b = mb.mask.len();
    anyhow::ensure!(opt.params.len() == PARAM_COUNT, "theta len {}", opt.params.len());
    anyhow::ensure!(mb.states.len() == b * STATE_DIM, "states len mismatch");
    anyhow::ensure!(
        mb.actions.len() == b && mb.old_logp.len() == b && mb.advantages.len() == b
            && mb.returns.len() == b,
        "minibatch field length mismatch"
    );

    // Each minibatch step ends in an Adam update, so the packed weight
    // panels below are valid for exactly this step's generation.
    let gen = ws.begin_step();
    let Workspace {
        panels,
        p_h1: h1,
        p_h2: h2,
        p_logits: logits,
        p_values: values,
        p_logp: logp,
        p_dlogits: dlogits,
        p_dvalues: dvalues,
        p_grad: g,
        p_dh1: dh1,
        p_dh2: dh2,
        ..
    } = ws;

    let theta = &opt.params;
    trunk_into(pool, theta, mb.states, b, h1, h2, logits, values);
    logp.clear();
    logp.resize(b * N_ACTIONS, 0.0);
    log_softmax(pool, logits, b, N_ACTIONS, logp);
    // PARITY: sequential left-to-right mask fold, mirrored by the
    // finite-difference test's loss recomputation — keep associations
    // identical or the gradient check drifts.
    let denom: f32 = mb.mask.iter().sum::<f32>().max(1.0);

    let mut pg_sum = 0.0f64;
    let mut v_sum = 0.0f64;
    let mut ent_sum = 0.0f64;
    let mut kl_sum = 0.0f64;
    dlogits.clear();
    dlogits.resize(b * N_ACTIONS, 0.0);
    dvalues.clear();
    dvalues.resize(b, 0.0);

    for i in 0..b {
        let mi = mb.mask[i];
        if mi == 0.0 {
            continue;
        }
        let lrow = &logp[i * N_ACTIONS..(i + 1) * N_ACTIONS];
        let ai = mb.actions[i] as usize;
        anyhow::ensure!(ai < N_ACTIONS, "action {ai} out of range");
        let lp = lrow[ai];
        // Entropy of this row's policy.
        let mut h_i = 0.0f32;
        for &l in lrow {
            h_i -= l.exp() * l;
        }
        ent_sum += (h_i * mi) as f64;

        // Policy-gradient coefficient dL/d(logp_i(a_i)).
        let gpg = match variant {
            PpoVariant::Clipped => {
                let ratio = (lp - mb.old_logp[i]).exp();
                let adv = mb.advantages[i];
                let unclipped = ratio * adv;
                let clipped = ratio.clamp(1.0 - hp.clip_eps, 1.0 + hp.clip_eps) * adv;
                pg_sum += (unclipped.min(clipped) * mi) as f64;
                kl_sum += ((mb.old_logp[i] - lp) * mi) as f64;
                if unclipped <= clipped {
                    -(mi / denom) * ratio * adv
                } else {
                    0.0 // clip is binding: constant branch, zero gradient
                }
            }
            PpoVariant::Simplified => {
                let ret = mb.returns[i];
                pg_sum += (lp * ret * mi) as f64;
                -(mi / denom) * ret
            }
        };

        // d(loss)/d(logits): pg term through the softmax Jacobian plus the
        // entropy bonus gradient ent*(m/D)*p*(logp + H).
        let drow = &mut dlogits[i * N_ACTIONS..(i + 1) * N_ACTIONS];
        for j in 0..N_ACTIONS {
            let pj = lrow[j].exp();
            drow[j] = -gpg * pj + hp.ent_coef * (mi / denom) * pj * (lrow[j] + h_i);
        }
        drow[ai] += gpg;

        let vdiff = values[i] - mb.returns[i];
        v_sum += ((vdiff * vdiff) * mi) as f64;
        dvalues[i] = hp.vf_coef * (mi / denom) * 2.0 * vdiff;
    }

    let pg_loss = (-pg_sum / denom as f64) as f32;
    let v_loss = (v_sum / denom as f64) as f32;
    let entropy = (ent_sum / denom as f64) as f32;
    let approx_kl = match variant {
        PpoVariant::Clipped => (kl_sum / denom as f64) as f32,
        PpoVariant::Simplified => 0.0,
    };
    let loss = pg_loss + hp.vf_coef * v_loss - hp.ent_coef * entropy;

    // Backward through heads + trunk into a flat gradient.
    g.clear();
    g.resize(PARAM_COUNT, 0.0);
    // pi head: dh2 from logits.
    col_sums(pool, dlogits, b, N_ACTIONS, &mut g[PI.b..PI.b + N_ACTIONS]);
    matmul_at(pool, h2, dlogits, b, HIDDEN, N_ACTIONS, &mut g[PI.w..PI.w + HIDDEN * N_ACTIONS]);
    dh2.clear();
    dh2.resize(b * HIDDEN, 0.0);
    matmul_bt_ws(
        pool, panels, gen, PI.w, dlogits, &theta[PI.w..PI.w + HIDDEN * N_ACTIONS],
        b, HIDDEN, N_ACTIONS, dh2,
    );
    // vf head: dh2 += dv ⊗ w_vf.
    let mut dvb = 0.0f32;
    for &dv in dvalues.iter() {
        dvb += dv;
    }
    g[VF.b] = dvb;
    for k in 0..HIDDEN {
        let wk = theta[VF.w + k];
        let mut gw = 0.0f32;
        for i in 0..b {
            gw += h2[i * HIDDEN + k] * dvalues[i];
            dh2[i * HIDDEN + k] += dvalues[i] * wk;
        }
        g[VF.w + k] = gw;
    }

    tanh_backward(pool, dh2, h2);
    col_sums(pool, dh2, b, HIDDEN, &mut g[FC1.b..FC1.b + HIDDEN]);
    matmul_at(pool, h1, dh2, b, HIDDEN, HIDDEN, &mut g[FC1.w..FC1.w + HIDDEN * HIDDEN]);
    dh1.clear();
    dh1.resize(b * HIDDEN, 0.0);
    matmul_bt_ws(
        pool, panels, gen, FC1.w, dh2, &theta[FC1.w..FC1.w + HIDDEN * HIDDEN],
        b, HIDDEN, HIDDEN, dh1,
    );
    tanh_backward(pool, dh1, h1);
    col_sums(pool, dh1, b, HIDDEN, &mut g[FC0.b..FC0.b + HIDDEN]);
    matmul_at(pool, mb.states, dh1, b, STATE_DIM, HIDDEN, &mut g[FC0.w..FC0.w + STATE_DIM * HIDDEN]);

    apply_adam(pool, opt, g, hp.lr);

    Ok(PpoStats { loss, pg_loss, v_loss, entropy, approx_kl })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hp() -> PpoHyper {
        PpoHyper { lr: 1e-2, clip_eps: 0.2, ent_coef: 0.01, vf_coef: 0.5 }
    }

    #[test]
    fn param_count_matches_ravel_pytree() {
        // fc0 (64 + 16*64) + fc1 (64 + 64*64) + pi (5 + 64*5) + vf (1 + 64).
        assert_eq!(PARAM_COUNT, 5638);
        assert_eq!(init_policy(0).len(), PARAM_COUNT);
    }

    #[test]
    fn forward_logprobs_normalized_and_near_uniform_at_init() {
        let theta = init_policy(0);
        let states = vec![0.1f32; MAX_WORKERS * STATE_DIM];
        let out = policy_forward(&theta, &states).unwrap();
        assert_eq!(out.logp.len(), MAX_WORKERS * N_ACTIONS);
        assert_eq!(out.values.len(), MAX_WORKERS);
        let uniform = (1.0f32 / N_ACTIONS as f32).ln();
        for w in 0..MAX_WORKERS {
            let row = &out.logp[w * N_ACTIONS..(w + 1) * N_ACTIONS];
            let total: f32 = row.iter().map(|l| l.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4, "worker {w}: {total}");
            // Near-zero head init => close to uniform, value near 0.
            for &l in row {
                assert!((l - uniform).abs() < 0.5, "far from uniform: {l}");
            }
            assert!(out.values[w].abs() < 0.5);
        }
    }

    /// Build a full padded minibatch rewarding `target` at a fixed state.
    fn minibatch_for<'a>(
        target: usize,
        n: usize,
        bufs: &'a mut (Vec<f32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>),
    ) -> PpoMinibatch<'a> {
        let (states, actions, old_logp, adv, ret, mask) = bufs;
        *states = vec![0.0f32; MINIBATCH * STATE_DIM];
        *actions = vec![0i32; MINIBATCH];
        *old_logp = vec![(1.0f32 / N_ACTIONS as f32).ln(); MINIBATCH];
        *adv = vec![0.0f32; MINIBATCH];
        *ret = vec![0.0f32; MINIBATCH];
        *mask = vec![0.0f32; MINIBATCH];
        for i in 0..n {
            for d in 0..STATE_DIM {
                states[i * STATE_DIM + d] = 0.2;
            }
            let a = i % N_ACTIONS;
            actions[i] = a as i32;
            adv[i] = if a == target { 1.0 } else { -0.25 };
            ret[i] = adv[i];
            mask[i] = 1.0;
        }
        PpoMinibatch {
            states: states.as_slice(),
            actions: actions.as_slice(),
            old_logp: old_logp.as_slice(),
            advantages: adv.as_slice(),
            returns: ret.as_slice(),
            mask: mask.as_slice(),
        }
    }

    #[test]
    fn update_direction_favors_advantaged_action() {
        // Golden direction test pinned to policy.py semantics: positive
        // advantage on one action must raise its probability.
        let mut opt = OptState::adam(init_policy(1));
        let probe = vec![0.2f32; STATE_DIM];
        let before = policy_forward(&opt.params, &probe).unwrap().logp[3];
        let mut bufs = Default::default();
        for _ in 0..40 {
            let mb = minibatch_for(3, 64, &mut bufs);
            let stats = policy_update(PpoVariant::Clipped, &mut opt, &mb, hp()).unwrap();
            assert!(stats.loss.is_finite());
            assert!(stats.entropy > 0.0);
        }
        let after = policy_forward(&opt.params, &probe).unwrap().logp[3];
        assert!(
            after > before + 0.1,
            "action 3 logp did not rise: {before} -> {after}"
        );
    }

    #[test]
    fn simplified_variant_reports_zero_kl_and_updates() {
        let mut opt = OptState::adam(init_policy(2));
        let t0 = opt.params.clone();
        let mut bufs = Default::default();
        let mb = minibatch_for(1, 32, &mut bufs);
        let stats = policy_update(PpoVariant::Simplified, &mut opt, &mb, hp()).unwrap();
        assert_eq!(stats.approx_kl, 0.0);
        assert!(stats.loss.is_finite());
        assert_ne!(t0, opt.params);
    }

    #[test]
    fn masked_rows_do_not_move_params() {
        // An all-masked minibatch must be a no-op gradient (Adam still
        // advances its step counter but with g = 0 params stay put).
        let mut opt = OptState::adam(init_policy(3));
        let t0 = opt.params.clone();
        let mut bufs = Default::default();
        let mb = minibatch_for(0, 0, &mut bufs); // n = 0 valid rows
        let stats = policy_update(PpoVariant::Clipped, &mut opt, &mb, hp()).unwrap();
        assert_eq!(stats.loss, 0.0);
        assert_eq!(t0, opt.params);
    }

    #[test]
    fn finite_difference_checks_ppo_gradient() {
        // Check the hand-derived clipped-PPO gradient against central
        // differences of the scalar loss at a handful of parameters.
        let theta0 = init_policy(5);
        let mut bufs = Default::default();
        let mb = minibatch_for(2, 48, &mut bufs);
        let h = hp();

        let loss_at = |theta: &[f32]| -> f64 {
            // Recompute the loss only (no update): forward + the same sums.
            let b = mb.mask.len();
            let (_h1, _h2, logits, values) = super::trunk(theta, mb.states, b);
            let mut logp = vec![0.0f32; b * N_ACTIONS];
            log_softmax(&Pool::sequential(), &logits, b, N_ACTIONS, &mut logp);
            // PARITY: same fold as `policy_update_ws`'s denominator.
            let denom: f32 = mb.mask.iter().sum::<f32>().max(1.0);
            let (mut pg, mut vl, mut ent) = (0.0f64, 0.0f64, 0.0f64);
            for i in 0..b {
                let mi = mb.mask[i];
                if mi == 0.0 {
                    continue;
                }
                let lrow = &logp[i * N_ACTIONS..(i + 1) * N_ACTIONS];
                let lp = lrow[mb.actions[i] as usize];
                let ratio = (lp - mb.old_logp[i]).exp();
                let adv = mb.advantages[i];
                let clipped = ratio.clamp(1.0 - h.clip_eps, 1.0 + h.clip_eps) * adv;
                pg += ((ratio * adv).min(clipped) * mi) as f64;
                let vd = values[i] - mb.returns[i];
                vl += (vd * vd * mi) as f64;
                let mut hi = 0.0f32;
                for &l in lrow {
                    hi -= l.exp() * l;
                }
                ent += (hi * mi) as f64;
            }
            let d = denom as f64;
            -pg / d + h.vf_coef as f64 * (vl / d) - h.ent_coef as f64 * (ent / d)
        };

        // Analytic gradient via the Adam first step: run the update from
        // zero moments; Adam's first step is -lr*sign(g), so recover sign
        // only — instead, re-derive g by differencing params is lossy.
        // Cleaner: call policy_update on a clone and read the moment m,
        // which after one step equals (1-b1)*g / — m = 0.1*g exactly.
        let mut opt = OptState::adam(theta0.clone());
        policy_update(PpoVariant::Clipped, &mut opt, &mb, h).unwrap();
        let g: Vec<f32> = opt.m.iter().map(|m| m / 0.1).collect();

        let mut theta = theta0.clone();
        for &idx in &[0usize, 100, FC1.w + 7, PI.w + 3, VF.w + 10, PARAM_COUNT - 1] {
            let eps = 2e-3f32;
            let orig = theta[idx];
            theta[idx] = orig + eps;
            let lp = loss_at(&theta);
            theta[idx] = orig - eps;
            let lm = loss_at(&theta);
            theta[idx] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!(
                (fd - g[idx]).abs() < 2e-2 * (1.0 + fd.abs().max(g[idx].abs())),
                "param {idx}: fd {fd} vs analytic {}",
                g[idx]
            );
        }
    }
}
